"""Structured serve tracing: recorder semantics, lifecycle audit, Chrome
export, and the metrics fixes that rode along.

The load-bearing contracts pinned here:

  * the event taxonomy is CLOSED (unknown names raise) and the disabled
    path (`NULL_RECORDER`) is a true no-op — a traced engine and an
    untraced one emit byte-identical greedy streams with the same two
    compiled step programs;
  * a traced replay (virtual clock) passes the full `traceview.audit`:
    per-request TTFT / latency / stall recomputed from event timestamps
    match the `ServeMetrics` sample lists, every admit reaches a terminal
    finish, the block pool conserves, decode-only steps carry zero chunk
    tokens, and the Chrome-trace export is valid JSON;
  * the audit actually BITES: corrupting a trace (dropped finish, forged
    free_after, inflated metrics) flips it to FAIL;
  * write_trace/load_trace round-trip events + metrics + metadata through
    one Perfetto-openable file;
  * satellites — `ServeMetrics.wall_s` is 0.0 (not 1e-9) while unset,
    `percentile` boundary behaviour, `chunk_fill_frac` with no chunk
    steps, and the pinned `bench_serving` CSV schema.
"""

import json
import math
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serve.kvcache import BlockAllocator, KVCacheConfig
from repro.serve.metrics import ServeMetrics, percentile
from repro.serve.runtime import ContinuousEngine, RuntimeConfig
from repro.serve.scheduler import ContinuousScheduler, ServeRequest
from repro.serve.trace import (
    EVENT_TYPES,
    NULL_RECORDER,
    TraceEvent,
    TraceRecorder,
    load_trace,
    metrics_snapshot,
    to_chrome_trace,
    write_trace,
)
from repro.serve import traceview

# benchmarks/ is a PEP 420 namespace package next to src/, not on the
# src path — make the CSV-schema import work under `PYTHONPATH=src pytest`
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax  # noqa: E402

from repro.distributed.sharding import DEFAULT_RULES  # noqa: E402
from repro.launch.mesh import single_device_mesh  # noqa: E402


# ---------------------------------------------------------------- recorder
def test_recorder_rejects_unknown_event_names():
    rec = TraceRecorder(now_fn=lambda: 1.0)
    rec.emit("submit", rid=1, arrival=0.5, prompt_len=4, max_new=2)
    assert len(rec) == 1
    with pytest.raises(ValueError, match="taxonomy is closed"):
        rec.emit("sumbit", rid=1)          # typo must be loud, not recorded
    assert len(rec) == 1


def test_recorder_clock_binding_and_explicit_timestamps():
    clock = {"t": 3.0}
    rec = TraceRecorder(now_fn=lambda: clock["t"])
    rec.emit("preempt", rid=7, slot=0)
    clock["t"] = 9.0
    rec.emit("finish", rid=7, n_output=2)
    rec.emit("compile", t=4.5, program="unified")
    assert [e.t for e in rec.events] == [3.0, 9.0, 4.5]
    rec.clear()
    assert len(rec) == 0 and rec.events == []


def test_null_recorder_is_inert():
    assert NULL_RECORDER.enabled is False
    NULL_RECORDER.emit("nonsense_name_never_validated", rid=1)
    NULL_RECORDER.emit("finish", rid=1)
    assert len(NULL_RECORDER) == 0
    NULL_RECORDER.clear()
    assert list(NULL_RECORDER.events) == []


def test_trace_event_dict_roundtrip():
    e = TraceEvent("chunk_committed", 1.25, rid=3,
                   fields={"start": 0, "n": 8, "prefilled": 8})
    assert TraceEvent.from_dict(e.to_dict()) == e
    # rid-less (scheduler-scoped) events omit the rid key entirely
    s = TraceEvent("step_begin", 0.0, fields={"step": 0, "kind": "unified"})
    assert "rid" not in s.to_dict()
    assert TraceEvent.from_dict(s.to_dict()) == s


# ------------------------------------------------------- metrics satellites
def test_wall_s_zero_until_clock_set():
    """Regression: wall_s used to return the 1e-9 division sentinel while
    start/end were unset, so tokens_per_s() on an engine that never ran
    reported billions of tok/s instead of 0."""
    m = ServeMetrics()
    m.tokens_out = 100
    assert m.wall_s == 0.0
    assert m.tokens_per_s() == 0.0
    assert m.summary()["tokens_per_s"] == 0.0
    m.start_time, m.end_time = 2.0, 6.0
    assert m.wall_s == 4.0
    assert m.tokens_per_s() == 25.0


def test_percentile_boundaries():
    xs = [5.0, 1.0, 3.0, 2.0, 4.0]
    assert percentile(xs, 0) == 1.0       # p=0 clamps to the minimum
    assert percentile(xs, 100) == 5.0
    assert percentile(xs, 50) == 3.0
    assert percentile([7.25], 0) == 7.25  # single element, any p
    assert percentile([7.25], 100) == 7.25
    assert percentile([2.0, 2.0, 2.0, 9.0], 50) == 2.0   # duplicates
    assert percentile([2.0, 2.0, 2.0, 9.0], 95) == 9.0
    assert percentile([], 95) == 0.0


def test_chunk_fill_frac_zero_without_chunk_steps():
    m = ServeMetrics()
    assert m.chunk_fill_frac() == 0.0
    m.record_decode_only_step()           # decode-only steps pay no lane
    assert m.chunk_fill_frac() == 0.0
    m.record_chunk_step([3, 5], lane_width=16)
    assert m.chunk_fill_frac() == 0.5


def test_bench_serving_csv_schema_pinned():
    """The harness CSV contract: exact ordered row names + 3-tuple rows.
    Extending the bench means updating this snapshot in the same change."""
    from benchmarks import bench_serving as bs

    assert bs.expected_csv_names() == [
        "serve_fixed_tok_s",
        "serve_continuous_tok_s",
        "serve_speedup_x",
        "serve_chunk_fill_frac",
        "serve_sampled_tok_s",
        "serve_sampled_mismatches",
        "serve_packing_packed_tok_s",
        "serve_packing_single_seg_tok_s",
        "serve_prefix_on_tok_s",
        "serve_prefix_off_tok_s",
        "serve_interference_chunked_decode_tbt_p95_s",
        "serve_interference_unchunked_decode_tbt_p95_s",
        "serve_pool_1.00x_tok_s",
        "serve_pool_0.50x_tok_s",
        "serve_pool_0.25x_tok_s",
        "serve_lane_xla-only_tok_s",
        "serve_lane_tuned_plan_tok_s",
        "serve_lane_forced_pallas_tok_s",
        "serve_ssm_fixed_tok_s",
        "serve_ssm_continuous_tok_s",
        "serve_ssm_speedup_x",
        "serve_ssm_preemptions",
        "serve_tp_mesh1_tok_s",
        "serve_tp_mesh2_tok_s",
        "serve_tp_mesh4_tok_s",
        "serve_tp_tuned_tok_s",
        "serve_tp_replicated_tok_s",
    ]
    # sections the smoke run skips drop their rows, never reorder the rest
    assert bs.expected_csv_names(pressure=False, lanes=False, ssm=False,
                                 tp=False) == bs.expected_csv_names()[:12]
    assert bs.expected_csv_names(tp=False) == \
        [n for n in bs.expected_csv_names() if "serve_tp_" not in n]
    assert bs.expected_csv_names(sampled=False) == \
        [n for n in bs.expected_csv_names() if "sampled" not in n]
    assert bs.expected_csv_names(prefix=False) == \
        [n for n in bs.expected_csv_names() if "prefix" not in n]
    row = bs.csv_row("serve_fixed_tok_s", np.float64(12.5), "derived note")
    assert row == ("serve_fixed_tok_s", 12.5, "derived note")
    assert isinstance(row[1], float) and len(row) == len(bs.CSV_COLUMNS)
    assert bs.csv_row("x", 3)[2] == ""
    with pytest.raises(ValueError):
        bs.csv_row("", 1.0)
    with pytest.raises((TypeError, ValueError)):
        bs.csv_row("serve_fixed_tok_s", "not-a-number")


# ------------------------------------------------------------ reject events
def test_scheduler_emits_reject_event_before_raising():
    kv = KVCacheConfig(num_blocks=4, block_size=4, max_blocks_per_seq=8)
    rec = TraceRecorder(now_fn=lambda: 0.0)
    sched = ContinuousScheduler(2, kv, BlockAllocator(kv), trace=rec)
    req = ServeRequest(rid=1, prompt=np.zeros(16, np.int32),
                       max_new_tokens=4, arrival_time=0.0)
    with pytest.raises(ValueError, match="KV blocks"):
        sched.submit(req)
    rejects = [e for e in rec.events if e.name == "reject"]
    assert len(rejects) == 1 and rejects[0].rid == 1
    assert "KV blocks" in rejects[0].fields["reason"]
    assert not sched.waiting                # rejected, not queued


# -------------------------------------------------------------- engine e2e
@pytest.fixture(scope="module")
def tiny_lm():
    cfg = get_config("qwen3-1.7b").reduced(n_layers=2, d_model=64, d_ff=128,
                                           vocab=97)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(model, params, *, chunk_tokens=8, chunk_segments=4,
            num_blocks=None, max_slots=4, now_fn=None, trace=None,
            max_new=10, prefix_sharing=False):
    return ContinuousEngine(
        model, params, single_device_mesh(), DEFAULT_RULES,
        RuntimeConfig(max_slots=max_slots, block_size=8, max_blocks_per_seq=6,
                      num_blocks=num_blocks, max_new_tokens=max_new,
                      chunk_tokens=chunk_tokens,
                      chunk_segments=chunk_segments,
                      prefix_sharing=prefix_sharing),
        now_fn=now_fn, trace=trace)


def _replay(model, params, arrivals, prompts, budgets, *, trace=None,
            num_blocks=None, max_slots=3, chunk_tokens=6,
            prefix_sharing=False):
    """Drive a Poisson workload under the deterministic virtual clock the
    differential fuzz uses; returns (engine, {rid: tokens})."""
    clock = {"t": 0.0}
    eng = _engine(model, params, chunk_tokens=chunk_tokens,
                  num_blocks=num_blocks, max_slots=max_slots,
                  now_fn=lambda: clock["t"], trace=trace,
                  prefix_sharing=prefix_sharing)
    for a, p, b in zip(arrivals, prompts, budgets):
        eng.submit(p, max_new_tokens=b, arrival_time=float(a))
    eng.metrics.start_time = 0.0
    with eng.mesh:
        while eng.scheduler.has_work:
            ran = eng.step()
            clock["t"] += 0.2 if ran else 0.05
    eng.metrics.end_time = clock["t"]
    return eng, {r.rid: r.output for r in eng._done}


def _workload(cfg, seed, n=8, max_prompt=20):
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(0.2, size=n))
    prompts = [rng.integers(0, cfg.vocab,
                            size=int(rng.integers(3, max_prompt)))
               .astype(np.int32) for _ in range(n)]
    budgets = [int(rng.integers(2, 10)) for _ in range(n)]
    return arrivals, prompts, budgets


def test_traced_replay_passes_audit_and_roundtrips(tiny_lm, tmp_path):
    cfg, model, params = tiny_lm
    arrivals, prompts, budgets = _workload(cfg, seed=0)
    rec = TraceRecorder()
    eng, outs = _replay(model, params, arrivals, prompts, budgets, trace=rec)
    assert len(outs) == len(prompts)
    # recorder stamped events on the ENGINE's virtual clock, in order
    assert rec.now_fn is eng.now_fn
    ts = [e.t for e in rec.events]
    assert ts == sorted(ts) and ts[-1] > 0.0
    names = {e.name for e in rec.events}
    assert {"submit", "admit", "chunk_scheduled", "chunk_committed",
            "first_token", "decode_token", "finish", "block_alloc",
            "block_free", "step_begin", "step_end", "compile"} <= names
    assert names <= EVENT_TYPES

    report = traceview.audit(rec.events, metrics=eng.metrics,
                             metadata={"usable_blocks":
                                       eng.kv_cfg.num_blocks - 1})
    assert report.ok, report.summary()
    assert report.checks["requests"] == len(prompts)
    assert report.checks["unified_steps"] == eng.metrics.chunk_steps
    assert report.checks["decode_only_steps"] \
        == eng.metrics.decode_only_steps

    # every lifecycle's event-derived phases tile its latency
    for x in report.lifecycles.values():
        parts = x.queued_s + x.prefill_s + x.stall_s + x.decode_s
        assert not math.isnan(parts)
        assert abs(parts - x.latency_s) < 1e-9
    table = traceview.format_attribution(report.lifecycles)
    assert len(table.splitlines()) == len(prompts) + 1   # header + rows

    # file round-trip: one Perfetto-openable JSON carrying the raw stream
    path = tmp_path / "trace.json"
    write_trace(str(path), rec.events, metrics=eng.metrics,
                metadata={"usable_blocks": eng.kv_cfg.num_blocks - 1})
    payload = json.loads(path.read_text())
    assert isinstance(payload["traceEvents"], list) and payload["traceEvents"]
    events2, metrics2, metadata2 = load_trace(str(path))
    assert [e.to_dict() for e in events2] \
        == [e.to_dict() for e in rec.events]
    assert metrics2 == metrics_snapshot(eng.metrics)
    report2 = traceview.audit(events2, metrics=metrics2, metadata=metadata2)
    assert report2.ok, report2.summary()
    # the audit CLI agrees, end to end
    assert traceview.main([str(path), "--quiet"]) == 0


def test_traced_preemption_replay_passes_audit(tiny_lm):
    """Pool pressure layered on chunking: the swap path emits its events
    (preempt / swap_out / swap_in / resume), stall recomputed from
    preempt->resume-admit intervals matches stall_s, and the pool replay
    conserves across the swap traffic."""
    cfg, model, params = tiny_lm
    arrivals, prompts, budgets = _workload(cfg, seed=1, n=10, max_prompt=28)
    rec = TraceRecorder()
    eng, outs = _replay(model, params, arrivals, prompts, budgets,
                        trace=rec, num_blocks=8)
    assert eng.metrics.preemptions >= 1
    names = [e.name for e in rec.events]
    for needed in ("preempt", "swap_out", "swap_in", "resume"):
        assert needed in names
    report = traceview.audit(rec.events, metrics=eng.metrics,
                             metadata={"usable_blocks":
                                       eng.kv_cfg.num_blocks - 1})
    assert report.ok, report.summary()
    stalled = [x for x in report.lifecycles.values() if x.stalls]
    assert stalled and all(x.stall_s > 0 for x in stalled)


def test_audit_bites_on_corrupted_traces(tiny_lm):
    """The audit must FAIL loudly when the trace and the metrics disagree —
    otherwise the CI step is theater."""
    cfg, model, params = tiny_lm
    arrivals, prompts, budgets = _workload(cfg, seed=2, n=6)
    rec = TraceRecorder()
    eng, _ = _replay(model, params, arrivals, prompts, budgets, trace=rec)
    meta = {"usable_blocks": eng.kv_cfg.num_blocks - 1}
    assert traceview.audit(rec.events, eng.metrics, meta).ok

    # (a) an admitted request that never terminates
    dropped = [e for e in rec.events
               if not (e.name == "finish" and e.rid == 1)]
    r = traceview.audit(dropped, eng.metrics, meta)
    assert not r.ok and any("terminal" in v for v in r.violations)

    # (b) forged pool accounting
    forged = [TraceEvent(e.name, e.t, e.rid, dict(e.fields))
              for e in rec.events]
    for e in forged:
        if e.name == "block_alloc":
            e.fields["free_after"] += 1
            break
    r = traceview.audit(forged, eng.metrics, meta)
    assert not r.ok and any("free_after" in v for v in r.violations)

    # (c) inflated aggregate metrics
    snap = metrics_snapshot(eng.metrics)
    snap["tokens_out"] += 5
    r = traceview.audit(rec.events, snap, meta)
    assert not r.ok and any("tokens_out" in v for v in r.violations)


def _prefix_workload(cfg, rng):
    """One registrant carrying a 16-token (two full blocks) system prompt,
    one exact duplicate arriving while the registrant still holds its
    blocks (claim-time CoW on the last shared block), then late adopters —
    the sequencing tests/test_prefix_sharing.py verified end to end."""
    system = rng.integers(0, cfg.vocab, size=16).astype(np.int32)
    prompts = [np.concatenate(
        [system, rng.integers(0, cfg.vocab, size=6).astype(np.int32)]),
        system.copy()]
    prompts += [np.concatenate(
        [system, rng.integers(0, cfg.vocab,
                              size=int(rng.integers(2, 8))).astype(np.int32)])
        for _ in range(4)]
    arrivals = [0.0, 0.7] + [1.8 + 0.1 * i for i in range(4)]
    budgets = [6] + [int(rng.integers(2, 8)) for _ in range(5)]
    return arrivals, prompts, budgets


def test_traced_prefix_sharing_replay_emits_pool_events_and_passes_audit(
        tiny_lm):
    """A sharing-on replay emits the refcount taxonomy — `block_share` on
    index adoption, `cow_copy` when a write lands in a co-owned block —
    and the refcount-aware pool replay conserves through shares, copies,
    revivals and partial frees, with the cow_copies metric cross-checked
    against the events."""
    cfg, model, params = tiny_lm
    arrivals, prompts, budgets = _prefix_workload(
        cfg, np.random.default_rng(6))
    rec = TraceRecorder()
    eng, _ = _replay(model, params, arrivals, prompts, budgets, trace=rec,
                     prefix_sharing=True)
    assert eng.metrics.prefix_hit_tokens > 0
    assert eng.metrics.cow_copies >= 1
    names = [e.name for e in rec.events]
    assert "block_share" in names and "cow_copy" in names
    shares = [e for e in rec.events if e.name == "block_share"]
    assert all({"n", "revived", "free_after"} <= set(e.fields)
               for e in shares)
    report = traceview.audit(rec.events, metrics=eng.metrics,
                             metadata={"usable_blocks":
                                       eng.kv_cfg.num_blocks - 1,
                                       "block_size":
                                       eng.kv_cfg.block_size})
    assert report.ok, report.summary()


def test_audit_bites_on_a_forged_share(tiny_lm):
    """Refcount semantics make shares auditable: a forged `block_share`
    (claiming one more free-list revival than happened) keeps its OWN
    free_after arithmetic consistent but breaks the pool chain for every
    later event — the audit must flag it.  Inflating the cow_copies
    aggregate against the recorded cow_copy events must also fail."""
    cfg, model, params = tiny_lm
    arrivals, prompts, budgets = _prefix_workload(
        cfg, np.random.default_rng(6))
    rec = TraceRecorder()
    eng, _ = _replay(model, params, arrivals, prompts, budgets, trace=rec,
                     prefix_sharing=True)
    meta = {"usable_blocks": eng.kv_cfg.num_blocks - 1,
            "block_size": eng.kv_cfg.block_size}
    assert traceview.audit(rec.events, eng.metrics, meta).ok

    forged = [TraceEvent(e.name, e.t, e.rid, dict(e.fields))
              for e in rec.events]
    for e in forged:
        if e.name == "block_share":
            e.fields["revived"] += 1
            e.fields["free_after"] -= 1       # self-consistent forgery
            break
    r = traceview.audit(forged, eng.metrics, meta)
    assert not r.ok and any("free_after" in v or "revived" in v
                            for v in r.violations)

    snap = metrics_snapshot(eng.metrics)
    snap["cow_copies"] += 1
    r = traceview.audit(rec.events, snap, meta)
    assert not r.ok and any("cow" in v for v in r.violations)


def test_tracing_is_invisible_to_tokens_and_compiles(tiny_lm):
    """Tracing must not perturb serving: a traced engine and an untraced
    one emit byte-identical greedy streams, and each still owns exactly
    two step executables compiled exactly once."""
    cfg, model, params = tiny_lm
    arrivals, prompts, budgets = _workload(cfg, seed=3)
    eng_off, out_off = _replay(model, params, arrivals, prompts, budgets)
    rec = TraceRecorder()
    eng_on, out_on = _replay(model, params, arrivals, prompts, budgets,
                             trace=rec)
    assert out_on == out_off
    for eng in (eng_off, eng_on):
        assert eng._unified._cache_size() == 1
        assert eng._decode_only._cache_size() == 1
    assert len(rec) > 0
    assert isinstance(eng_off.trace, type(NULL_RECORDER))
    # the traced engine saw its compiles as events too
    compiled = {e.fields["program"] for e in rec.events
                if e.name == "compile"}
    assert {"unified", "decode_only"} <= compiled


def test_chrome_export_track_structure(tiny_lm):
    cfg, model, params = tiny_lm
    arrivals, prompts, budgets = _workload(cfg, seed=4, n=4)
    rec = TraceRecorder()
    _replay(model, params, arrivals, prompts, budgets, trace=rec)
    chrome = to_chrome_trace(rec.events)
    json.dumps(chrome)                      # serializable
    pids = {e["pid"] for e in chrome}
    assert pids == {1, 2, 3}                # requests / scheduler / pool
    spans = {e["name"] for e in chrome if e["ph"] == "X"}
    assert {"queued", "prefill", "decode"} <= spans
    assert any(n.startswith("step:") for n in spans)
    assert all(e["dur"] >= 0.0 for e in chrome if e["ph"] == "X")
    assert any(e["ph"] == "C" and e["name"] == "free_blocks" for e in chrome)
    # timestamps are rebased: the earliest event opens at ts=0
    assert min(e["ts"] for e in chrome if "ts" in e) == 0.0
    assert to_chrome_trace([]) == []


# ------------------------------------------------------------- slow replay
@pytest.mark.slow
def test_traced_poisson_fuzz_audit(tiny_lm, tmp_path):
    """Acceptance: seeded Poisson workloads exercising chunking, packing
    and pool-pressure preemption, replayed with tracing ON — the full
    audit passes on every seed (event-recomputed TTFT / latency / stall
    match ServeMetrics, every admit terminal, pool conserves) and the
    written file is valid Chrome-trace JSON, while the traced streams stay
    byte-identical to untraced ones."""
    cfg, model, params = tiny_lm
    for seed in range(3):
        arrivals, prompts, budgets = _workload(cfg, seed=seed, n=12,
                                               max_prompt=28)
        rec = TraceRecorder()
        eng, out_t = _replay(model, params, arrivals, prompts, budgets,
                             trace=rec, num_blocks=8)
        _, out_u = _replay(model, params, arrivals, prompts, budgets,
                           num_blocks=8)
        assert out_t == out_u, f"traced stream diverged (seed {seed})"
        assert eng.metrics.preemptions >= 1, f"no preemption (seed {seed})"
        assert eng.metrics.packed_segments > 0, f"no packing (seed {seed})"
        assert eng.metrics.decode_only_steps > 0, seed
        meta = {"usable_blocks": eng.kv_cfg.num_blocks - 1, "seed": seed}
        report = traceview.audit(rec.events, metrics=eng.metrics,
                                 metadata=meta)
        assert report.ok, f"seed {seed}: {report.summary()}"
        path = tmp_path / f"trace_{seed}.json"
        write_trace(str(path), rec.events, metrics=eng.metrics,
                    metadata=meta)
        payload = json.loads(path.read_text())
        assert payload["traceEvents"]
        assert traceview.main([str(path), "--quiet"]) == 0
