"""Fault tolerance: checkpoint atomicity/roundtrip/GC, trainer resume
equivalence, preemption handling, data-pipeline determinism + sharding."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step
from repro.configs import get_config
from repro.data import DataConfig, SyntheticLMData
from repro.distributed.sharding import DEFAULT_RULES
from repro.launch.mesh import single_device_mesh
from repro.launch.steps import TrainConfig
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainerConfig


# ------------------------------------------------------------------- data
def test_data_pipeline_deterministic():
    d = SyntheticLMData(DataConfig(vocab=101, seq_len=8, global_batch=4))
    a, b = d.batch(3), d.batch(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = d.batch(4)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_pipeline_sharding_equals_global_slice():
    d = SyntheticLMData(DataConfig(vocab=101, seq_len=8, global_batch=8))
    full = d.batch(5)
    parts = [d.batch(5, shard=i, n_shards=4) for i in range(4)]
    np.testing.assert_array_equal(
        np.concatenate([p["tokens"] for p in parts]), full["tokens"])


def test_data_labels_learnable_structure():
    d = SyntheticLMData(DataConfig(vocab=101, seq_len=8, global_batch=2))
    b = d.batch(0)
    np.testing.assert_array_equal((b["tokens"] + 17) % 101, b["labels"])


# -------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"params": {"w": np.arange(6, dtype=np.float32).reshape(2, 3)},
             "opt": {"step": np.int32(7)}}
    for s in (10, 20, 30):
        mgr.save(s, state)
    assert latest_step(str(tmp_path)) == 30
    assert not os.path.exists(tmp_path / "step_10")   # GC'd
    step, restored = mgr.restore_latest(state)
    assert step == 30
    np.testing.assert_array_equal(restored["params"]["w"], state["params"]["w"])


def test_checkpoint_atomic_no_partial_commit(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    # a stale .tmp dir (simulated crash) must not count as a checkpoint
    os.makedirs(tmp_path / "step_99.tmp")
    assert latest_step(str(tmp_path)) is None
    mgr.save(5, {"x": np.ones(3)})
    assert latest_step(str(tmp_path)) == 5


def test_checkpoint_async_then_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save_async(1, {"x": np.ones(4)})
    mgr.wait()
    assert latest_step(str(tmp_path)) == 1


def test_checkpoint_reshard_on_restore(tmp_path):
    """Restore with an explicit target sharding (elastic restart seam)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(str(tmp_path))
    state = {"w": np.arange(8, dtype=np.float32)}
    mgr.save(1, state)
    mesh = single_device_mesh()
    sh = {"w": NamedSharding(mesh, P("data"))}
    restored = mgr.restore(1, state, shardings=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]), state["w"])


# ---------------------------------------------------------------- trainer
def _mk_trainer(tmp_path, steps, ckpt_every=4, seq=16, batch=4, total_steps=None):
    cfg = get_config("qwen3-1.7b").reduced(n_layers=1, d_model=64, d_ff=128,
                                           vocab=101, n_heads=2, n_kv_heads=2,
                                           head_dim=32)
    model = build_model(cfg)
    data = SyntheticLMData(DataConfig(vocab=cfg.vocab, seq_len=seq,
                                      global_batch=batch))
    tc = TrainerConfig(
        steps=steps, ckpt_every=ckpt_every, ckpt_dir=str(tmp_path),
        log_every=1000,
        train=TrainConfig(microbatches=1, zero1=False,
                          opt=AdamWConfig(lr=1e-3, warmup_steps=2,
                                          total_steps=total_steps or steps)))
    return Trainer(model, single_device_mesh(), DEFAULT_RULES, data, tc)


def test_trainer_loss_decreases(tmp_path):
    tr = _mk_trainer(tmp_path, steps=12)
    step, state, info = tr.run()
    assert step == 12
    losses = [m["loss"] for m in tr.metrics_log]
    assert losses[-1] < losses[0]
    assert not info["preempted"]


def test_trainer_resume_bitwise_equivalent(tmp_path):
    """Kill-and-resume must reproduce the uninterrupted run exactly:
    train 8 straight vs train 4 (ckpt) + fresh trainer resume 4."""
    t_full = _mk_trainer(tmp_path / "a", steps=8, ckpt_every=100)
    _, state_full, _ = t_full.run()

    t1 = _mk_trainer(tmp_path / "b", steps=4, ckpt_every=4, total_steps=8)
    t1.run()
    assert latest_step(str(tmp_path / "b")) == 4
    # simulate a NEW process: fresh trainer, auto-resume from the checkpoint
    t2 = _mk_trainer(tmp_path / "b", steps=8, ckpt_every=100)
    step0, state = t2.restore_or_init()
    assert step0 == 4
    _, state_resumed, _ = t2.run(start_step=step0, state=state)

    full_leaves = jax.tree.leaves(state_full["params"])
    res_leaves = jax.tree.leaves(state_resumed["params"])
    for a, b in zip(full_leaves, res_leaves):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-6, atol=1e-6)


def test_trainer_preemption_checkpoint_and_exit(tmp_path):
    tr = _mk_trainer(tmp_path, steps=50, ckpt_every=100)

    def trip_preemption(step, row):
        if step == 3:
            open(tr.preempt_file, "w").close()

    step, _, info = tr.run(on_step=trip_preemption)
    assert info["preempted"] and step == 3
    assert latest_step(str(tmp_path)) == 3
    meta = tr.ckpt.meta(3)
    assert meta["preempted"] is True


def test_trainer_records_stragglers(tmp_path):
    tr = _mk_trainer(tmp_path, steps=10)
    import time as _t
    orig = tr._step_fn

    def slow_step(p, o, b, _n=[0]):
        _n[0] += 1
        if _n[0] == 7:
            # injected straggler: sleep well past 3x the rolling median even
            # under CPU contention from parallel jobs
            _t.sleep(max(5.0, 4.0 * float(np.median(tr.step_times[-8:]))))
        return orig(p, o, b)

    tr._step_fn = slow_step
    tr.run()
    assert 6 in tr.stragglers or 7 in tr.stragglers
