"""WPK end-to-end: graph -> optimize -> search/selection -> engine, verified
against the unoptimized reference — including the paper's ResNet-18."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    Engine,
    Graph,
    InferencePlan,
    Tuner,
    default_registry,
    optimize_graph,
    select,
)
from repro.core.selection import op_desc_of
from repro.models.resnet import conv_groups, resnet18_graph


@pytest.fixture(scope="module")
def mini_convnet():
    rng = np.random.default_rng(0)
    g = Graph("mini")
    x = g.add_input("x", (2, 3, 16, 16))
    w1 = g.add_constant("w1", rng.standard_normal((8, 3, 3, 3)).astype(np.float32) * 0.2)
    c1 = g.add_node("conv2d", [x, w1], (2, 8, 16, 16), {"stride": 1, "padding": "SAME"})
    sc = g.add_constant("sc", (rng.random(8) + 0.5).astype(np.float32))
    sh = g.add_constant("sh", rng.standard_normal(8).astype(np.float32) * 0.1)
    b1 = g.add_node("batch_norm", [c1, sc, sh], (2, 8, 16, 16))
    r1 = g.add_node("relu", [b1], (2, 8, 16, 16))
    gp = g.add_node("global_avg_pool", [r1], (2, 8))
    wf = g.add_constant("wf", rng.standard_normal((8, 10)).astype(np.float32) * 0.3)
    mm = g.add_node("matmul", [gp, wf], (2, 10))
    g.set_outputs([mm])
    return g


def test_full_wpk_pipeline_equivalence(mini_convnet):
    g = mini_convnet
    gopt = optimize_graph(g)
    plan = select(gopt, tuner=Tuner(methods=("genetic",)), dtype="float32")
    eng = Engine(gopt, plan, default_registry(interpret=True))
    x = jnp.asarray(np.random.default_rng(1).standard_normal((2, 3, 16, 16)).astype(np.float32))
    err = eng.verify_against_reference(x)
    ref = Engine(g, None, None)(x)[0]
    np.testing.assert_allclose(np.asarray(eng(x)[0]), np.asarray(ref),
                               rtol=2e-2, atol=2e-2)
    assert err < 1e-2


def test_plan_has_all_tunable_ops_and_candidates(mini_convnet):
    gopt = optimize_graph(mini_convnet)
    plan = select(gopt, tuner=Tuner(methods=("genetic",)))
    tunable = [n for n in gopt.nodes
               if n.op in ("fused_conv2d", "conv2d", "matmul", "fused_matmul")]
    assert len(plan.choices) == len(tunable)
    for choice in plan.choices.values():
        assert "xla" in choice.candidates          # vendor lane always raced
        assert choice.modeled_time_s == min(choice.candidates.values())


def test_plan_serialisation_roundtrip(mini_convnet, tmp_path):
    gopt = optimize_graph(mini_convnet)
    plan = select(gopt, tuner=Tuner(methods=("genetic",)))
    p = tmp_path / "plan.json"
    plan.save(str(p))
    plan2 = InferencePlan.load(str(p))
    assert plan2.backend_histogram() == plan.backend_histogram()
    assert abs(plan2.total_modeled_time_s() - plan.total_modeled_time_s()) < 1e-12


def test_third_party_ablation_never_faster():
    """Paper §3.4: excluding third-party (vendor) ops costs a little; the
    full system-level plan is by construction <= the WPK-only plan."""
    g = resnet18_graph(batch=1, image=32)
    gopt = optimize_graph(g)
    cache_tuner = Tuner(methods=("genetic",))
    full = select(gopt, tuner=cache_tuner, third_party=True)
    wpk_only = select(gopt, tuner=cache_tuner, third_party=False)
    assert full.total_modeled_time_s() <= wpk_only.total_modeled_time_s() + 1e-12


def test_resnet18_optimized_graph_equivalence():
    g = resnet18_graph(batch=1, image=32)
    gopt = optimize_graph(g)
    x = jnp.asarray(np.random.default_rng(2).standard_normal((1, 3, 32, 32)).astype(np.float32))
    ref = Engine(g, None, None)(x)[0]
    got = Engine(gopt, None, None)(x)[0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-3, atol=2e-3)
    # fusion must actually collapse conv+bn(+relu): no bare batch_norm left
    assert "batch_norm" not in gopt.op_histogram()


def test_resnet18_conv_groups_match_paper_criterion():
    groups = conv_groups()
    sigs = [op.signature() for _, op in groups]
    assert len(sigs) == len(set(sigs))           # deduplicated
    assert 10 <= len(groups) <= 13               # ResNet-18 has ~11 groups
    # the stem conv is the first group
    assert dict(groups[0][1].dims)["cin"] == 3


def test_op_desc_of_handles_all_tunables(mini_convnet):
    gopt = optimize_graph(mini_convnet)
    kinds = set()
    for node in gopt.nodes:
        d = op_desc_of(gopt, node)
        if d is not None:
            kinds.add(d.kind)
    assert "conv2d" in kinds and "matmul" in kinds
