"""Property-based `SlotAllocator` invariants (hypothesis): the slot-pooled
state cache (repro.serve.statecache) is the fixed-size rendering of the
paged `BlockAllocator`, and it keeps the same discipline under adversarial
search — under arbitrary interleavings of allocate / free / swap_out /
swap_in the allocator must keep `free + used == usable`, never hand a row
to two owners, never leak the null row, fail loudly on double-free and on
re-allocating a swapped-out request, and stay resumable when a swap-in
finds the pool dry.  `check_invariants()` runs after EVERY operation.

Mirror of `test_kv_alloc_properties.py` (the paged pool's suite); the same
CI profile applies (HYPOTHESIS_PROFILE=ci, registered in conftest.py).
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.serve.statecache import (
    NULL_SLOT,
    SlotAllocator,
    SlotStateCache,
    StateCacheConfig,
)


def run_op_sequence(cfg: StateCacheConfig, ops) -> SlotAllocator:
    """Interpret (kind, x) pairs against a fresh allocator, asserting the
    full invariant set after every operation.  `x` is folded into whatever
    range the chosen operation needs, so any integer sequence is a valid
    program — hypothesis shrinks freely."""
    alloc = SlotAllocator(cfg)
    live, swapped = [], []
    next_rid = 1

    def check():
        alloc.check_invariants()
        assert alloc.num_free + alloc.num_used == cfg.usable
        assert sorted(alloc.owners) == sorted(live)
        assert sorted(alloc.swapped) == sorted(swapped)
        assert 0.0 <= alloc.occupancy() <= 1.0

    for kind, x in ops:
        kind = kind % 4
        if kind == 0:                                   # allocate
            rid = next_rid
            next_rid += 1
            if alloc.num_free == 0:                     # pool exhausted
                with pytest.raises(MemoryError):
                    alloc.allocate(rid)
            else:
                row = alloc.allocate(rid)
                assert row != NULL_SLOT
                assert alloc.slot_of(rid) == row and alloc.holds(rid)
                with pytest.raises(ValueError):
                    alloc.allocate(rid)                 # one row per request
                live.append(rid)
        elif kind == 1 and live:                        # free (+ double-free)
            rid = live.pop(x % len(live))
            assert alloc.free(rid) == 1
            with pytest.raises(KeyError):
                alloc.free(rid)                         # idempotent-by-error
        elif kind == 2 and live:                        # swap_out
            rid = live.pop(x % len(live))
            free_before = alloc.num_free
            assert alloc.swap_out(rid) == 1
            assert alloc.num_free == free_before + 1
            assert alloc.swapped[rid] == 1
            with pytest.raises(ValueError):
                alloc.allocate(rid)       # swapped rid resumes, never reallocs
            swapped.append(rid)
        elif kind == 3 and swapped:                     # swap_in
            rid = swapped[x % len(swapped)]
            if alloc.num_free == 0:
                with pytest.raises(MemoryError):
                    alloc.swap_in(rid)
                assert alloc.swapped[rid] == 1          # still resumable
            else:
                row = alloc.swap_in(rid)
                assert row != NULL_SLOT
                swapped.remove(rid)
                live.append(rid)
        check()

    return alloc


ops_strategy = st.lists(
    st.tuples(st.integers(0, 3), st.integers(0, 1 << 16)), max_size=150)


@given(num_slots=st.integers(2, 24), ops=ops_strategy)
@settings(deadline=None)
def test_slot_allocator_invariants_under_random_ops(num_slots, ops):
    run_op_sequence(StateCacheConfig(num_slots=num_slots), ops)


@given(ops=ops_strategy)
@settings(deadline=None)
def test_slot_allocator_drains_back_to_full_pool(ops):
    """After any program, releasing every survivor restores the exact free
    pool — no row is ever lost or duplicated across swap round-trips."""
    cfg = StateCacheConfig(num_slots=9)
    alloc = run_op_sequence(cfg, ops)
    for rid in list(alloc.owners):
        alloc.free(rid)
    for rid in list(alloc.swapped):
        del alloc.swapped[rid]
    alloc.check_invariants()
    assert alloc.num_free == cfg.usable
    assert alloc.num_used == 0


# ------------------------------------------------- device-pool round trips
def test_state_cache_swap_round_trip_preserves_bytes():
    """swap_out copies the owner's rows to host buffers byte-for-byte and
    reports the bytes moved; take_swapped hands back those exact arrays."""
    cache = SlotStateCache(StateCacheConfig(num_slots=3), n_layers=2,
                           conv_width=4, conv_dim=3, nheads=2, head_dim=2,
                           d_state=5)
    row = cache.alloc.allocate(7)
    conv_val = np.arange(2 * 3 * 3, dtype=np.float32).reshape(2, 3, 3)
    ssm_val = np.arange(2 * 2 * 2 * 5, dtype=np.float32).reshape(2, 2, 2, 5)
    cache.conv = cache.conv.at[:, row].set(conv_val)
    cache.ssm = cache.ssm.at[:, row].set(ssm_val)

    nbytes = cache.swap_out(7)
    assert nbytes == conv_val.nbytes + ssm_val.nbytes
    assert cache.is_swapped(7) and not cache.alloc.holds(7)
    conv_host, ssm_host = cache.take_swapped(7)
    np.testing.assert_array_equal(conv_host, conv_val)
    np.testing.assert_array_equal(ssm_host, ssm_val)
    assert not cache.is_swapped(7)


def test_index_array_points_absent_requests_at_null_row():
    cache = SlotStateCache(StateCacheConfig(num_slots=4), n_layers=1,
                           conv_width=4, conv_dim=2, nheads=1, head_dim=2,
                           d_state=2)
    r1 = cache.alloc.allocate(1)
    r2 = cache.alloc.allocate(2)
    idx = cache.index_array([2, None, 1, 99])
    assert idx.dtype == np.int32
    assert list(idx) == [r2, NULL_SLOT, r1, NULL_SLOT]
