"""Per-architecture smoke tests (deliverable f): every assigned architecture
instantiates a REDUCED config of its own family and runs one forward + one
train step on CPU, asserting output shapes and no NaNs; the serving path
(prefill + decode) must agree with the forward pass."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.models import build_model


def _batch_for(cfg, B=2, S=16):
    batch = {
        "tokens": jnp.asarray(np.arange(B * S).reshape(B, S) % min(cfg.vocab, 97),
                              jnp.int32),
        "labels": jnp.asarray((np.arange(B * S).reshape(B, S) + 1)
                              % min(cfg.vocab, 97), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.full((B, cfg.n_vision_tokens, cfg.d_model),
                                          0.02, jnp.float32)
    if cfg.family == "encdec":
        batch["audio_embeds"] = jnp.full((B, cfg.enc_seq, cfg.d_model),
                                         0.02, jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch_for(cfg, B, S)

    logits = model.forward(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert not np.isnan(np.asarray(logits, np.float32)).any(), "NaN logits"

    # one SGD-ish step: loss and grads must be finite, params must move
    loss_fn = lambda p: model.loss(p, batch)[0]
    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    gnorm = np.sqrt(sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                        for g in jax.tree.leaves(grads)))
    assert np.isfinite(gnorm) and gnorm > 0
    new_params = jax.tree.map(lambda p, g: p - 1e-3 * g.astype(p.dtype),
                              params, grads)
    loss2 = float(loss_fn(new_params))
    assert np.isfinite(loss2)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_smoke_serving_consistency(arch):
    """prefill's last-token logits == forward's last-token logits, and a
    decode step runs with finite outputs."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 2, 16
    batch = _batch_for(cfg, B, S)

    logits = model.forward(params, batch)
    lp, cache = model.prefill(params, batch, max_seq=S + 8)
    np.testing.assert_allclose(
        np.asarray(lp[:, 0], np.float32), np.asarray(logits[:, -1], np.float32),
        rtol=3e-2, atol=3e-2)

    ld, cache2 = model.decode_step(params, cache, jnp.ones((B, 1), jnp.int32))
    assert ld.shape == (B, 1, cfg.vocab)
    assert not np.isnan(np.asarray(ld, np.float32)).any()
    assert int(cache2["lengths"][0]) == S + 1


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_config_matches_assignment(arch):
    """The full (published) config numbers survive in the registry."""
    cfg = get_config(arch)
    expected = {
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
        "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "mamba2-2.7b": (64, 2560, 0, 0, 0, 50280),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expected


def test_moe_specifics():
    q3 = get_config("qwen3-moe-235b-a22b")
    assert (q3.n_experts, q3.top_k, q3.n_shared_experts) == (128, 8, 0)
    q2 = get_config("qwen2-moe-a2.7b")
    assert (q2.n_experts, q2.top_k, q2.n_shared_experts) == (60, 4, 4)


def test_ssm_specifics():
    m = get_config("mamba2-2.7b")
    assert m.ssm_state == 128 and m.family == "ssm" and m.sub_quadratic
    z = get_config("zamba2-1.2b")
    assert z.ssm_state == 64 and z.attn_every == 6 and z.sub_quadratic


def test_decode_greedy_continuation_changes_with_prompt():
    """Decode must actually condition on the cache (not just the new token)."""
    cfg = get_config("qwen3-1.7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    S = 16
    b1 = {"tokens": jnp.asarray(np.full((1, S), 3), jnp.int32)}
    b2 = {"tokens": jnp.asarray(np.full((1, S), 9), jnp.int32)}
    _, c1 = model.prefill(params, b1, max_seq=S + 4)
    _, c2 = model.prefill(params, b2, max_seq=S + 4)
    tok = jnp.ones((1, 1), jnp.int32)
    l1, _ = model.decode_step(params, c1, tok)
    l2, _ = model.decode_step(params, c2, tok)
    assert not np.allclose(np.asarray(l1, np.float32),
                           np.asarray(l2, np.float32))
