"""Fused per-slot sampling: property pins + sampled differential harness.

The step programs now sample (temperature / top-k / top-p) in-program with
per-slot knobs and per-slot PRNG keys carried as traced data.  These tests
pin the contract from the bottom up:

  * the pure sampler (repro.kernels.sampling): top-k keeps exactly k
    logits, top-p keeps the MINIMAL nucleus, temperature 0 is bitwise
    argmax even with top-k/top-p set, and the key derivation makes a
    token's draw a pure function of (seed, rid, token_index) — independent
    of row position or batch width;
  * the differential harness, extended to sampled streams: a continuous
    run over chunked / packed / preempted schedules must be byte-identical
    to the FixedBatchEngine B=1 drain given the same per-request
    SamplingParams, for BOTH families, still from exactly two compiled
    step executables;
  * the eos/stats bugfixes that block the harness: stop-at-first-eos is
    one shared rule (`truncate_at_eos`) for both engines, tokens_out
    counts tokens actually emitted, and latency is attributed per request;
  * the trace contract: sampled submits carry their seed, finish events
    pin the stream with a digest the audit recomputes from token events.
"""

import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.distributed.sharding import DEFAULT_RULES
from repro.kernels.sampling import derive_key, mask_top_k, mask_top_p
from repro.launch.mesh import single_device_mesh
from repro.models import build_model
from repro.serve import (
    ContinuousEngine,
    FixedBatchEngine,
    RuntimeConfig,
    SamplingParams,
    ServeConfig,
    TraceRecorder,
    truncate_at_eos,
)
from repro.serve import traceview
from repro.serve.sampling import batch_sampling_arrays, sample_host

MAX_NEW = 10


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = get_config("qwen3-1.7b").reduced(n_layers=2, d_model=64, d_ff=128,
                                           vocab=97)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _reference_greedy(model, params, prompt, n_new, max_seq=64):
    toks = jnp.asarray(prompt[None], jnp.int32)
    logits, cache = model.prefill(params, {"tokens": toks}, max_seq=max_seq)
    out = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(n_new - 1):
        nxt = jnp.asarray([[out[-1]]], jnp.int32)
        logits, cache = model.decode_step(params, cache, nxt)
        out.append(int(jnp.argmax(logits[0, -1])))
    return out


def _mix(i):
    """The bench's mixed-sampling cycle: greedy / pure temperature /
    temperature+top-k / temperature+top-p, unique seed per request."""
    r = i % 4
    if r == 0:
        return SamplingParams()
    if r == 1:
        return SamplingParams(temperature=0.8, seed=1000 + i)
    if r == 2:
        return SamplingParams(temperature=1.0, top_k=8, seed=1000 + i)
    return SamplingParams(temperature=0.9, top_p=0.85, seed=1000 + i)


# ---------------------------------------------------------- sampler pins
def test_top_k_keeps_exactly_k_largest():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.permutation(41).astype(np.float32))  # all distinct
    for k in (1, 2, 7, 40, 41):
        kept = np.isfinite(np.asarray(mask_top_k(x, jnp.int32(k))))
        assert kept.sum() == k
        # the kept set IS the k largest
        want = set(np.argsort(np.asarray(x))[-k:].tolist())
        assert set(np.flatnonzero(kept).tolist()) == want
    # k = 0 (off) and k >= vocab keep everything
    for k in (0, 41, 1000):
        kept = np.isfinite(np.asarray(mask_top_k(x, jnp.int32(k))))
        assert kept.sum() == (41 if k == 0 or k >= 41 else k)


def test_top_p_keeps_minimal_nucleus():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=53).astype(np.float32))
    probs = np.asarray(jax.nn.softmax(x))
    for p in (0.05, 0.3, 0.72, 0.95):
        kept = np.isfinite(np.asarray(mask_top_p(x, jnp.float32(p))))
        mass = probs[kept].sum()
        assert mass >= p - 1e-6                      # covers the nucleus
        # minimal: dropping the smallest kept prob dips below p
        smallest = probs[kept].min()
        assert mass - smallest < p
    # p = 1.0 escapes entirely: the logits pass through untouched
    assert np.array_equal(np.asarray(mask_top_p(x, jnp.float32(1.0))),
                          np.asarray(x))


def test_temperature_zero_is_bitwise_argmax_despite_knobs():
    rng = np.random.default_rng(2)
    logits = jnp.asarray(rng.normal(size=(6, 31)).astype(np.float32))
    sp = np.zeros((6, 3), np.float32)
    sp[:, 1] = 3.0                # top_k set — must be ignored at temp 0
    sp[:, 2] = 0.4                # top_p set — must be ignored at temp 0
    ks = np.stack([np.arange(6), np.arange(6), np.arange(6)], 1).astype(
        np.int32)
    out = np.asarray(sample_host(logits, sp, ks))
    assert np.array_equal(out, np.asarray(jnp.argmax(logits, -1),
                                          dtype=np.int32))


def test_key_is_pure_function_of_seed_rid_index():
    """The same (seed, rid, token_index) triple must draw the same token
    wherever its row sits and whatever else shares the batch — this is
    what makes sampled streams invariant to packing and preemption."""
    rng = np.random.default_rng(3)
    row = rng.normal(size=29).astype(np.float32)
    sp_row = np.asarray([0.9, 0.0, 1.0], np.float32)
    ks_row = np.asarray([42, 7, 5], np.int32)

    def at(position, width):
        logits = rng.normal(size=(width, 29)).astype(np.float32)
        logits[position] = row
        sp = np.zeros((width, 3), np.float32)
        sp[:, 0] = 0.7            # other rows sample too, with other keys
        sp[:, 2] = 1.0
        sp[position] = sp_row
        ks = np.stack([np.arange(width)] * 3, 1).astype(np.int32)
        ks[position] = ks_row
        return int(np.asarray(sample_host(jnp.asarray(logits), sp, ks))
                   [position])

    draws = {at(0, 1), at(0, 4), at(3, 4), at(5, 8)}
    assert len(draws) == 1
    # and fold_in keys actually separate: a different triple, same logits
    k1 = derive_key(jnp.int32(42), jnp.int32(7), jnp.int32(5))
    k2 = derive_key(jnp.int32(42), jnp.int32(7), jnp.int32(6))
    assert not np.array_equal(np.asarray(k1), np.asarray(k2))


def test_sampled_tokens_stay_inside_the_truncated_support():
    rng = np.random.default_rng(4)
    row = rng.normal(size=37).astype(np.float32)
    n = 256
    logits = jnp.asarray(np.tile(row, (n, 1)))
    sp = np.zeros((n, 3), np.float32)
    sp[:, 0] = 1.0
    sp[:, 1] = 5.0                              # top_k = 5
    sp[:, 2] = 1.0
    ks = np.zeros((n, 3), np.int32)
    ks[:, 0] = np.arange(n)                     # one seed per row
    out = np.asarray(sample_host(logits, sp, ks))
    top5 = set(np.argsort(row)[-5:].tolist())
    assert set(out.tolist()) <= top5
    assert len(set(out.tolist())) > 1           # it does actually sample


def test_sampling_params_validation_rejected_by_both_engines(tiny_lm):
    assert SamplingParams().invalid_reason() is None
    assert SamplingParams(temperature=-1.0).invalid_reason()
    assert SamplingParams(temperature=float("nan")).invalid_reason()
    assert SamplingParams(top_k=-2).invalid_reason()
    assert SamplingParams(top_p=0.0).invalid_reason()
    assert SamplingParams(top_p=1.5).invalid_reason()
    assert SamplingParams(seed=2**31).invalid_reason()

    cfg, model, params = tiny_lm
    mesh = single_device_mesh()
    bad = SamplingParams(top_p=0.0)
    prompt = np.arange(4, dtype=np.int32)
    fixed = FixedBatchEngine(model, params, mesh, DEFAULT_RULES,
                             ServeConfig(batch_size=1, max_seq=64,
                                         max_new_tokens=2))
    with pytest.raises(ValueError, match="top_p"):
        fixed.submit(prompt, sampling=bad)
    cont = ContinuousEngine(model, params, mesh, DEFAULT_RULES,
                            RuntimeConfig(max_slots=2, block_size=8,
                                          max_blocks_per_seq=8,
                                          max_new_tokens=2))
    with pytest.raises(ValueError, match="top_p"):
        cont.submit(prompt, sampling=bad)


# -------------------------------------------------- sampled differentials
def _decoder_engines(tiny_lm, eos_id=-1, trace=None):
    cfg, model, params = tiny_lm
    mesh = single_device_mesh()
    # the family-seam preemption config: chunked prefill, packed segments,
    # and block pressure that forces at least one preemption
    eng = ContinuousEngine(
        model, params, mesh, DEFAULT_RULES,
        RuntimeConfig(max_slots=3, block_size=4, max_blocks_per_seq=8,
                      num_blocks=10, chunk_tokens=8, chunk_segments=2,
                      max_new_tokens=MAX_NEW, eos_id=eos_id),
        trace=trace)
    fixed = FixedBatchEngine(model, params, mesh, DEFAULT_RULES,
                             ServeConfig(batch_size=1, max_seq=64,
                                         max_new_tokens=MAX_NEW,
                                         eos_id=eos_id))
    return eng, fixed


def test_decoder_sampled_streams_match_fixed_drain(tiny_lm):
    """Same (seed, rid, token_index) triples on both engines: the sampled
    continuous streams must be byte-identical to the B=1 drain across
    chunking, packing and preemption — and at least one stream must
    actually differ from greedy (the sampler is live, not a no-op)."""
    cfg, model, params = tiny_lm
    eng, fixed = _decoder_engines(tiny_lm)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, size=s).astype(np.int32)
               for s in (12, 11, 13, 12)]
    samplings = [_mix(i) for i in range(len(prompts))]

    for p, s in zip(prompts, samplings):
        fixed.submit(p, sampling=s)
    ref = {r.rid: r.output for r in fixed.run()}

    for p, s in zip(prompts, samplings):
        eng.submit(p, sampling=s)
    done = {r.rid: r.output for r in eng.run()}

    assert done == ref
    assert eng.metrics.preemptions >= 1        # the schedule was adversarial
    assert eng._unified._cache_size() == 1     # sampling is traced data:
    assert eng._decode_only._cache_size() == 1  # still two executables
    greedy = {rid: _reference_greedy(model, params, p, MAX_NEW)
              for rid, p in enumerate(prompts, start=1)}
    assert any(done[rid] != greedy[rid] for rid in done)
    # ... while the greedy submits in the mix stayed bitwise greedy
    for rid, s in enumerate(samplings, start=1):
        if s.greedy:
            assert done[rid] == greedy[rid]


def test_explicit_temperature_zero_is_the_greedy_path(tiny_lm):
    """temperature=0 with top-k/top-p set still reduces bitwise to the
    pre-sampling argmax stream (the knobs only bite when sampling)."""
    cfg, model, params = tiny_lm
    eng, _ = _decoder_engines(tiny_lm)
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab, size=s).astype(np.int32)
               for s in (9, 14)]
    for p in prompts:
        eng.submit(p, sampling=SamplingParams(temperature=0.0, top_k=5,
                                              top_p=0.5, seed=77))
    done = {r.rid: r.output for r in eng.run()}
    for rid, p in enumerate(prompts, start=1):
        assert done[rid] == _reference_greedy(model, params, p, MAX_NEW)


def test_ssm_sampled_streams_match_fixed_drain():
    """The same sampled differential for the slot-pooled family, across a
    state pool one row short of the slot count (forced state swap)."""
    cfg = get_config("mamba2-2.7b").reduced(n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mesh = single_device_mesh()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=l).astype(np.int32)
               for l in (5, 16, 32, 7)]
    samplings = [_mix(i + 1) for i in range(len(prompts))]  # all non-greedy
    max_new = 6

    fixed = FixedBatchEngine(model, params, mesh, DEFAULT_RULES,
                             ServeConfig(batch_size=1, max_seq=64,
                                         max_new_tokens=max_new))
    for p, s in zip(prompts, samplings):
        fixed.submit(p, sampling=s)
    ref = {r.rid: r.output for r in fixed.run()}

    eng = ContinuousEngine(model, params, mesh, DEFAULT_RULES,
                           RuntimeConfig(max_slots=3, chunk_tokens=16,
                                         max_new_tokens=max_new,
                                         state_slots=3))
    for p, s in zip(prompts, samplings):
        eng.submit(p, arrival_time=0.0, sampling=s)
    done = {r.rid: r.output for r in eng.run()}

    assert done == ref
    assert eng.metrics.preemptions >= 1
    assert eng._unified._cache_size() == 1
    assert eng._decode_only._cache_size() == 1


# ------------------------------------------------------- eos / stats pins
def test_fixed_batch_eos_truncation_stats_and_latency(tiny_lm):
    """The satellite bugfixes: with an emittable eos, tokens_out counts
    tokens actually emitted (not n * max_new_tokens), latency is
    attributed per request (an early-stopping request reports less than a
    batch mate that drained the full budget), and both engines share
    stop-at-first-eos semantics."""
    cfg, model, params = tiny_lm
    mesh = single_device_mesh()
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, size=s).astype(np.int32)
               for s in (12, 11)]

    # the B=2 drain is its own ground truth (left-padded batched prefill
    # is not bitwise the B=1 stream): run once with eos disabled to learn
    # the full streams, then discover an eos the batch actually emits
    # mid-stream, preferring one that truncates to DIFFERENT lengths
    def b2_engine(eos_id):
        return FixedBatchEngine(model, params, mesh, DEFAULT_RULES,
                                ServeConfig(batch_size=2, max_seq=64,
                                            max_new_tokens=MAX_NEW,
                                            eos_id=eos_id))

    full_eng = b2_engine(eos_id=-1)
    for p in prompts:
        full_eng.submit(p)
    streams = [r.output for r in full_eng.run()]
    assert all(len(s) == MAX_NEW for s in streams)
    assert full_eng.stats["tokens_out"] == 2 * MAX_NEW

    eos, lens = None, None
    for cand in streams[0][:-1]:
        l0 = len(truncate_at_eos(streams[0], cand))
        l1 = len(truncate_at_eos(streams[1], cand))
        if l0 < MAX_NEW or l1 < MAX_NEW:
            eos, lens = cand, (l0, l1)
            if l0 != l1:
                break
    assert eos is not None, "greedy stream never repeats a token?"

    fixed = b2_engine(eos_id=eos)
    for p in prompts:
        fixed.submit(p)
    done = {r.rid: r for r in fixed.run()}

    for rid, (stream, want_len) in enumerate(zip(streams, lens), start=1):
        assert done[rid].output == truncate_at_eos(stream, eos)
        assert len(done[rid].output) == want_len
    # tokens_out counts what was emitted, not the drain budget
    assert fixed.stats["tokens_out"] == sum(lens)
    assert fixed.stats["tokens_out"] < 2 * MAX_NEW
    # latency is per-request: the earlier-stopping batch mate reports less
    if lens[0] != lens[1]:
        shorter = 1 if lens[0] < lens[1] else 2
        longer = 3 - shorter
        assert done[shorter].latency_s < done[longer].latency_s
    for r in done.values():
        assert r.latency_s > 0.0

    # cross-engine eos semantics pin against the B=1-equivalent reference:
    # the continuous engine's greedy streams are byte-identical to the
    # unbatched drain, so the shared stop-at-first-eos rule must land both
    # engines on the same truncation of the same streams
    ref = [_reference_greedy(model, params, p, MAX_NEW) for p in prompts]
    ceos, clens = None, None
    for cand in ref[0][:-1]:
        l0 = len(truncate_at_eos(ref[0], cand))
        l1 = len(truncate_at_eos(ref[1], cand))
        if l0 < MAX_NEW or l1 < MAX_NEW:
            ceos, clens = cand, (l0, l1)
            if l0 != l1:
                break
    assert ceos is not None

    eng, b1 = _decoder_engines(tiny_lm, eos_id=ceos)
    for p in prompts:
        eng.submit(p)
        b1.submit(p)
    cont = {r.rid: r.output for r in eng.run()}
    b1_done = {r.rid: r for r in b1.run()}
    for rid, (stream, want_len) in enumerate(zip(ref, clens), start=1):
        assert cont[rid] == truncate_at_eos(stream, ceos)
        assert b1_done[rid].output == cont[rid]
        assert len(cont[rid]) == want_len
    assert eng.metrics.tokens_out == sum(clens)
    assert b1.stats["tokens_out"] == sum(clens)


def test_eos_anywhere_in_output_finishes_continuous_requests(tiny_lm):
    """_finished now checks the whole stream, not just the last token —
    the structural unification with truncate_at_eos.  (In-engine the two
    were equivalent because _finished runs after every append; this pins
    the shared rule so they can never drift.)"""
    cfg, model, params = tiny_lm
    eng, _ = _decoder_engines(tiny_lm, eos_id=3)

    class _R:
        max_new_tokens = 100
        output = [5, 3, 9]
    assert eng._finished(_R())                   # eos mid-stream finishes
    _R.output = [5, 9]
    assert not eng._finished(_R())


# ----------------------------------------------------------- trace contract
def test_sampled_trace_digest_seed_and_tamper_detection(tiny_lm):
    """A traced sampled run audits clean (finish digests match the token
    events; sampled submits carry seeds) and the audit actually has teeth:
    perturbing one recorded token, or stripping a sampled submit's seed,
    each raise a violation."""
    cfg, model, params = tiny_lm
    rec = TraceRecorder()
    eng, fixed = _decoder_engines(tiny_lm, trace=rec)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, size=s).astype(np.int32)
               for s in (12, 11, 13, 12)]
    samplings = [_mix(i) for i in range(len(prompts))]
    for p, s in zip(prompts, samplings):
        eng.submit(p, sampling=s)
    eng.run()

    report = traceview.audit(
        rec.events, metrics=eng.metrics,
        metadata={"usable_blocks": eng.kv_cfg.num_blocks - 1})
    assert report.ok, report.summary()
    assert report.checks["sampled_requests"] == \
        sum(1 for s in samplings if not s.greedy)
    subs = [e for e in rec.events if e.name == "submit"]
    assert sum("seed" in e.fields for e in subs) == \
        report.checks["sampled_requests"]

    # tamper 1: flip one decode_token's recorded value -> digest violation
    evs = copy.deepcopy(rec.events)
    tok = next(e for e in evs if e.name == "decode_token")
    tok.fields["token"] = (tok.fields["token"] + 1) % cfg.vocab
    bad = traceview.audit(evs)
    assert any("digest" in v for v in bad.violations), bad.summary()

    # tamper 2: strip a sampled submit's seed -> replayability violation
    evs = copy.deepcopy(rec.events)
    sub = next(e for e in evs
               if e.name == "submit" and "seed" in e.fields)
    del sub.fields["seed"]
    bad = traceview.audit(evs)
    assert any("seed" in v for v in bad.violations), bad.summary()
