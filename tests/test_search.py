"""Automated-search tests: genetic (§2.3), RL (§2.4), random baseline,
cache (§3.3), constraint validity (hypothesis property)."""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro import hw
from repro.core import (
    GeneticSearch,
    ModelFitness,
    SearchCache,
    SearchTask,
    TEMPLATES,
    Tuner,
    genetic_search,
    random_search,
    rl_search,
)
from repro.core.costmodel import pallas_time, roofline_bound
from repro.core.schedules import OpDesc

CONV = OpDesc.conv2d(1, 56, 56, 64, 128, 3, 3, stride=2)
MM = OpDesc.matmul(512, 1024, 768)


def test_genetic_beats_or_matches_random_at_equal_budget():
    t1 = SearchTask(CONV, TEMPLATES["pallas_conv2d"], seed=0)
    g = genetic_search(t1)
    t2 = SearchTask(CONV, TEMPLATES["pallas_conv2d"], seed=123)
    r = random_search(t2, budget=g.evals)
    assert g.runtime_s <= r.runtime_s * 1.05


def test_genetic_deterministic_given_seed():
    a = genetic_search(SearchTask(MM, TEMPLATES["pallas_matmul"], seed=7))
    b = genetic_search(SearchTask(MM, TEMPLATES["pallas_matmul"], seed=7))
    assert a.config == b.config and a.runtime_s == b.runtime_s


@pytest.mark.slow
def test_genetic_convergence_and_history_monotone():
    res = genetic_search(SearchTask(MM, TEMPLATES["pallas_matmul"], seed=1))
    hist = res.history
    assert all(b <= a + 1e-12 for a, b in zip(hist, hist[1:]))
    assert res.runtime_s >= roofline_bound(MM) * 0.5  # sane lower bound


def test_population_schedule_varies_size():
    gs = GeneticSearch(population=12, population_schedule=[12, 16, 8],
                       max_generations=3)
    res = gs.run(SearchTask(MM, TEMPLATES["pallas_matmul"], seed=2))
    assert res.runtime_s < float("inf")


@pytest.mark.slow
def test_best_config_beats_median_of_space():
    task = SearchTask(CONV, TEMPLATES["pallas_conv2d"], seed=0)
    res = genetic_search(task)
    rng = np.random.default_rng(0)
    tmpl = TEMPLATES["pallas_conv2d"]
    samples = [pallas_time(CONV, tmpl.random_config(CONV, rng)) for _ in range(50)]
    assert res.runtime_s <= np.median(samples)


@pytest.mark.slow
def test_rl_search_runs_and_improves_over_worst():
    task = SearchTask(CONV, TEMPLATES["pallas_conv2d"], seed=0)
    res = rl_search(task, episodes=2, steps_per_episode=8)
    assert np.isfinite(res.runtime_s)
    assert TEMPLATES["pallas_conv2d"].validate(CONV, res.config)
    assert res.evals > 8


def test_cache_hit_returns_without_evals(tmp_path):
    cache = SearchCache(str(tmp_path / "cache.json"))
    tuner = Tuner(methods=("genetic",), cache=cache)
    r1 = tuner.tune(MM)
    assert cache.misses >= 1
    r2 = tuner.tune(MM)
    assert r2.evals == 0 and "cache" in r2.method
    assert r2.config == r1.config
    cache.save()
    cache2 = SearchCache(str(tmp_path / "cache.json"))
    assert len(cache2) == len(cache)


def test_cache_respects_computational_identity():
    """Paper §3.1: same shapes/filter/stride/padding == identical op."""
    cache = SearchCache()
    op_a = OpDesc.conv2d(1, 28, 28, 128, 128, 3, 3, stride=1)
    op_b = OpDesc.conv2d(1, 28, 28, 128, 128, 3, 3, stride=1)
    op_c = OpDesc.conv2d(1, 28, 28, 128, 128, 3, 3, stride=2)
    cache.put("tpu_v5e", op_a, "pallas_conv2d", {"bm": 8}, 1.0, "genetic")
    assert cache.get("tpu_v5e", op_b, "pallas_conv2d") is not None
    assert cache.get("tpu_v5e", op_c, "pallas_conv2d") is None


def test_retarget_changes_best_config_or_runtime():
    """Hardware-awareness: v5e and v5p must not produce identical tuning."""
    r_e = Tuner(chip=hw.TPU_V5E, methods=("genetic",)).tune(MM)
    r_p = Tuner(chip=hw.TPU_V5P, methods=("genetic",)).tune(MM)
    assert r_e.runtime_s != r_p.runtime_s


# ---------------------------------------------------------------- property
@given(st.integers(0, 2**16),
       st.sampled_from(["pallas_matmul", "pallas_conv2d", "pallas_attention"]))
@settings(max_examples=30, deadline=None)
def test_random_configs_always_valid(seed, tmpl_name):
    """§2.3 Step1: every proposed configuration satisfies the hardware
    constraints (the CUDA <=1024-threads analogue is the VMEM-fit rule)."""
    tmpl = TEMPLATES[tmpl_name]
    op = {"pallas_matmul": MM, "pallas_conv2d": CONV,
          "pallas_attention": OpDesc.attention(2, 1024, 1024, 8, 128)}[tmpl_name]
    rng = np.random.default_rng(seed)
    cfg = tmpl.random_config(op, rng)
    assert tmpl.validate(op, cfg)
    # encode/decode roundtrip
    assert tmpl.decode(op, tmpl.encode(op, cfg)) == cfg


@given(st.integers(0, 2**16))
@settings(max_examples=20, deadline=None)
def test_modeled_time_above_roofline(seed):
    rng = np.random.default_rng(seed)
    tmpl = TEMPLATES["pallas_matmul"]
    cfg = tmpl.random_config(MM, rng)
    assert pallas_time(MM, cfg) >= roofline_bound(MM) * 0.9
