"""Search-cache fitness partitioning (no hypothesis dependency, unlike
test_search.py, so these regressions always run in tier-1): a runtime_s is
only meaningful under the fitness that produced it — model-fitness and
wall-clock entries must never cross-serve."""

from repro.core.schedules import OpDesc
from repro.core.search.cache import SearchCache
from repro.core.search.tuner import Tuner

MM = OpDesc.matmul(512, 1024, 768)


def test_cache_misses_across_fitness_kinds():
    """Regression: an entry tuned under the analytical ModelFitness must NOT
    be served to a wall-clock tuner (its runtime_s is a modeled number, not
    a measurement) — and vice versa."""
    cache = SearchCache()
    cache.put("tpu_v5e", MM, "pallas_matmul", {"bm": 128}, 1e-4, "genetic",
              fitness="model")
    assert cache.get("tpu_v5e", MM, "pallas_matmul", fitness="model") is not None
    assert cache.get("tpu_v5e", MM, "pallas_matmul", fitness="wallclock") is None
    cache.put("tpu_v5e", MM, "pallas_matmul", {"bm": 256}, 2e-3, "genetic",
              fitness="wallclock")
    assert cache.get("tpu_v5e", MM, "pallas_matmul",
                     fitness="wallclock")["config"] == {"bm": 256}
    assert cache.get("tpu_v5e", MM, "pallas_matmul",
                     fitness="model")["config"] == {"bm": 128}


def test_cache_legacy_untagged_entries_served_as_model_fitness():
    """Entries persisted before the fitness tag existed keep hitting for
    model-fitness tuners and stay invisible to wall-clock ones."""
    cache = SearchCache()
    legacy_key = f"tpu_v5e|pallas_matmul|{MM.signature()}"
    cache._store[legacy_key] = {"config": {"bm": 64}, "runtime_s": 1e-4,
                                "method": "genetic"}
    assert cache.get("tpu_v5e", MM, "pallas_matmul",
                     fitness="model")["config"] == {"bm": 64}
    assert cache.get("tpu_v5e", MM, "pallas_matmul", fitness="wallclock") is None


def test_tuner_fitness_kind_partitions_the_cache():
    """A Tuner under wall-clock fitness must not consume (or poison) the
    model-fitness entries for the same op/template."""
    from repro.core.costmodel import WallClockFitness, pallas_time

    cache = SearchCache()
    model_tuner = Tuner(methods=("genetic",), cache=cache)
    r_model = model_tuner.tune(MM)
    assert model_tuner.fitness_kind == "model"

    # a fake wall-clock fitness (kind='wallclock') with detuned timings so a
    # cross-fitness cache hit would be observable as a bogus runtime_s
    class FakeWallClock(WallClockFitness):
        def __init__(self):
            super().__init__(runner=None, repeats=1)

        def __call__(self, op, cfg):
            self.evals += 1
            return 10.0 + pallas_time(op, cfg)

    wall_tuner = Tuner(methods=("genetic",), cache=cache,
                       fitness=FakeWallClock())
    assert wall_tuner.fitness_kind == "wallclock"
    r_wall = wall_tuner.tune(MM)
    assert "cache" not in r_wall.method          # cross-fitness MISS
    assert r_wall.runtime_s >= 10.0              # measured, not modeled
    # both kinds now cached side by side; each tuner hits its own entry
    assert "cache" in model_tuner.tune(MM).method
    hit = wall_tuner.tune(MM)
    assert "cache" in hit.method and hit.runtime_s == r_wall.runtime_s
    assert model_tuner.tune(MM).runtime_s == r_model.runtime_s
