"""Pallas-kernel correctness: shape/dtype sweeps against the pure-jnp
oracles in repro.kernels.ref (interpret mode on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import kernels
from repro.kernels import ref
from repro.kernels.matmul import matmul_padded

TOLS = {np.float32: dict(rtol=2e-4, atol=2e-4),
        jnp.bfloat16: dict(rtol=5e-2, atol=5e-2)}


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 384, 128),
                                   (64, 512, 256), (70, 200, 130), (8, 128, 128)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_matmul_shapes_dtypes(m, k, n, dtype):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.dtype(dtype))
    w = jnp.asarray(rng.standard_normal((k, n)) * 0.1, jnp.dtype(dtype))
    out = kernels.matmul(x, w)
    want = ref.matmul_ref(x, w)
    tol = TOLS[jnp.bfloat16] if dtype == "bfloat16" else TOLS[np.float32]
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol)


@pytest.mark.parametrize("order", ["mn", "nm"])
@pytest.mark.parametrize("act", [None, "relu", "gelu", "silu"])
def test_matmul_fused_epilogue(order, act):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((128, 256)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((256, 128)) * 0.1, jnp.float32)
    b = jnp.asarray(rng.standard_normal((1, 128)), jnp.float32)
    out = matmul_padded(x, w, b, bm=64, bn=128, bk=128, order=order,
                        activation=act, interpret=True)
    want = ref.matmul_ref(x, w, b[0], activation=act)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("k,stride,cin,cout", [
    (1, 1, 8, 16), (3, 1, 16, 32), (3, 2, 16, 32), (5, 2, 4, 8), (7, 2, 3, 16)])
def test_conv2d_kernel_sweep(k, stride, cin, cout):
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((2, 16, 16, cin)) * 0.3, jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, k, cin, cout)) * 0.2, jnp.float32)
    b = jnp.asarray(rng.standard_normal((cout,)) * 0.1, jnp.float32)
    out = kernels.conv2d(x, w, b, stride=stride, padding="SAME", layout="NHWC",
                         activation="relu")
    want = ref.conv2d_ref(x, w, b, stride=stride, padding="SAME", activation="relu")
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_conv2d_nchw_layout_matches():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((1, 8, 12, 12)) * 0.3, jnp.float32)
    w = jnp.asarray(rng.standard_normal((16, 8, 3, 3)) * 0.2, jnp.float32)
    out = kernels.conv2d(x, w, None, stride=1, padding="SAME", layout="NCHW")
    dn = jax.lax.conv_dimension_numbers(x.shape, w.shape, ("NCHW", "OIHW", "NCHW"))
    want = jax.lax.conv_general_dilated(x, w, (1, 1), "SAME", dimension_numbers=dn)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("sq,skv,h,hkv", [(256, 256, 4, 4), (256, 256, 4, 2),
                                          (200, 200, 4, 1), (128, 384, 2, 2)])
@pytest.mark.parametrize("causal", [True, False])
def test_attention_sweep(sq, skv, h, hkv, causal):
    if causal and sq != skv:
        pytest.skip("causal requires aligned histories here")
    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.standard_normal((2, sq, h, 64)) * 0.3, jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, skv, hkv, 64)) * 0.3, jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, skv, hkv, 64)) * 0.3, jnp.float32)
    out = kernels.attention(q, k, v, causal=causal,
                            config={"block_q": 128, "block_kv": 128})
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("lengths", [[64, 199], [1, 256], [256, 256]])
def test_attention_decode_lengths(lengths):
    rng = np.random.default_rng(5)
    B, S, H, HKV, D = 2, 256, 4, 2, 64
    q = jnp.asarray(rng.standard_normal((B, H, D)) * 0.3, jnp.float32)
    kc = jnp.asarray(rng.standard_normal((B, S, HKV, D)) * 0.3, jnp.float32)
    vc = jnp.asarray(rng.standard_normal((B, S, HKV, D)) * 0.3, jnp.float32)
    L = jnp.asarray(np.array(lengths, np.int32))
    out = kernels.attention_decode(q, kc, vc, L, config={"block_kv": 128})
    want = ref.attention_decode_ref(q, kc, vc, L)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


def test_fused_elementwise_chain():
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.standard_normal((3, 50, 33)), jnp.float32)
    e = jnp.asarray(rng.standard_normal((3, 50, 33)), jnp.float32)
    chain = [{"op": "add"}, {"op": "gelu"}, {"op": "mul"}, {"op": "tanh"}]
    out = kernels.fused_elementwise(x, chain, [e, e])
    want = ref.fused_elementwise_ref(x, chain, [e, e])
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_tuned_config_from_search_is_numerically_sound():
    """End-to-end: a genetic-search winning config must run correctly."""
    from repro.core import SearchTask, TEMPLATES, genetic_search
    from repro.core.schedules import OpDesc
    op = OpDesc.matmul(256, 256, 384, dtype="float32")
    res = genetic_search(SearchTask(op, TEMPLATES["pallas_matmul"], seed=0))
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((256, 384)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((384, 256)) * 0.1, jnp.float32)
    out = kernels.matmul(x, w, config=res.config)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.matmul_ref(x, w)),
                               rtol=2e-4, atol=2e-4)
