"""Preemption + on-demand KV growth: admission is gated on the *prompt*
footprint, block tables grow one block at a time during decode, and when the
pool runs dry the scheduler preempts (swap-out to host) and later resumes
(swap-in through the shared prefill-commit path).  The differential tests
pin the contract that preemption is invisible to the tokens: a shrunken pool
must produce byte-identical greedy streams to an unconstrained one, with the
single decode program never recompiling."""

import math

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.distributed.sharding import DEFAULT_RULES
from repro.launch.mesh import single_device_mesh
from repro.models import build_model
from repro.serve.kvcache import BlockAllocator, KVCacheConfig
from repro.serve.metrics import ServeMetrics
from repro.serve.runtime import ContinuousEngine, RuntimeConfig
from repro.serve.scheduler import ContinuousScheduler, ServeRequest


def _req(rid, plen, max_new=4, arrival=0.0):
    return ServeRequest(rid=rid, prompt=np.zeros(plen, np.int32),
                        max_new_tokens=max_new, arrival_time=arrival)


# ----------------------------------------------------- latency_s / ttft_s
def test_latency_and_ttft_are_nan_until_finished():
    """Regression: unfinished requests used to report `0.0 - arrival_time`
    (a large negative latency) which any mean/percentile would silently
    absorb.  Now they are NaN until the timestamps exist."""
    r = _req(1, plen=4, arrival=123.4)
    assert math.isnan(r.latency_s)
    assert math.isnan(r.ttft_s)
    r.first_token_time = 125.0
    assert r.ttft_s == pytest.approx(1.6)
    assert math.isnan(r.latency_s)          # still mid-decode
    r.finish_time = 130.4
    assert r.latency_s == pytest.approx(7.0)


def test_metrics_refuse_nan_aggregation():
    m = ServeMetrics()
    with pytest.raises(ValueError):
        m.record_completion(_req(1, 4).latency_s, 3)
    with pytest.raises(ValueError):
        m.record_first_token(_req(1, 4).ttft_s)
    assert m.requests_done == 0 and not m.latencies_s and not m.ttfts_s


# ----------------------------------------------------------- admission
def test_admission_gates_on_prompt_not_budget():
    """The pool holds 3 usable blocks; each request's prompt needs 1 block
    but its worst case needs 3.  Worst-case reservation admitted one at a
    time — on-demand admission runs both concurrently."""
    kv = KVCacheConfig(num_blocks=4, block_size=4, max_blocks_per_seq=3)
    alloc = BlockAllocator(kv)
    sched = ContinuousScheduler(max_slots=2, kv_cfg=kv, alloc=alloc)
    sched.submit(_req(1, plen=4, max_new=9))     # worst case 12 rows = 3 blocks
    sched.submit(_req(2, plen=4, max_new=9))
    assert [r.rid for r in sched.admit(now=1.0)] == [1, 2]
    assert alloc.num_used == 2                   # one prompt block each


def test_submit_still_rejects_never_completable_requests():
    # worst case larger than the whole pool: no amount of preemption can
    # ever let this finish — reject at submit, as before.
    kv = KVCacheConfig(num_blocks=4, block_size=4, max_blocks_per_seq=8)
    sched = ContinuousScheduler(2, kv, BlockAllocator(kv))
    with pytest.raises(ValueError, match="KV blocks"):
        sched.submit(_req(1, plen=16, max_new=4))


def test_resume_queue_has_priority_and_blocks_newer_arrivals():
    """A preempted request re-admits before any new arrival, and while its
    block set does not fit, nothing behind it is admitted either (head-of-
    line fairness across both queues)."""
    kv = KVCacheConfig(num_blocks=7, block_size=4, max_blocks_per_seq=6)
    alloc = BlockAllocator(kv)
    sched = ContinuousScheduler(max_slots=2, kv_cfg=kv, alloc=alloc)
    sched.submit(_req(1, plen=8, max_new=8))     # 2 prompt blocks
    sched.submit(_req(2, plen=8, max_new=8))
    assert len(sched.admit(now=0.0)) == 2
    # grow rid 1 to 4 blocks, then preempt it (bookkeeping only — the
    # engine's device-side swap is exercised in the e2e tests below)
    assert alloc.extend(1, 16)
    r1 = sched.slots[0]
    alloc.swap_out(1)
    sched.preempt(r1, now=1.0)
    assert sched.num_preempted == 1 and r1.preemptions == 1
    sched.submit(_req(3, plen=4, max_new=2))     # 1 block — would fit!
    # free pool is 4 blocks (rid 2 holds 2), rid 1 needs 4 -> it resumes,
    # and rid 3 must NOT have jumped the queue beforehand
    admitted = sched.admit(now=2.0)
    assert [r.rid for r in admitted] == [1]
    assert r1.stall_s == pytest.approx(1.0)
    # pool now dry for rid 3's block? 0 free -> rid 3 still waits
    assert sched.admit(now=3.0) == []
    assert sched.num_waiting == 1


def test_victim_selection_is_deterministic_lifo():
    kv = KVCacheConfig(num_blocks=32, block_size=4, max_blocks_per_seq=8)
    alloc = BlockAllocator(kv)
    sched = ContinuousScheduler(max_slots=3, kv_cfg=kv, alloc=alloc)
    for rid, budget in enumerate([29, 5, 20], start=1):
        sched.submit(_req(rid, plen=4, max_new=budget))
    a = sched.admit(now=0.0)
    a[0].admitted_time, a[1].admitted_time, a[2].admitted_time = 1.0, 2.0, 2.0
    # LIFO first: rids 2 and 3 tie on admitted_time; the larger remaining
    # budget (rid 3, 20 tokens) wins the tiebreak — and repeatedly so.
    for _ in range(3):
        assert sched.victim_for_preemption(exclude_rid=99).rid == 3
    # the growing request itself is never its own victim
    assert sched.victim_for_preemption(exclude_rid=3).rid == 2
    sched.preempt(sched.slots[2], now=3.0)       # rid 3 off-slot
    alloc.swap_out(3)
    assert sched.victim_for_preemption(exclude_rid=1).rid == 2
    assert sched.victim_for_preemption(exclude_rid=2).rid == 1
    # only the excluded request left -> no victim, never a crash
    sched.preempt(sched.slots[1], now=3.0)
    alloc.swap_out(2)
    assert sched.victim_for_preemption(exclude_rid=1) is None


# ------------------------------------------------------------- engine e2e
@pytest.fixture(scope="module")
def tiny_lm():
    cfg = get_config("qwen3-1.7b").reduced(n_layers=2, d_model=64, d_ff=128,
                                           vocab=97)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _run(model, params, prompts, budgets, num_blocks, max_slots=3,
         now_fn=None):
    eng = ContinuousEngine(
        model, params, single_device_mesh(), DEFAULT_RULES,
        RuntimeConfig(max_slots=max_slots, block_size=8, max_blocks_per_seq=6,
                      num_blocks=num_blocks, max_new_tokens=16),
        now_fn=now_fn)
    for p, b in zip(prompts, budgets):
        eng.submit(p, max_new_tokens=b, arrival_time=0.0)
    done = eng.run()
    return eng, done


def test_preemption_differential_identity(tiny_lm):
    """Shrunken pool (forces preemption) vs unconstrained pool: per-request
    greedy streams identical, zero decode recompiles, pool fully drained."""
    cfg, model, params = tiny_lm
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=int(rng.integers(6, 20)))
               .astype(np.int32) for _ in range(6)]
    budgets = [int(rng.integers(8, 16)) for _ in prompts]

    small, done_s = _run(model, params, prompts, budgets, num_blocks=7)
    big, done_b = _run(model, params, prompts, budgets, num_blocks=None)

    assert small.metrics.preemptions >= 1
    assert big.metrics.preemptions == 0
    assert ({r.rid: r.output for r in done_s}
            == {r.rid: r.output for r in done_b})
    assert small._unified._cache_size() == 1    # preempt/resume: no recompile
    # prefill KV commits in-program now; the separate commit program is the
    # resume path only and always pads to the full table width — exactly
    # one shape ever traces, no bucket ladder anywhere.
    assert small._commit._cache_size() == 1
    assert small.metrics.swap_out_bytes > 0
    assert small.metrics.swap_in_bytes == small.metrics.swap_out_bytes
    small.cache.alloc.check_invariants()
    assert small.cache.alloc.num_used == 0
    assert not small.cache.alloc.swapped and not small.cache._swapped


def test_resume_preserves_output_and_timestamps(tiny_lm):
    """A preempted request finishes with its pre-preemption tokens intact
    (the resumed decode continues the same stream) and its lifecycle
    timestamps stay consistent: TTFT from the original prefill, positive
    stall, finite latency."""
    cfg, model, params = tiny_lm
    clock = {"t": 0.0}

    def now():
        clock["t"] += 0.01
        return clock["t"]

    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, size=12).astype(np.int32)
               for _ in range(5)]
    eng, done = _run(model, params, prompts, [14] * 5, num_blocks=7,
                     now_fn=now)
    preempted = [r for r in done if r.preemptions > 0]
    assert preempted, "pool of 6 usable blocks must force a preemption"
    for r in done:
        assert len(r.output) == 14
        assert r.arrival_time <= r.admitted_time <= r.first_token_time
        assert r.first_token_time <= r.finish_time
        assert not math.isnan(r.latency_s) and r.latency_s > 0
        assert r.preempted_time is None          # nobody left off-slot
    for r in preempted:
        assert r.stall_s > 0
    assert eng.metrics.stall_s == pytest.approx(
        sum(r.stall_s for r in done))


@pytest.mark.slow
def test_differential_fuzz_poisson_traces(tiny_lm):
    """Differential fuzz: random Poisson traces replayed through a shrunken
    pool (preemption-heavy) and an unconstrained pool under the same virtual
    clock — every per-request greedy stream must match, across seeds."""
    cfg, model, params = tiny_lm
    for seed in range(3):
        rng = np.random.default_rng(seed)
        n = 10
        arrivals = np.cumsum(rng.exponential(0.3, size=n))
        prompts = [rng.integers(0, cfg.vocab,
                                size=int(rng.integers(4, 24))).astype(np.int32)
                   for _ in range(n)]
        budgets = [int(rng.integers(2, 16)) for _ in range(n)]

        def replay(num_blocks):
            clock = {"t": 0.0}
            eng = ContinuousEngine(
                model, params, single_device_mesh(), DEFAULT_RULES,
                RuntimeConfig(max_slots=3, block_size=8, max_blocks_per_seq=6,
                              num_blocks=num_blocks, max_new_tokens=16),
                now_fn=lambda: clock["t"])
            for a, p, b in zip(arrivals, prompts, budgets):
                eng.submit(p, max_new_tokens=b, arrival_time=float(a))
            with eng.mesh:
                while eng.scheduler.has_work:
                    ran = eng.step()
                    clock["t"] += 0.2 if ran else 0.05
            return eng, {r.rid: r.output for r in eng._done}

        small, out_s = replay(num_blocks=7)
        big, out_b = replay(num_blocks=None)
        assert out_s == out_b, f"token streams diverged (seed {seed})"
        assert small.metrics.preemptions >= 1, f"no preemption (seed {seed})"
        assert small._unified._cache_size() == 1
        small.cache.alloc.check_invariants()
        assert small.cache.alloc.num_used == 0
