"""Mamba2 in the continuous scheduler: differential pins vs the drain.

The `SSMFamilyAdapter` serves `zoo.MambaLM` through the SAME
family-agnostic `ContinuousEngine` the decoder uses — fixed-size
slot-pooled conv+SSM state rows (repro.serve.statecache) instead of paged
KV blocks.  The contract mirrors the decoder's: byte-identical greedy
streams to the `FixedBatchEngine` drain (batch_size=1 — the per-request
ground truth), exactly two step executables plus the one-shape swap-in
commit, and preemption that swaps STATE ROWS without perturbing a single
token.  Prompt lengths are <= ssm_chunk or multiples of it because the
fixed-batch reference prefills whole prompts through the SSD scan, which
requires chunk alignment; the continuous chunk lane itself pads ragged
tails with zeroed-dt rows and takes any length.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.distributed.sharding import DEFAULT_RULES
from repro.launch.mesh import single_device_mesh
from repro.models import build_model
from repro.serve import (
    ContinuousEngine,
    FixedBatchEngine,
    RuntimeConfig,
    SSMFamilyAdapter,
    ServeConfig,
    TraceRecorder,
    write_trace,
)
from repro.serve import traceview

MAX_NEW = 8
LENS = (5, 16, 32, 7, 16, 48)     # partial, exact, and multi-chunk prompts


@pytest.fixture(scope="module")
def mamba_setup():
    cfg = get_config("mamba2-2.7b").reduced(n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mesh = single_device_mesh()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=l).astype(np.int32)
               for l in LENS]
    fixed = FixedBatchEngine(model, params, mesh, DEFAULT_RULES,
                             ServeConfig(batch_size=1, max_seq=64,
                                         max_new_tokens=MAX_NEW))
    for p in prompts:
        fixed.submit(p)
    ref = {r.rid: r.output for r in fixed.run()}
    return cfg, model, params, mesh, prompts, ref


def _virtual_clock():
    c = iter(range(1 << 20))
    return lambda: float(next(c))


def _drain(engine, prompts):
    for p in prompts:
        engine.submit(p, arrival_time=0.0)
    return {r.rid: r.output for r in engine.run()}


def test_ssm_continuous_matches_fixed_drain(mamba_setup):
    """Chunked-prefill commit into state slots + slot-batched decode must
    reproduce the drain's greedy streams exactly, from one unified and one
    decode-only executable."""
    cfg, model, params, mesh, prompts, ref = mamba_setup
    eng = ContinuousEngine(model, params, mesh, DEFAULT_RULES,
                           RuntimeConfig(max_slots=3, chunk_tokens=16,
                                         max_new_tokens=MAX_NEW),
                           now_fn=_virtual_clock())
    assert eng.family == "ssm"
    assert isinstance(eng.adapter, SSMFamilyAdapter)
    assert eng._chunk_width % cfg.ssm_chunk == 0   # SSD scan alignment
    done = _drain(eng, prompts)
    assert done == ref                             # byte-identical streams
    assert eng._unified._cache_size() == 1
    assert eng._decode_only._cache_size() == 1
    assert eng.metrics.preemptions == 0            # pool sized for the slots
    eng.cache.alloc.check_invariants()
    assert eng.cache.alloc.num_used == 0           # every row returned


def test_ssm_forced_slot_preemption_stays_byte_identical(mamba_setup):
    """State pool one row SHORT of the slot count (state_slots == max_slots
    -> usable == max_slots - 1): the replay must cross state-row swap-out /
    swap-in and still match the drain token-for-token, with the swap-in
    scatter compiling exactly once and the family taxonomy + trace audit
    holding over the run."""
    cfg, model, params, mesh, prompts, ref = mamba_setup
    rec = TraceRecorder()
    eng = ContinuousEngine(model, params, mesh, DEFAULT_RULES,
                           RuntimeConfig(max_slots=3, chunk_tokens=16,
                                         max_new_tokens=MAX_NEW,
                                         state_slots=3),
                           now_fn=_virtual_clock(), trace=rec)
    done = _drain(eng, prompts)
    assert done == ref
    assert eng.metrics.preemptions >= 1            # pressure actually bit
    assert eng._unified._cache_size() == 1
    assert eng._decode_only._cache_size() == 1
    assert eng._commit._cache_size() == 1          # swap-in scatter: one shape

    swap_outs = [e for e in rec.events if e.name == "swap_out"]
    assert swap_outs and all(e.fields["nbytes"] > 0 for e in swap_outs)
    lifecycle = [e for e in rec.events
                 if e.name in ("submit", "admit", "preempt", "finish",
                               "step_begin", "step_end")]
    assert lifecycle
    assert all(e.fields.get("family") == "ssm" for e in lifecycle)
    assert eng.metrics.family == "ssm"
    report = traceview.audit(
        rec.events, metrics=eng.metrics,
        metadata={"usable_blocks": eng.cache.cfg.usable, "family": "ssm"})
    assert report.ok, report.summary()
    eng.cache.alloc.check_invariants()
    assert eng.cache.alloc.num_used == 0 and not eng.cache.alloc.swapped


def test_ssm_traceview_cli_audits_traced_run(mamba_setup, tmp_path):
    """The PR 6 audit pipeline holds for the ssm family end-to-end: a traced
    continuous run written with write_trace passes the standalone
    `python -m repro.serve.traceview` CLI (exit 0)."""
    cfg, model, params, mesh, prompts, ref = mamba_setup
    rec = TraceRecorder()
    eng = ContinuousEngine(model, params, mesh, DEFAULT_RULES,
                           RuntimeConfig(max_slots=3, chunk_tokens=16,
                                         max_new_tokens=MAX_NEW,
                                         state_slots=3),
                           now_fn=_virtual_clock(), trace=rec)
    done = _drain(eng, prompts)
    assert done == ref
    path = tmp_path / "ssm_trace.json"
    write_trace(str(path), rec.events, metrics=eng.metrics,
                metadata={"usable_blocks": eng.cache.cfg.usable,
                          "block_size": 1, "family": "ssm"})
    assert traceview.main([str(path)]) == 0
