"""Tensor-parallel continuous serving: mesh invariance + the layout race.

Three layers of pinning:

  * `serve_rules` is a pure function (mesh enters only through
    `mesh.shape`), so its three tiers — single-device identity,
    divisibility guards, verdict demotion — are tested with stub meshes
    and fabricated plans, no devices involved;
  * the layout axis of the plan race (`select(model_parallel=...)`) is
    pinned structurally: every serving-stage matmul whose shard dim
    divides must carry BOTH layout candidates, and the verdict must
    round-trip through plan save/load;
  * the engine-level contract — token streams byte-identical across mesh
    widths 1/2/4 (greedy AND keyed sampling, under pool-pressure
    preemption and prefix sharing), exactly two step executables per
    family at every width, admission compiles nothing — runs in a
    subprocess that forces 4 virtual host devices before importing jax
    (the pattern of tests/test_distributed.py).
"""

import json
import subprocess
import sys
import textwrap

import pytest

from repro.configs import get_config
from repro.core.plan import InferencePlan, OpChoice
from repro.core.search.tuner import Tuner
from repro.distributed.sharding import DEFAULT_RULES
from repro.kernels.dispatch import MATMUL_ROLES
from repro.serve.router import PlanRouter, build_serve_plan


class FakeMesh:
    def __init__(self, model: int, data: int = 1):
        self.shape = {"data": data, "model": model}


def _raced_choice(layout: str, raced: bool = True) -> OpChoice:
    cands = ({"replicated": 1e-6, "model_parallel": 2e-6} if raced else {})
    return OpChoice("xla", {}, 1e-6, layout=layout, layout_candidates=cands)


def _plan_with(verdicts) -> InferencePlan:
    plan = InferencePlan("serve", "tpu_v5e")
    for name, choice in verdicts.items():
        plan.choices[name] = choice
    return plan


def _decoder_cfg(vocab: int = 97):
    # n_heads=4, n_kv_heads=2, head_dim=32 (reduced defaults)
    return get_config("qwen3-1.7b").reduced(n_layers=2, d_model=64,
                                            d_ff=128, vocab=vocab)


# ------------------------------------------------------------- serve_rules
def test_serve_rules_single_device_is_identity():
    router = PlanRouter(_plan_with({"decode.mlp_up":
                                    _raced_choice("replicated")}))
    out = router.serve_rules(DEFAULT_RULES, FakeMesh(model=1), _decoder_cfg())
    assert out is DEFAULT_RULES   # tier 1: the pre-mesh engine, untouched


def test_serve_rules_no_plan_applies_divisibility_guards():
    cfg = _decoder_cfg(vocab=97)          # prime: vocab can never shard
    r = PlanRouter(None).serve_rules(DEFAULT_RULES, FakeMesh(model=2), cfg)
    assert r.lookup("heads") == "model"       # 4 % 2 == 0
    assert r.lookup("kv_heads") == "model"    # 2 % 2 == 0
    assert r.lookup("ffn") == "model"         # 128 % 2 == 0
    assert r.lookup("vocab") is None          # 97 % 2 != 0
    assert r.lookup("embed_vec") == "model"   # d_model fallback, 64 % 2 == 0

    r4 = PlanRouter(None).serve_rules(DEFAULT_RULES, FakeMesh(model=4), cfg)
    assert r4.lookup("kv_heads") is None      # 2 % 4 != 0
    assert r4.lookup("heads") == "model"      # 4 % 4 == 0


def test_serve_rules_demotes_only_on_explicit_replicated_verdict():
    cfg = _decoder_cfg(vocab=128)             # everything divides 2
    mesh = FakeMesh(model=2)

    # an explicit replicated verdict on the mlp pair demotes 'ffn' — and
    # ONLY 'ffn' (the head axes keep their guard-passed layout)
    router = PlanRouter(_plan_with(
        {"decode.mlp_up": _raced_choice("replicated")}))
    r = router.serve_rules(DEFAULT_RULES, mesh, cfg)
    assert r.lookup("ffn") is None
    assert r.lookup("heads") == "model"
    assert r.lookup("vocab") == "model"

    # qkv/attention verdicts demote the coupled head axes together
    router = PlanRouter(_plan_with(
        {"prefill_chunk.attention": _raced_choice("replicated")}))
    r = router.serve_rules(DEFAULT_RULES, mesh, cfg)
    assert r.lookup("heads") is None and r.lookup("kv_heads") is None
    assert r.lookup("ffn") == "model"

    # lm_head demotes vocab AND the embed_vec fallback
    router = PlanRouter(_plan_with(
        {"decode.lm_head": _raced_choice("replicated")}))
    r = router.serve_rules(DEFAULT_RULES, mesh, cfg)
    assert r.lookup("vocab") is None and r.lookup("embed_vec") is None


def test_serve_rules_old_plans_and_nonserve_stages_never_demote():
    cfg = _decoder_cfg(vocab=128)
    mesh = FakeMesh(model=2)

    # a pre-layout plan (no layout_candidates) carries no verdict: the
    # guards alone govern, exactly as with no plan at all
    router = PlanRouter(_plan_with(
        {"decode.mlp_up": _raced_choice("replicated", raced=False)}))
    r = router.serve_rules(DEFAULT_RULES, mesh, cfg)
    assert r.lookup("ffn") == "model"

    # a prefill-only plan serves decode through the `_lookup` fallback, so
    # its replicated verdict governs...
    router = PlanRouter(_plan_with(
        {"prefill.mlp_up": _raced_choice("replicated")}))
    r = router.serve_rules(DEFAULT_RULES, mesh, cfg)
    assert r.lookup("ffn") is None
    # ...but stage-specific serving choices take precedence over the
    # fallback: with explicit model_parallel verdicts on the serve stages,
    # the stale prefill verdict no longer demotes
    router = PlanRouter(_plan_with({
        "prefill.mlp_up": _raced_choice("replicated"),
        "decode.mlp_up": _raced_choice("model_parallel"),
        "prefill_chunk.mlp_up": _raced_choice("model_parallel"),
        "decode.mlp_down": _raced_choice("model_parallel"),
        "prefill_chunk.mlp_down": _raced_choice("model_parallel"),
    }))
    r = router.serve_rules(DEFAULT_RULES, mesh, cfg)
    assert r.lookup("ffn") == "model"


def test_serve_rules_ssm_guards_and_demotion():
    cfg = get_config("mamba2-2.7b").reduced(n_layers=2)
    d_in = cfg.ssm_expand * cfg.d_model
    nh = d_in // cfg.ssm_head_dim
    conv_dim = d_in + 2 * cfg.ssm_state

    m = 2
    r = PlanRouter(None).serve_rules(DEFAULT_RULES, FakeMesh(model=m), cfg,
                                     family="ssm")
    assert r.lookup("ssm_heads") == ("model" if nh % m == 0 else None)
    assert r.lookup("conv_dim") == ("model" if conv_dim % m == 0 else None)

    router = PlanRouter(_plan_with(
        {"ssm_decode.in_proj": _raced_choice("replicated")}))
    r = router.serve_rules(DEFAULT_RULES, FakeMesh(model=m), cfg,
                           family="ssm")
    assert r.lookup("ssm_heads") is None and r.lookup("conv_dim") is None


# ------------------------------------------------------------ layout race
def test_plan_race_covers_both_layouts_per_matmul_stage():
    """Acceptance pin: with a model axis, `select` races >= 2 layout
    choices (replicated + model_parallel) for every serving-stage matmul
    whose shard dim divides, and records the verdict on the choice."""
    cfg = _decoder_cfg(vocab=128)
    plan = build_serve_plan(cfg, prefill_len=32, slots=4, max_seq=64,
                            tuner=Tuner(methods=("random",),
                                        random_budget=4),
                            model_parallel=2)
    for stage in ("decode", "prefill_chunk"):
        for role in MATMUL_ROLES + ("attention",):
            c = plan.choice(f"{stage}.{role}")
            assert c is not None, f"{stage}.{role} missing from plan"
            assert set(c.layout_candidates) == {
                "replicated", "model_parallel"}, (stage, role)
            assert c.layout in ("replicated", "model_parallel")
            # the verdict must agree with the recorded race times
            lc = c.layout_candidates
            fastest = min(lc, key=lc.get)
            assert c.layout == fastest or lc["replicated"] == lc[fastest]

    # single-device plans never open the layout axis
    flat = build_serve_plan(cfg, prefill_len=32, slots=4, max_seq=64,
                            tuner=Tuner(methods=("random",),
                                        random_budget=4))
    assert all(not c.layout_candidates for c in flat.choices.values())


def test_indivisible_dims_are_never_raced():
    cfg = _decoder_cfg(vocab=97)          # prime vocab: lm_head can't shard
    plan = build_serve_plan(cfg, prefill_len=32, slots=4, max_seq=64,
                            tuner=Tuner(methods=("random",),
                                        random_budget=4),
                            model_parallel=8)
    # vocab 97 % 8 != 0 and n_heads 4 % 8 != 0: no illegal layout
    # candidate appears on those roles, while divisible dims still race
    for stage in ("decode", "prefill_chunk"):
        assert not plan.choice(f"{stage}.lm_head").layout_candidates
        assert not plan.choice(f"{stage}.attention").layout_candidates
        # ffn 128 % 8 == 0 and qkv n-dim 256 % 8 == 0 still race
        assert plan.choice(f"{stage}.mlp_up").layout_candidates
        assert plan.choice(f"{stage}.qkv_proj").layout_candidates


def test_layout_verdict_roundtrips_through_plan_save(tmp_path):
    plan = _plan_with({
        "decode.mlp_up": _raced_choice("model_parallel"),
        "decode.lm_head": _raced_choice("replicated"),
        "decode.qkv_proj": OpChoice("xla", {}, 1e-6),   # pre-layout choice
    })
    path = str(tmp_path / "plan.json")
    plan.save(path)
    loaded = InferencePlan.load(path)
    assert loaded.choice("decode.mlp_up").layout == "model_parallel"
    assert loaded.choice("decode.mlp_up").layout_candidates == {
        "replicated": 1e-6, "model_parallel": 2e-6}
    assert loaded.choice("decode.lm_head").layout == "replicated"
    assert loaded.choice("decode.qkv_proj").layout == "replicated"
    assert loaded.choice("decode.qkv_proj").layout_candidates == {}

    router = PlanRouter(loaded)
    assert router.layout_table("decode")["mlp_up"] == "model_parallel"
    assert router.layout_table("decode")["lm_head"] == "replicated"


# --------------------------------------------- cross-mesh differential pins
# One subprocess, 4 virtual host devices: serve the same preemption +
# prefix-sharing + mixed-sampling workload at mesh widths 1/2/4 and pin
# byte-identical streams, the two-executable compile property at every
# width, compile-free admission, and the tuned layout table reaching the
# step builders (through engine.rules).
_CROSS_MESH = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import json
    import numpy as np
    import jax
    from repro.configs import get_config
    from repro.core.search.tuner import Tuner
    from repro.data import DataConfig, SyntheticLMData
    from repro.distributed.sharding import DEFAULT_RULES
    from repro.launch.mesh import single_device_mesh, tp_mesh
    from repro.launch.steps import TrainConfig, jit_train_step
    from repro.models import build_model
    from repro.optim import AdamWConfig, adamw_init
    from repro.serve.router import PlanRouter, build_serve_plan
    from repro.serve.runtime import ContinuousEngine, RuntimeConfig
    from repro.serve.sampling import SamplingParams

    SEEDS = %(seeds)s
    # A briefly-trained model, not random init: the learned affine task
    # gives every position a macroscopic argmax margin.  K-sharded layers
    # (mlp_down, out_proj) reassociate their reduction under the mesh, so
    # bf16 hidden states legitimately differ by ~1 ulp across layouts —
    # a random-init model's near-uniform logits flip on exactly that ulp,
    # while trained margins dominate it by orders of magnitude.  Byte
    # identity across meshes is a decision-level invariant, and this is
    # the regime (a model with actual structure) where it is exact.
    cfg = get_config("qwen3-1.7b").reduced(n_layers=2, d_model=128,
                                           d_ff=256, vocab=192)
    model = build_model(cfg)
    mesh1 = single_device_mesh()
    data = SyntheticLMData(DataConfig(vocab=cfg.vocab, seq_len=32,
                                      global_batch=8))
    STEPS = 60
    with mesh1:
        params = model.init(jax.random.PRNGKey(0))
        opt = adamw_init(params)
        b0 = data.batch(0)
        specs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                 for k, v in b0.items()}
        step = jit_train_step(
            model, mesh1, DEFAULT_RULES,
            TrainConfig(opt=AdamWConfig(lr=3e-3, warmup_steps=5,
                                        total_steps=STEPS)), specs)
        for i in range(STEPS):
            b = {k: jax.numpy.asarray(v) for k, v in data.batch(i).items()}
            params, opt, _ = step(params, opt, b)

    # num_blocks=8 (7 usable) forces decode-growth preemption with 3
    # slots; prompts 0/2/4 share a start so prefix sharing hits too
    rcfg = RuntimeConfig(max_slots=3, max_new_tokens=12, chunk_tokens=16,
                         num_blocks=8, prefix_sharing=True)

    def affine(start, n):
        return ((start + 17 * np.arange(n)) %% cfg.vocab).astype(np.int32)

    def workload(seed):
        rng = np.random.RandomState(seed)
        s_hot = int(rng.randint(0, cfg.vocab))
        prompts, samp = [], []
        for i, n in enumerate((48, 23, 64, 12, 48, 3)):
            start = s_hot if i %% 2 == 0 else int(rng.randint(0, cfg.vocab))
            prompts.append(affine(start, n))
            samp.append([None,
                         SamplingParams(temperature=0.7, top_k=8,
                                        seed=11 + seed),
                         None,
                         SamplingParams(temperature=0.5, top_p=0.9,
                                        seed=5 + seed)][i %% 4])
        return prompts, samp

    def serve(tp, seed, router=None, rules=DEFAULT_RULES):
        eng = ContinuousEngine(model, params, tp_mesh(tp), rules, rcfg,
                               router=router or PlanRouter(None))
        prompts, samp = workload(seed)
        for p, s in zip(prompts, samp):
            eng.submit(p, sampling=s)
        pre = (eng._unified._cache_size() + eng._decode_only._cache_size())
        done = eng.run()
        s = eng.metrics.summary()
        return ({r.rid: [int(t) for t in r.output] for r in done},
                {"admission_compiles": pre,
                 "unified": eng._unified._cache_size(),
                 "decode_only": eng._decode_only._cache_size(),
                 "preemptions": int(s["preemptions"]),
                 "prefix_hits": int(s.get("prefix_hit_tokens", 0)),
                 "rules": {a: eng.rules.lookup(a) for a in
                           ("heads", "kv_heads", "ffn", "vocab",
                            "embed_vec")}})

    out = {"ndev": len(jax.devices()), "runs": []}
    tuned4 = PlanRouter(build_serve_plan(
        cfg, prefill_len=64, slots=rcfg.max_slots, max_seq=rcfg.max_seq,
        chunk_tokens=rcfg.chunk_width,
        tuner=Tuner(methods=("random",), random_budget=4),
        model_parallel=4))
    for seed in SEEDS:
        base, info1 = serve(1, seed)
        run = {"seed": seed, "tp1": info1}
        for tp in (2, 4):
            got, info = serve(tp, seed)
            run[f"tp{tp}"] = info
            run[f"identical_tp{tp}"] = got == base
        got, info = serve(4, seed, router=tuned4)
        run["tp4_tuned"] = info
        run["identical_tp4_tuned"] = got == base
        out["runs"].append(run)
    print(json.dumps(out))
""")


def _run_cross_mesh(seeds, timeout=600):
    script = _CROSS_MESH % {"seeds": repr(list(seeds))}
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=timeout,
                         env={**__import__("os").environ,
                              "PYTHONPATH": "src"},
                         cwd="/root/repo")
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def _check_run(run):
    for tp in (2, 4):
        assert run[f"identical_tp{tp}"], (
            f"seed {run['seed']}: tp={tp} stream diverged from tp=1")
    assert run["identical_tp4_tuned"], (
        f"seed {run['seed']}: tuned-layout tp=4 stream diverged")
    for leg in ("tp1", "tp2", "tp4", "tp4_tuned"):
        info = run[leg]
        # admission compiles nothing; exactly 2 step executables after
        assert info["admission_compiles"] == 0, (leg, info)
        assert info["unified"] == 1, (leg, info)
        assert info["decode_only"] == 1, (leg, info)
    # the workload must actually exercise the hard paths
    assert run["tp1"]["preemptions"] > 0, run
    assert run["tp1"]["prefix_hits"] > 0, run
    # guards reach the step builders through engine.rules: at tp=4 the
    # indivisible axis (kv_heads=2) demotes, the rest shard
    r4 = run["tp4"]["rules"]
    assert r4["heads"] == "model" and r4["kv_heads"] is None, r4
    assert r4["ffn"] == "model" and r4["vocab"] == "model", r4
    r1 = run["tp1"]["rules"]
    assert r1["heads"] == "model" and r1["vocab"] == "model", r1


def test_cross_mesh_streams_byte_identical_fast():
    """Greedy + keyed-sampled token streams on 1x2 and 1x4 host meshes are
    byte-identical to single-device, under preemption and prefix sharing,
    with the two-executable and compile-free-admission pins at every
    width — the fast differential (one seed)."""
    payload = _run_cross_mesh([7])
    assert payload["ndev"] == 4
    _check_run(payload["runs"][0])


@pytest.mark.slow
def test_cross_mesh_streams_byte_identical_fuzz():
    """The seeded fuzz: several workloads (different prompt mixes, keys
    and preemption patterns) through the same cross-mesh differential."""
    payload = _run_cross_mesh([0, 1, 2], timeout=900)
    assert payload["ndev"] == 4
    for run in payload["runs"]:
        _check_run(run)
