"""Optimizer, PPO internals, mamba SSD oracle, and the serving engine."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
    int8_compress,
    int8_decompress,
)


def test_adamw_minimises_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=1, total_steps=200, weight_decay=0.0,
                      grad_clip=10.0)
    params = {"w": jnp.asarray(np.array([3.0, -2.0], np.float32))}
    state = adamw_init(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_grad_clipping():
    tree = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(20.0)
    new_norm = float(jnp.sqrt(jnp.sum(jnp.square(clipped["a"]))))
    assert new_norm == pytest.approx(1.0, rel=1e-5)


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    assert float(cosine_schedule(cfg, jnp.asarray(0))) == pytest.approx(0.0)
    assert float(cosine_schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(cosine_schedule(cfg, jnp.asarray(100))) == pytest.approx(0.1, abs=1e-6)


def test_int8_compression_error_bound():
    x = jnp.asarray(np.random.default_rng(0).standard_normal(1000).astype(np.float32))
    q, s = int8_compress(x)
    back = int8_decompress(q, s)
    assert q.dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                               atol=float(s) + 1e-6)


# ------------------------------------------------------------------- PPO
def test_gae_matches_naive():
    from repro.core.search.ppo import gae
    rng = np.random.default_rng(0)
    T = 16
    r = rng.standard_normal(T).astype(np.float32)
    v = rng.standard_normal(T + 1).astype(np.float32)
    gamma, lam = 0.9, 0.8
    adv, ret = gae(r, v, gamma, lam)
    # naive O(T^2)
    for t in range(T):
        acc, coef = 0.0, 1.0
        for l in range(T - t):
            delta = r[t + l] + gamma * v[t + l + 1] - v[t + l]
            acc += coef * delta
            coef *= gamma * lam
        assert adv[t] == pytest.approx(acc, rel=1e-4, abs=1e-4)
    np.testing.assert_allclose(ret, adv + v[:-1], rtol=1e-6)


def test_ppo_policy_architecture_matches_paper():
    """FC 512/1024/1024/512 + final linear head (paper §2.4)."""
    from repro.core.search.ppo import init_params, POLICY_WIDTHS, POLICY_ACTS
    assert POLICY_WIDTHS == (512, 1024, 1024, 512)
    assert POLICY_ACTS == ("tanh", "tanh", "selu", "selu")
    p = init_params(jax.random.PRNGKey(0), obs_dim=17, n_actions=10)
    widths = [layer["w"].shape for layer in p["policy"]]
    assert widths == [(17, 512), (512, 1024), (1024, 1024), (1024, 512), (512, 10)]


def test_ppo_update_moves_params_and_loss_finite():
    from repro.core.search.ppo import PPOAgent, PPOConfig
    agent = PPOAgent(obs_dim=17, n_actions=6,
                     cfg=PPOConfig(epochs=1, minibatch=8), seed=0)
    rng = np.random.default_rng(0)
    T = 16
    obs = rng.standard_normal((T, 17)).astype(np.float32)
    acts, logps = zip(*(agent.act(o) for o in obs))
    rew = rng.standard_normal(T).astype(np.float32)
    before = np.asarray(agent.params["policy"][0]["w"]).copy()
    loss = agent.update(obs, list(acts), list(logps), rew, obs[-1])
    assert np.isfinite(loss)
    after = np.asarray(agent.params["policy"][0]["w"])
    assert not np.array_equal(before, after)


def test_rl_reward_equation4():
    """r_t = alpha_{t-1} - min(beta_t, 2 alpha_{t-1})."""
    alpha = 10.0
    assert alpha - min(5.0, 2 * alpha) == 5.0      # faster -> positive
    assert alpha - min(15.0, 2 * alpha) == -5.0    # slower -> negative
    assert alpha - min(100.0, 2 * alpha) == -alpha  # clamped worst case


# ------------------------------------------------------------------ mamba
def test_ssd_chunked_matches_naive_recurrence():
    from repro.models.mamba import ssd_chunked
    rng = np.random.default_rng(0)
    b, s, h, p, n = 2, 24, 3, 4, 8
    x = rng.standard_normal((b, s, h, p)) * 0.5
    dt = rng.random((b, s, h)) * 0.5
    A = -rng.random(h)
    B = rng.standard_normal((b, s, n)) * 0.5
    C = rng.standard_normal((b, s, n)) * 0.5

    hstate = np.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        dA = np.exp(dt[:, t] * A)
        hstate = hstate * dA[..., None, None] + np.einsum(
            "bhp,bn,bh->bhpn", x[:, t], B[:, t], dt[:, t])
        ys.append(np.einsum("bhpn,bn->bhp", hstate, C[:, t]))
    y_ref = np.stack(ys, 1)

    y, hf = ssd_chunked(jnp.asarray(x, jnp.float32), jnp.asarray(dt, jnp.float32),
                        jnp.asarray(A, jnp.float32), jnp.asarray(B, jnp.float32),
                        jnp.asarray(C, jnp.float32), chunk=8)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(hf), hstate, rtol=2e-3, atol=2e-3)


def test_mamba_prefill_state_equals_decode_rollout():
    """Prefill final SSM state must equal the state after decoding the same
    tokens one by one (SSD <-> recurrence duality)."""
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("mamba2-2.7b").reduced(n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 16
    toks = jnp.asarray(np.arange(S).reshape(1, S) % cfg.vocab, jnp.int32)
    _, cache_pre = model.prefill(params, {"tokens": toks}, max_seq=S)

    cache = jax.tree.map(jnp.zeros_like, cache_pre)
    cache["lengths"] = jnp.zeros((B,), jnp.int32)
    for t in range(S):
        _, cache = model.decode_step(params, cache, toks[:, t:t + 1])
    np.testing.assert_allclose(np.asarray(cache["ssm"], np.float32),
                               np.asarray(cache_pre["ssm"], np.float32),
                               rtol=2e-2, atol=2e-2)


# ------------------------------------------------------------------ serve
def test_serve_engine_end_to_end():
    from repro.configs import get_config
    from repro.distributed.sharding import DEFAULT_RULES
    from repro.launch.mesh import single_device_mesh
    from repro.models import build_model
    from repro.serve import ServeConfig, ServeEngine

    cfg = get_config("qwen3-1.7b").reduced(n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, single_device_mesh(), DEFAULT_RULES,
                      ServeConfig(batch_size=2, max_seq=64, max_new_tokens=8))
    rng = np.random.default_rng(0)
    rids = [eng.submit(rng.integers(0, cfg.vocab, size=12)) for _ in range(5)]
    done = eng.run()
    assert len(done) == 5
    for r in done:
        assert len(r.output) == 8
        assert all(0 <= t < cfg.vocab for t in r.output)
    assert eng.stats["requests"] == 5
    assert eng.throughput() > 0


def test_serve_greedy_decode_matches_forward_argmax():
    """The served first token must equal argmax of the forward logits."""
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("qwen3-1.7b").reduced(n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    S = 12
    toks = jnp.asarray(np.arange(S).reshape(1, S) % cfg.vocab, jnp.int32)
    logits = model.forward(params, {"tokens": toks})
    want = int(jnp.argmax(logits[0, -1]))
    lp, _ = model.prefill(params, {"tokens": toks}, max_seq=S + 4)
    got = int(jnp.argmax(lp[0, -1]))
    assert got == want
