"""Sharding-rule logic + an in-subprocess 8-device mini dry-run (the only
place outside launch/dryrun.py that forces host devices)."""

import dataclasses
import json
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs import SHAPES, get_config
from repro.distributed.sharding import DEFAULT_RULES, ShardingRules, prune_for_mesh
from repro.launch.mesh import single_device_mesh
from repro.launch.steps import rules_for_shape, zero1_axes


def test_rules_lookup_and_replace():
    r = DEFAULT_RULES
    assert r.lookup("ffn") == "model"
    r2 = r.replace(ffn=None)
    assert r2.lookup("ffn") is None
    assert r.lookup("ffn") == "model"  # original untouched
    with pytest.raises(KeyError):
        r.lookup("nope")


def test_prune_for_mesh_drops_missing_axes():
    mesh = single_device_mesh()  # data, model only
    r = prune_for_mesh(DEFAULT_RULES, mesh)
    assert r.lookup("batch") == "data"  # ('pod','data') -> 'data'


def test_prune_for_mesh_tuple_axes():
    """Tuple-valued rules prune element-wise: a surviving pair stays a
    tuple, a single survivor collapses to a bare axis, none -> None."""
    class PodDataMesh:
        shape = {"pod": 2, "data": 4}

    class ModelOnlyMesh:
        shape = {"model": 2}

    r = prune_for_mesh(DEFAULT_RULES, PodDataMesh())
    assert r.lookup("batch") == ("pod", "data")   # both present: unchanged
    assert r.lookup("heads") is None              # 'model' absent

    r = prune_for_mesh(DEFAULT_RULES, ModelOnlyMesh())
    assert r.lookup("batch") is None              # neither tuple member
    assert r.lookup("heads") == "model"
    assert r.lookup("zero") is None               # 'data' absent
    assert r.lookup("seq") is None                # None stays None

    wide = DEFAULT_RULES.replace(batch=("pod", "data", "model"))
    r = prune_for_mesh(wide, PodDataMesh())
    assert r.lookup("batch") == ("pod", "data")   # multi-survivor tuple


def test_replace_round_trips_and_preserves_table():
    r = DEFAULT_RULES.replace(ffn=None, vocab=None, batch="data")
    back = r.replace(ffn="model", vocab="model", batch=("pod", "data"))
    assert back == DEFAULT_RULES          # frozen dataclass value equality
    assert dict(back.rules) == dict(DEFAULT_RULES.rules)
    # replace never reorders or drops axes — the table stays congruent
    assert [k for k, _ in r.rules] == [k for k, _ in DEFAULT_RULES.rules]
    with pytest.raises(dataclasses.FrozenInstanceError):
        r.rules = ()                      # frozen: no in-place mutation


def test_logical_to_spec_unknown_axis_raises():
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import logical_to_spec

    assert logical_to_spec(DEFAULT_RULES, ("batch", None, "heads")) == \
        P(("pod", "data"), None, "model")
    with pytest.raises(KeyError, match="made_up_axis"):
        logical_to_spec(DEFAULT_RULES, ("batch", "made_up_axis"))
    # None entries are legal and map to replicated dims, even trailing
    assert logical_to_spec(DEFAULT_RULES, (None, None)) == P(None, None)


def test_rules_for_shape_divisibility_fallbacks():
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    # kv=8 indivisible by 16 -> replicated KV
    cfg = get_config("internlm2-20b")
    r = rules_for_shape(cfg, SHAPES["train_4k"], FakeMesh())
    assert r.lookup("kv_heads") is None
    assert r.lookup("heads") == "model"      # 48 % 16 == 0

    # whisper: odd vocab -> embed_vec fallback
    cfg = get_config("whisper-base")
    r = rules_for_shape(cfg, SHAPES["train_4k"], FakeMesh())
    assert r.lookup("vocab") is None
    assert r.lookup("embed_vec") == "model"

    # qwen2-moe: 60 experts indivisible -> TP inside experts
    cfg = get_config("qwen2-moe-a2.7b")
    r = rules_for_shape(cfg, SHAPES["train_4k"], FakeMesh())
    assert r.lookup("experts") is None
    assert r.lookup("expert_ffn") == "model"

    # qwen3-moe keeps EP
    cfg = get_config("qwen3-moe-235b-a22b")
    r = rules_for_shape(cfg, SHAPES["train_4k"], FakeMesh())
    assert r.lookup("experts") == "model"

    # long_500k batch=1 -> SP
    cfg = get_config("mamba2-2.7b")
    r = rules_for_shape(cfg, SHAPES["long_500k"], FakeMesh())
    assert r.lookup("batch") is None
    assert r.lookup("ssm_state") == "data"


def test_zero1_rewrites_first_divisible_dim():
    class FakeMesh:
        shape = {"data": 4, "model": 2}

    logical = {"w": (None, None), "v": ("ffn", None), "s": (None,)}
    shapes = {"w": jax.ShapeDtypeStruct((8, 6), np.float32),
              "v": jax.ShapeDtypeStruct((4, 8), np.float32),
              "s": jax.ShapeDtypeStruct((7,), np.float32)}
    out = zero1_axes(logical, shapes, FakeMesh(), DEFAULT_RULES)
    assert out["w"] == ("zero", None)      # dim0 divisible by 4
    assert out["v"] == ("ffn", "zero")     # first None dim that divides
    assert out["s"] == (None,)             # 7 % 4 != 0 -> untouched


def test_param_shardings_cover_every_leaf():
    cfg = get_config("qwen3-1.7b").reduced()
    from repro.models import build_model
    from repro.launch.steps import make_state_shardings, TrainConfig
    model = build_model(cfg)
    mesh = single_device_mesh()
    p_shard, opt_shard = make_state_shardings(
        model, mesh, prune_for_mesh(DEFAULT_RULES, mesh), TrainConfig())
    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    assert len(jax.tree.leaves(p_shard)) == len(jax.tree.leaves(params_shapes))
    assert len(jax.tree.leaves(opt_shard["m"])) == len(jax.tree.leaves(params_shapes))


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, numpy as np
    from repro.configs import get_config, input_specs, SHAPES
    from repro.launch.steps import TrainConfig, jit_train_step, rules_for_shape
    from repro.models import build_model
    from repro.optim import adamw_init
    import dataclasses

    cfg = get_config("qwen3-1.7b").reduced()
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=32, global_batch=8)
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    model = build_model(cfg)
    rules = rules_for_shape(cfg, shape, mesh)
    with mesh:
        params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        opt = jax.eval_shape(adamw_init, params)
        batch = input_specs(cfg, shape)
        fn = jit_train_step(model, mesh, rules, TrainConfig(microbatches=2), batch)
        compiled = fn.lower(params, opt, batch).compile()
        from repro.launch.steps import cost_dict
        cost = cost_dict(compiled.cost_analysis())
        print(json.dumps({"flops": float(cost.get("flops", 0)),
                          "ndev": len(jax.devices())}))
""")


@pytest.mark.slow
def test_multidevice_dryrun_subprocess():
    """An 8-device (2x2x2 pod/data/model) train-step lower+compile must
    succeed — the miniature version of the 512-device production dry-run."""
    out = subprocess.run([sys.executable, "-c", _SUBPROC], capture_output=True,
                         text=True, timeout=600,
                         env={**__import__("os").environ, "PYTHONPATH": "src"},
                         cwd="/root/repo")
    assert out.returncode == 0, out.stderr[-2000:]
    payload = json.loads(out.stdout.strip().splitlines()[-1])
    assert payload["ndev"] == 8
    assert payload["flops"] > 0


def test_compressed_allgather_mean_roundtrip():
    """int8-compressed gradient reduction under shard_map (1-device axis):
    value error stays within quantisation tolerance."""
    from functools import partial
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.optim import compressed_allgather_mean

    mesh = jax.make_mesh((1,), ("pod",))
    x = np.linspace(-1, 1, 64).astype(np.float32)
    fn = shard_map(partial(compressed_allgather_mean, axis_name="pod"),
                   mesh=mesh, in_specs=P(), out_specs=P(), check_rep=False)
    got = np.asarray(fn(x))
    np.testing.assert_allclose(got, x, atol=2.0 / 127)
