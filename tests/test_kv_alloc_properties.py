"""Property-based `BlockAllocator` invariants (hypothesis): under arbitrary
interleavings of allocate / extend / free / swap_out / swap_in / share /
copy-on-write / prefix-index registration the allocator must keep every
block free XOR owned, with each owned block's refcount equal to the number
of tables containing it, never hand the same free block to two owners, fail
loudly on double-free, only ever grow a table append-only (`extend`
monotonicity), and clamp `extend` to the table bound.
`check_invariants()` runs after EVERY operation.

The same interpreter is exercised with a fixed numpy seed (no hypothesis)
from `test_serving_runtime.py`'s churn test; this module is the adversarial
search on top.  CI pins the hypothesis profile via HYPOTHESIS_PROFILE=ci
(registered in conftest.py: derandomized, fixed example budget) so the fast
job is reproducible.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.serve.kvcache import NULL_BLOCK, BlockAllocator, KVCacheConfig

# one common token stream for the prefix-index ops: every registration keys
# prefixes of THIS array, so registrations collide (first wins) and
# match_prefix actually hits
TOKENS = np.arange(1, 4097, dtype=np.int32)


def run_op_sequence(cfg: KVCacheConfig, ops) -> BlockAllocator:
    """Interpret (kind, x) pairs against a fresh allocator, asserting the
    full invariant set after every operation.  `x` is folded into whatever
    range the chosen operation needs, so any integer sequence is a valid
    program — hypothesis shrinks freely."""
    alloc = BlockAllocator(cfg)
    usable = cfg.num_blocks - 1
    live, swapped = [], []
    next_rid = 1

    def check():
        alloc.check_invariants()
        assert alloc.num_free + alloc.num_used == usable
        assert sorted(alloc.tables) == sorted(live)
        assert sorted(alloc.swapped) == sorted(swapped)

    for kind, x in ops:
        kind = kind % 8
        if kind == 0:                                   # allocate
            rid = next_rid
            next_rid += 1
            n = x % (alloc.num_free + 2)                # may exceed the pool
            if n > alloc.num_free:
                with pytest.raises(MemoryError):
                    alloc.allocate(rid, n)
            else:
                blocks = alloc.allocate(rid, n)
                assert len(blocks) == n
                assert NULL_BLOCK not in blocks
                live.append(rid)
        elif kind == 1 and (live or swapped):           # extend
            if not live:
                # swapped-out rids must be rejected loudly, not KeyError
                with pytest.raises(ValueError):
                    alloc.extend(swapped[x % len(swapped)], 1)
            else:
                rid = live[x % len(live)]
                before = list(alloc.tables[rid])
                target = x % (usable * cfg.block_size + 4)
                want = cfg.blocks_for(target)
                need = max(0, want - len(before))
                ok = alloc.extend(rid, target)
                after = alloc.tables[rid]
                assert after[: len(before)] == before   # append-only growth
                if want > cfg.max_blocks_per_seq:
                    assert not ok and after == before   # table-bound clamp
                elif ok:
                    assert len(after) == len(before) + need
                    assert len(after) * cfg.block_size >= min(
                        target, len(before) * cfg.block_size)
                else:
                    assert need > 0 and after == before  # dry pool: unchanged
        elif kind == 2 and live:                        # free (+ double-free)
            rid = live.pop(x % (len(live) + 1) - 1)
            table = list(alloc.tables[rid])
            held = len(table)
            # refcount semantics: only blocks whose LAST owner lets go
            # return to the free list
            expect_released = sum(1 for b in table if alloc.refcount[b] == 1)
            free_before = alloc.num_free
            freed = alloc.free(rid)
            assert freed == held
            assert alloc.num_free == free_before + expect_released
            with pytest.raises(KeyError):
                alloc.free(rid)                         # idempotent-by-error
        elif kind == 3 and live:                        # swap_out
            rid = live.pop(x % len(live))
            held = len(alloc.tables[rid])
            assert alloc.swap_out(rid) == held
            assert alloc.swapped[rid] == held
            swapped.append(rid)
        elif kind == 4 and swapped:                     # swap_in
            rid = swapped[x % len(swapped)]
            n = alloc.swapped[rid]
            if n > alloc.num_free:
                with pytest.raises(MemoryError):
                    alloc.swap_in(rid)
                assert alloc.swapped[rid] == n          # still resumable
            else:
                blocks = alloc.swap_in(rid)
                assert len(blocks) == n
                swapped.remove(rid)
                live.append(rid)
        elif kind == 5 and live:                        # share (refcount +1)
            # adopt a donor table's prefix — plus, sometimes, blocks pulled
            # straight off the free list (the revival path: a freed block's
            # refcount restarts at 1 when a new owner adopts it)
            donor = live[x % len(live)]
            blocks = list(alloc.tables[donor][: x % 4])
            n_revive = min((x >> 4) % 3, alloc.num_free)
            blocks += [b for b in alloc._free[:n_revive] if b not in blocks]
            rid = next_rid
            next_rid += 1
            revived = sum(1 for b in blocks if b not in alloc.refcount)
            free_before = alloc.num_free
            alloc.share(rid, blocks)
            assert alloc.num_free == free_before - revived
            assert alloc.tables[rid] == blocks
            live.append(rid)
        elif kind == 6 and live:                        # copy-on-write
            rid = live[x % len(live)]
            table = alloc.tables[rid]
            if table:
                bi = (x >> 4) % len(table)
                src = table[bi]
                is_shared = alloc.refcount[src] > 1
                free_before = alloc.num_free
                if is_shared and alloc.num_free == 0:
                    with pytest.raises(MemoryError):
                        alloc.cow(rid, bi)              # dry: caller preempts
                elif is_shared:
                    old, new = alloc.cow(rid, bi)
                    assert old == src and new != src
                    assert alloc.tables[rid][bi] == new
                    assert alloc.refcount[new] == 1
                    # the old block keeps its other owners — nothing freed
                    assert alloc.refcount[old] >= 1
                    assert alloc.num_free == free_before - 1
                else:
                    assert alloc.cow(rid, bi) is None   # private: no copy
                    assert alloc.tables[rid][bi] == src
        elif kind == 7 and cfg.prefix_sharing:          # prefix index
            if live and x & 1:
                # register a live table's full-block prefixes of the common
                # token stream (first registration wins on collisions)
                rid = live[x % len(live)]
                n_tok = min(len(alloc.tables[rid]) * cfg.block_size,
                            len(TOKENS))
                alloc.register_prefix(rid, TOKENS, n_tok)
            else:
                # admit an adopter through the index: match_prefix + share,
                # reviving any matched block parked on the free list
                m = (x % (usable + 1)) * cfg.block_size
                matched = alloc.match_prefix(TOKENS[:m])
                if matched:
                    rid = next_rid
                    next_rid += 1
                    revived = sum(1 for b in matched
                                  if b not in alloc.refcount)
                    free_before = alloc.num_free
                    alloc.share(rid, matched)
                    assert alloc.num_free == free_before - revived
                    live.append(rid)
        check()

    return alloc


ops_strategy = st.lists(
    st.tuples(st.integers(0, 7), st.integers(0, 1 << 16)), max_size=150)


@given(num_blocks=st.integers(2, 48),
       block_size=st.sampled_from([1, 4, 16]),
       ops=ops_strategy)
@settings(deadline=None)
def test_allocator_invariants_under_random_ops(num_blocks, block_size, ops):
    cfg = KVCacheConfig(num_blocks=num_blocks, block_size=block_size,
                        max_blocks_per_seq=max(1, num_blocks - 1),
                        prefix_sharing=True)
    run_op_sequence(cfg, ops)


@given(ops=ops_strategy)
@settings(deadline=None)
def test_allocator_drains_back_to_full_pool(ops):
    """After any program, releasing every survivor restores the exact free
    pool — no block is ever lost or duplicated across swap round-trips,
    shares, copy-on-writes or index revivals."""
    cfg = KVCacheConfig(num_blocks=17, block_size=4, max_blocks_per_seq=16,
                        prefix_sharing=True)
    alloc = run_op_sequence(cfg, ops)
    for rid in list(alloc.tables):
        alloc.free(rid)
    for rid in list(alloc.swapped):
        del alloc.swapped[rid]
    alloc.check_invariants()
    assert alloc.num_free == cfg.num_blocks - 1
    assert alloc.num_used == 0
