"""Property-based `BlockAllocator` invariants (hypothesis): under arbitrary
interleavings of allocate / extend / free / swap_out / swap_in the allocator
must keep `free + used == total`, never hand a block to two owners, fail
loudly on double-free, and only ever grow a table append-only (`extend`
monotonicity).  `check_invariants()` runs after EVERY operation.

The same interpreter is exercised with a fixed numpy seed (no hypothesis)
from `test_serving_runtime.py`'s churn test; this module is the adversarial
search on top.  CI pins the hypothesis profile via HYPOTHESIS_PROFILE=ci
(registered in conftest.py: derandomized, fixed example budget) so the fast
job is reproducible.
"""

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.serve.kvcache import NULL_BLOCK, BlockAllocator, KVCacheConfig


def run_op_sequence(cfg: KVCacheConfig, ops) -> BlockAllocator:
    """Interpret (kind, x) pairs against a fresh allocator, asserting the
    full invariant set after every operation.  `x` is folded into whatever
    range the chosen operation needs, so any integer sequence is a valid
    program — hypothesis shrinks freely."""
    alloc = BlockAllocator(cfg)
    usable = cfg.num_blocks - 1
    live, swapped = [], []
    next_rid = 1

    def check(extra_free_delta=0):
        alloc.check_invariants()
        assert alloc.num_free + alloc.num_used == usable
        assert sorted(alloc.tables) == sorted(live)
        assert sorted(alloc.swapped) == sorted(swapped)

    for kind, x in ops:
        kind = kind % 5
        if kind == 0:                                   # allocate
            rid = next_rid
            next_rid += 1
            n = x % (alloc.num_free + 2)                # may exceed the pool
            if n > alloc.num_free:
                with pytest.raises(MemoryError):
                    alloc.allocate(rid, n)
            else:
                blocks = alloc.allocate(rid, n)
                assert len(blocks) == n
                assert NULL_BLOCK not in blocks
                live.append(rid)
        elif kind == 1 and live:                        # extend
            rid = live[x % len(live)]
            before = list(alloc.tables[rid])
            target = x % (usable * cfg.block_size + 4)
            need = max(0, cfg.blocks_for(target) - len(before))
            ok = alloc.extend(rid, target)
            after = alloc.tables[rid]
            assert after[: len(before)] == before       # append-only growth
            if ok:
                assert len(after) == len(before) + need
                assert len(after) * cfg.block_size >= min(
                    target, len(before) * cfg.block_size)
            else:
                assert need > 0 and after == before     # dry pool: unchanged
        elif kind == 2 and live:                        # free (+ double-free)
            rid = live.pop(x % (len(live) + 1) - 1)
            held = len(alloc.tables[rid])
            freed = alloc.free(rid)
            assert freed == held
            with pytest.raises(KeyError):
                alloc.free(rid)                         # idempotent-by-error
        elif kind == 3 and live:                        # swap_out
            rid = live.pop(x % len(live))
            held = len(alloc.tables[rid])
            free_before = alloc.num_free
            assert alloc.swap_out(rid) == held
            assert alloc.num_free == free_before + held
            assert alloc.swapped[rid] == held
            swapped.append(rid)
        elif kind == 4 and swapped:                     # swap_in
            rid = swapped[x % len(swapped)]
            n = alloc.swapped[rid]
            if n > alloc.num_free:
                with pytest.raises(MemoryError):
                    alloc.swap_in(rid)
                assert alloc.swapped[rid] == n          # still resumable
            else:
                blocks = alloc.swap_in(rid)
                assert len(blocks) == n
                swapped.remove(rid)
                live.append(rid)
        check()

    return alloc


ops_strategy = st.lists(
    st.tuples(st.integers(0, 4), st.integers(0, 1 << 16)), max_size=150)


@given(num_blocks=st.integers(2, 48),
       block_size=st.sampled_from([1, 4, 16]),
       ops=ops_strategy)
@settings(deadline=None)
def test_allocator_invariants_under_random_ops(num_blocks, block_size, ops):
    cfg = KVCacheConfig(num_blocks=num_blocks, block_size=block_size,
                        max_blocks_per_seq=max(1, num_blocks - 1))
    run_op_sequence(cfg, ops)


@given(ops=ops_strategy)
@settings(deadline=None)
def test_allocator_drains_back_to_full_pool(ops):
    """After any program, releasing every survivor restores the exact free
    pool — no block is ever lost or duplicated across swap round-trips."""
    cfg = KVCacheConfig(num_blocks=17, block_size=4, max_blocks_per_seq=16)
    alloc = run_op_sequence(cfg, ops)
    for rid in list(alloc.tables):
        alloc.free(rid)
    for rid in list(alloc.swapped):
        del alloc.swapped[rid]
    alloc.check_invariants()
    assert alloc.num_free == cfg.num_blocks - 1
    assert alloc.num_used == 0
