"""Prefix sharing: refcounted copy-on-write block reuse + packed resumes.

The load-bearing contracts pinned here:

  * `BlockAllocator.extend` CLAMPS at `max_blocks_per_seq` (returns False,
    table untouched) instead of growing a table wider than the compiled
    `table_array` — the old overgrowth broadcast-crashed at dispatch; and
    extending a swapped-out rid raises a clear ValueError, not a bare
    KeyError out of the tables dict;
  * the prefix index + share/CoW lifecycle at the allocator level: full-
    block prompt prefixes keyed first-wins, `match_prefix` walking the
    longest indexed chain, `share` adopting (and REVIVING refcount-0
    blocks parked on the free list), `cow` copying a shared block into a
    private one (the source keeps its other owners — nothing is freed) and
    no-oping on private blocks, with `check_invariants` holding throughout
    and the pool draining back to full;
  * sharing is INVISIBLE to the tokens: with `prefix_sharing=True` a
    workload of requests sharing a hot system prompt emits byte-identical
    streams to the sharing-off engine — greedy AND sampled — while
    committing >= 40% fewer chunk tokens (prefix_hit_tokens is exactly the
    work the chunk lane never did), exercising claim-time CoW via a
    full-prompt match; the compiled-program pins hold (two step
    executables, admission compiles nothing, at most one CoW executable);
  * a resume burst of K swapped requests costs ceil(K / resume_segments)
    commit invocations — ONE commit executable across group sizes (ragged
    groups pad to the full segment count);
  * slow multi-seed Poisson fuzz layering pool-pressure preemption of
    shared-block holders on top of sharing: streams still match the
    sharing-off reference byte for byte.
"""

import math

import numpy as np
import pytest

from repro.configs import get_config
from repro.distributed.sharding import DEFAULT_RULES
from repro.launch.mesh import single_device_mesh
from repro.models import build_model
from repro.serve.kvcache import NULL_BLOCK, BlockAllocator, KVCacheConfig
from repro.serve.runtime import ContinuousEngine, RuntimeConfig
from repro.serve.sampling import SamplingParams

import jax


# -------------------------------------------------------------- allocator
def _cfg(**kw):
    base = dict(num_blocks=16, block_size=4, max_blocks_per_seq=3,
                prefix_sharing=True)
    base.update(kw)
    return KVCacheConfig(**base)


def test_extend_clamps_at_the_table_bound():
    """Growing past `max_blocks_per_seq` must refuse (False) and leave the
    table untouched — the compiled table_array is exactly that wide, so an
    overgrown table would broadcast-crash at the NEXT dispatch, far from
    the bug."""
    alloc = BlockAllocator(_cfg())
    alloc.allocate(1, 2)
    assert alloc.extend(1, 12)                   # 3 blocks: at the bound
    table = list(alloc.tables[1])
    assert len(table) == 3
    free_before = alloc.num_free
    assert not alloc.extend(1, 13)               # 4th block: clamped
    assert alloc.tables[1] == table              # nothing allocated
    assert alloc.num_free == free_before
    alloc.check_invariants()


def test_extend_on_swapped_rid_raises_value_error():
    alloc = BlockAllocator(_cfg())
    alloc.allocate(7, 2)
    alloc.swap_out(7)
    with pytest.raises(ValueError, match="swap"):
        alloc.extend(7, 9)
    alloc.swap_in(7)
    assert alloc.extend(7, 9)                    # alive again: grows fine
    alloc.check_invariants()


def test_prefix_index_share_cow_and_revival():
    """The full allocator-level lifecycle: register -> match -> share ->
    CoW -> free -> revive-from-free-list, invariants after every move."""
    cfg = _cfg(max_blocks_per_seq=8)
    alloc = BlockAllocator(cfg)
    tokens = np.arange(100, 116, dtype=np.int32)     # 16 tokens = 4 blocks

    b = alloc.allocate(1, 4)
    alloc.register_prefix(1, tokens, 16)
    assert alloc.match_prefix(tokens[:12]) == b[:3]
    assert alloc.match_prefix(tokens[:11]) == b[:2]  # partial block ignored
    assert alloc.match_prefix(tokens[:3]) == []      # shorter than a block
    assert alloc.match_prefix(tokens[::-1]) == []    # different content

    # adopter shares the 3-block prefix: refcounts climb, no new blocks
    free_before = alloc.num_free
    alloc.share(2, alloc.match_prefix(tokens[:12]))
    assert alloc.num_free == free_before
    assert [alloc.refcount[x] for x in b] == [2, 2, 2, 1]
    alloc.check_invariants()

    # CoW: the adopter's first block copies; the source keeps its owner
    old, new = alloc.cow(2, 0)
    assert old == b[0] and new != old
    assert alloc.tables[2][0] == new
    assert alloc.refcount[old] == 1 and alloc.refcount[new] == 1
    assert alloc.cow(2, 0) is None               # now private: no copy
    assert alloc.drain_cow_copies() == 1
    alloc.check_invariants()

    # registrant leaves: only its now-sole-owned blocks return to the free
    # list (b[1], b[2] survive through rid 2), index entries persist
    free_before = alloc.num_free
    alloc.free(1)
    assert alloc.num_free == free_before + 2     # b[0], b[3] released
    alloc.check_invariants()

    # a full-prefix match REVIVES the freed-but-indexed blocks off the
    # free list: refcount restarts at 1, free count drops by the revivals
    matched = alloc.match_prefix(tokens[:16])
    assert matched == [b[0], b[1], b[2], b[3]]
    free_before = alloc.num_free
    alloc.share(3, matched)
    assert alloc.num_free == free_before - 2     # b[0], b[3] revived
    assert alloc.refcount[b[0]] == 1 and alloc.refcount[b[3]] == 1
    assert alloc.refcount[b[1]] == 2 and alloc.refcount[b[2]] == 2
    alloc.check_invariants()

    # first-wins: re-registering the same prefixes changes nothing
    index_before = dict(alloc._index)
    alloc.register_prefix(3, tokens, 16)
    assert alloc._index == index_before

    # drain: every owner released -> the pool is whole again
    alloc.free(2)
    alloc.free(3)
    alloc.check_invariants()
    assert alloc.num_used == 0
    assert alloc.num_free == cfg.num_blocks - 1


def test_prefix_index_disabled_without_the_flag():
    alloc = BlockAllocator(_cfg(prefix_sharing=False))
    tokens = np.arange(16, dtype=np.int32)
    alloc.allocate(1, 4)
    alloc.register_prefix(1, tokens, 16)         # no-op when disabled
    assert alloc.match_prefix(tokens[:8]) == []
    assert not alloc._index
    alloc.check_invariants()


def test_cow_on_a_dry_pool_raises_memory_error():
    alloc = BlockAllocator(_cfg(num_blocks=3, max_blocks_per_seq=2))
    b = alloc.allocate(1, 2)
    alloc.share(2, b)                            # both blocks shared
    assert alloc.num_free == 0
    with pytest.raises(MemoryError):
        alloc.cow(2, 0)                          # caller preempts + retries
    alloc.check_invariants()
    assert alloc.tables[2] == b                  # nothing half-applied


# -------------------------------------------------------------- engine e2e
@pytest.fixture(scope="module")
def tiny_lm():
    cfg = get_config("qwen3-1.7b").reduced(n_layers=2, d_model=64, d_ff=128,
                                           vocab=97)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(model, params, *, chunk_tokens, chunk_segments=4, num_blocks=None,
            max_slots=4, now_fn=None, max_new=10, prefix_sharing=False):
    return ContinuousEngine(
        model, params, single_device_mesh(), DEFAULT_RULES,
        RuntimeConfig(max_slots=max_slots, block_size=8, max_blocks_per_seq=6,
                      num_blocks=num_blocks, max_new_tokens=max_new,
                      chunk_tokens=chunk_tokens,
                      chunk_segments=chunk_segments,
                      prefix_sharing=prefix_sharing),
        now_fn=now_fn)


def _system_prompt_workload(cfg, rng):
    """A hot 24-token (3 full blocks at block_size=8) system prompt: one
    registrant, one EXACT full-prompt duplicate (forces claim-time CoW on
    the last shared block), several suffixed variants, one unrelated
    prompt.  The registrant arrives alone; the duplicate arrives while the
    registrant still HOLDS its blocks (so its final-token chunk lands in a
    block with two owners — the copy-on-write case, not a sole-owner
    revival); the rest arrive after both retire, adopting through the
    index by reviving the freed-but-keyed blocks."""
    system = rng.integers(0, cfg.vocab, size=24).astype(np.int32)
    tails = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
             for n in (6, 3, 5, 7, 4)]
    prompts = [np.concatenate([system, tails[0]]),          # registrant
               system.copy(),                               # exact match
               np.concatenate([system, tails[1]]),
               np.concatenate([system, tails[2]]),
               np.concatenate([system, tails[3]]),
               np.concatenate([system, tails[4]]),
               rng.integers(0, cfg.vocab, size=7).astype(np.int32)]
    arrivals = [0.0, 0.3] + [2.0 + 0.01 * i for i in range(len(prompts) - 2)]
    budgets = [int(rng.integers(3, 9)) for _ in prompts]
    return prompts, arrivals, budgets


@pytest.mark.parametrize("sampled", [False, True], ids=["greedy", "sampled"])
def test_prefix_sharing_identity_and_chunk_token_savings(tiny_lm, sampled):
    """Fast differential: sharing-on and sharing-off engines replay the
    same system-prompt workload under the same virtual clock and must emit
    byte-identical streams (greedy and sampled), while the sharing engine
    commits >= 40% fewer chunk tokens, adopts every saved token through
    the prefix index (committed + adopted == total prompt tokens), and
    copy-on-writes at least once (the exact-duplicate prompt's last shared
    block).  Program pins: two step executables, at most one CoW
    executable, zero commit compiles (no preemption here), admission
    compiles nothing."""
    cfg, model, params = tiny_lm
    rng = np.random.default_rng(3)
    prompts, arrivals, budgets = _system_prompt_workload(cfg, rng)

    def replay(prefix_sharing):
        clock = {"t": 0.0}
        eng = _engine(model, params, chunk_tokens=16,
                      now_fn=lambda: clock["t"],
                      prefix_sharing=prefix_sharing)
        for i, (p, a, b) in enumerate(zip(prompts, arrivals, budgets)):
            eng.submit(p, max_new_tokens=b, arrival_time=a,
                       sampling=(SamplingParams(temperature=0.8, top_k=12,
                                                seed=101 + i)
                                 if sampled else None))
        with eng.mesh:
            while eng.scheduler.has_work:
                ran = eng.step()
                clock["t"] += 0.2 if ran else 0.05
        assert eng._unified._cache_size() == 1
        assert eng._decode_only._cache_size() == 1
        assert eng._commit._cache_size() == 0      # nothing was preempted
        assert eng._cow._cache_size() <= 1
        eng.cache.alloc.check_invariants()
        assert eng.cache.alloc.num_used == 0
        return eng, {r.rid: r.output for r in eng._done}

    off, out_off = replay(prefix_sharing=False)
    on, out_on = replay(prefix_sharing=True)
    assert out_on == out_off

    total = sum(len(p) for p in prompts)
    assert off.metrics.chunk_tokens_committed == total
    assert off.metrics.prefix_hit_tokens == 0
    assert off.metrics.cow_copies == 0
    # every prompt token is either committed by the chunk lane or adopted
    # from the index — and the hot prefix makes adoption the bulk of it
    mon = on.metrics
    assert mon.prefix_hit_tokens > 0
    assert mon.chunk_tokens_committed + mon.prefix_hit_tokens == total
    assert mon.chunk_tokens_committed <= 0.6 * total
    # the exact-duplicate prompt re-commits its final token into a shared
    # block -> claim-time copy-on-write ran, on the compiled copy program
    assert mon.cow_copies >= 1
    assert on._cow._cache_size() == 1


def test_resume_burst_packs_commit_invocations(tiny_lm):
    """A burst of K swapped requests resumes in ceil(K / resume_segments)
    commit invocations — ONE commit executable across ragged group sizes
    (groups pad to the full segment count) — and the preempted streams
    still match an undisturbed engine's byte for byte."""
    cfg, model, params = tiny_lm
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab, size=int(n)).astype(np.int32)
               for n in (9, 6, 12)]

    def fresh(**kw):
        eng = _engine(model, params, chunk_tokens=16, chunk_segments=2,
                      max_slots=3, max_new=6, **kw)
        for p in prompts:
            eng.submit(p, max_new_tokens=6, arrival_time=0.0)
        return eng

    eng = fresh()
    assert eng.adapter.resume_segments == 2
    with eng.mesh:
        # run until every request is in the decode batch, then swap ALL
        # of them out — the next step re-admits the burst together
        while any(r is None or r.prefilling for r in eng.scheduler.slots):
            eng.step()
        for req in [r for r in eng.scheduler.slots if r is not None]:
            eng._preempt(req)
        assert eng.metrics.preemptions == 3
        assert all(r is None for r in eng.scheduler.slots)
        eng.step()                                  # resume burst: [2, 1]
        assert eng.metrics.resume_commits == math.ceil(3 / 2) == 2
        assert eng.metrics.packed_resumes == 2      # only the shared pair
        assert eng._commit._cache_size() == 1       # padded: ONE shape
        while eng.scheduler.has_work:
            eng.step()
    assert eng._commit._cache_size() == 1
    eng.cache.alloc.check_invariants()
    assert eng.cache.alloc.num_used == 0

    base = fresh()
    with base.mesh:
        while base.scheduler.has_work:
            base.step()
    assert base.metrics.resume_commits == 0
    assert {r.rid: r.output for r in eng._done} \
        == {r.rid: r.output for r in base._done}


# ------------------------------------------------------------- slow fuzz
@pytest.mark.slow
def test_differential_fuzz_prefix_sharing_under_pressure(tiny_lm):
    """Slow differential fuzz: Poisson arrival traces where most requests
    share a hot 16-token system prompt (mixed greedy/sampled), replayed
    through sharing-off, sharing-on, and sharing-on-under-pool-pressure
    engines on the same virtual clock.  Streams must match byte for byte
    across seeds — including runs whose shrunken pool preempts requests
    HOLDING shared blocks — with the usual program pins, invariants and
    full drain."""
    cfg, model, params = tiny_lm
    for seed in range(3):
        rng = np.random.default_rng(seed)
        n = 10
        system = rng.integers(0, cfg.vocab, size=16).astype(np.int32)
        arrivals = np.cumsum(rng.exponential(0.3, size=n))
        prompts, sampling = [], []
        for i in range(n):
            if rng.random() < 0.7:
                tail = rng.integers(0, cfg.vocab,
                                    size=int(rng.integers(1, 9)))
                prompts.append(np.concatenate([system, tail.astype(np.int32)]))
            else:
                prompts.append(rng.integers(
                    0, cfg.vocab, size=int(rng.integers(3, 13)))
                    .astype(np.int32))
            sampling.append(SamplingParams(temperature=0.7, top_k=16,
                                           seed=1000 + i)
                            if i % 3 == 0 else None)
        budgets = [int(rng.integers(2, 12)) for _ in range(n)]

        def replay(prefix_sharing, num_blocks=None):
            clock = {"t": 0.0}
            eng = _engine(model, params, chunk_tokens=6, chunk_segments=4,
                          num_blocks=num_blocks, max_slots=3,
                          now_fn=lambda: clock["t"],
                          prefix_sharing=prefix_sharing)
            for a, p, b, s in zip(arrivals, prompts, budgets, sampling):
                eng.submit(p, max_new_tokens=b, arrival_time=float(a),
                           sampling=s)
            with eng.mesh:
                while eng.scheduler.has_work:
                    ran = eng.step()
                    clock["t"] += 0.2 if ran else 0.05
            assert eng._unified._cache_size() == 1
            assert eng._decode_only._cache_size() <= 1
            assert eng._cow._cache_size() <= 1
            eng.cache.alloc.check_invariants()
            assert eng.cache.alloc.num_used == 0
            return eng, {r.rid: r.output for r in eng._done}

        _, out_off = replay(prefix_sharing=False)
        shared, out_on = replay(prefix_sharing=True)
        assert out_on == out_off, f"shared stream diverged (seed {seed})"
        assert shared.metrics.prefix_hit_tokens > 0, \
            f"no prefix hits (seed {seed})"
        # sharing itself shrinks block demand, so the pressure pool must be
        # tighter than the packing fuzz's to still force preemption
        small, out_small = replay(prefix_sharing=True, num_blocks=8)
        assert out_small == out_off, \
            f"shared+preempted stream diverged (seed {seed})"
        assert small.metrics.preemptions >= 1, f"no preemption (seed {seed})"
