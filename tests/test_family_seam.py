"""Decoder no-op pins for the engine/model-family seam.

`ContinuousEngine.step()` is family-agnostic orchestration (admit ->
schedule -> grow-or-preempt -> dispatch -> retire); everything that knows
what the family's per-request device state IS lives behind the
`FamilyAdapter` resolved at construction (repro.serve.family).  These
tests pin the refactor's contract for the decoder family: the seam added
NOTHING — byte-identical greedy streams to the sequential reference
across preemption and chunked/packed prefill, exactly TWO compiled step
executables, and the family taxonomy stamped on every lifecycle event.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.distributed.sharding import DEFAULT_RULES
from repro.launch.mesh import single_device_mesh
from repro.models import build_model
from repro.serve import (
    ContinuousEngine,
    DecoderFamilyAdapter,
    RuntimeConfig,
    SSMFamilyAdapter,
    TraceRecorder,
    resolve_family_adapter,
)
from repro.serve import traceview


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = get_config("qwen3-1.7b").reduced(n_layers=2, d_model=64, d_ff=128,
                                           vocab=97)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _reference_greedy(model, params, prompt, n_new, max_seq=64):
    toks = jnp.asarray(prompt[None], jnp.int32)
    logits, cache = model.prefill(params, {"tokens": toks}, max_seq=max_seq)
    out = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(n_new - 1):
        nxt = jnp.asarray([[out[-1]]], jnp.int32)
        logits, cache = model.decode_step(params, cache, nxt)
        out.append(int(jnp.argmax(logits[0, -1])))
    return out


# --------------------------------------------------------------- resolver
def test_resolver_picks_the_adapter_by_capability():
    class _Cfg:
        def __init__(self, family):
            self.family = family

    class Paged:
        cfg = _Cfg("decoder")

        def decode_step_paged(self):
            pass

    class SSM:
        cfg = _Cfg("ssm")

        def decode_step_slots(self):
            pass

    class Neither:
        cfg = _Cfg("encdec")

    assert resolve_family_adapter(Paged()) is DecoderFamilyAdapter
    assert resolve_family_adapter(SSM()) is SSMFamilyAdapter
    with pytest.raises(TypeError, match="fixed-batch ServeEngine"):
        resolve_family_adapter(Neither())


def test_ssm_capability_needs_the_slot_entry_points():
    """An ssm-family model WITHOUT the slot-pooled entry points must not be
    routed to the slot adapter (it would fail at dispatch, not resolve)."""
    class _Cfg:
        family = "ssm"

    class SSMNoSlots:
        cfg = _Cfg()

    with pytest.raises(TypeError):
        resolve_family_adapter(SSMNoSlots())


# ------------------------------------------------------------ decoder no-op
def test_decoder_noop_streams_exes_and_family_taxonomy(tiny_lm):
    """The seam is a provable no-op for the decoder family.  One replay
    crosses chunked prefill, segment packing, pool-pressure preemption and
    resume, and must still produce byte-identical greedy streams from
    exactly TWO step executables — with every lifecycle event carrying the
    family tag and the trace audit agreeing with the metrics."""
    cfg, model, params = tiny_lm
    rec = TraceRecorder()
    eng = ContinuousEngine(
        model, params, single_device_mesh(), DEFAULT_RULES,
        RuntimeConfig(max_slots=3, block_size=4, max_blocks_per_seq=8,
                      num_blocks=10, chunk_tokens=8, chunk_segments=2,
                      max_new_tokens=10),
        trace=rec)
    assert eng.family == "decoder"
    assert isinstance(eng.adapter, DecoderFamilyAdapter)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, size=s).astype(np.int32)
               for s in (12, 11, 13, 12)]
    for p in prompts:
        eng.submit(p)
    done = {r.rid: r.output for r in eng.run()}

    for rid, p in enumerate(prompts, start=1):
        assert done[rid] == _reference_greedy(model, params, p, 10)
    # exactly two step executables — the adapter indirection compiled none
    assert eng._unified._cache_size() == 1
    assert eng._decode_only._cache_size() == 1
    # the replay actually crossed the paths the no-op claim covers
    assert eng.metrics.preemptions >= 1
    assert eng.metrics.packed_segments > 0
    eng.cache.alloc.check_invariants()
    assert eng.cache.alloc.num_used == 0

    lifecycle = [e for e in rec.events
                 if e.name in ("submit", "admit", "preempt", "finish",
                               "step_begin", "step_end")]
    assert lifecycle
    assert all(e.fields.get("family") == "decoder" for e in lifecycle)
    assert eng.metrics.family == "decoder"
    report = traceview.audit(
        rec.events, metrics=eng.metrics,
        metadata={"usable_blocks": eng.kv_cfg.num_blocks - 1})
    assert report.ok, report.summary()


def test_engine_delegates_adapter_surface(tiny_lm):
    """The engine's historical attribute surface (step programs, cache,
    kv_cfg) now lives on the adapter but stays reachable off the engine —
    callers and tests written against the pre-seam engine keep working."""
    cfg, model, params = tiny_lm
    eng = ContinuousEngine(
        model, params, single_device_mesh(), DEFAULT_RULES,
        RuntimeConfig(max_slots=2, block_size=8, max_blocks_per_seq=6,
                      max_new_tokens=4))
    assert eng.cache is eng.adapter.cache
    assert eng.kv_cfg is eng.adapter.kv_cfg
    assert eng._unified is eng.adapter._unified
    assert eng._decode_only is eng.adapter._decode_only
    assert eng._commit is eng.adapter._commit
    with pytest.raises(AttributeError):
        eng.not_an_adapter_attr
