"""Segment-packed prefill + the compiled decode-only fast path.

The load-bearing contracts pinned here:

  * the segment-packed prefill Pallas kernel agrees with a from-scratch
    per-segment gather reference at segment boundaries — 2-4 segments,
    ragged lengths, GQA groupings, block_q tiles that straddle segments;
  * packing is invisible to the tokens: a `chunk_segments`-packed engine
    emits byte-identical greedy streams to a single-segment one (PR 4
    behaviour) and to an unlimited one, fast small case + slow multi-seed
    Poisson fuzz including runs under pool pressure (preemption layered on
    packing);
  * the runtime owns EXACTLY TWO step executables — the unified packed
    step and the decode-only fast path — and admission (packed admission
    of several prompts at once included) compiles zero new programs;
  * chunk-less steps dispatch the decode-only program (the chunk-wide idle
    forward is skipped, not masked);
  * satellites: `next_chunks` greedy-fill/ordering semantics, the
    `max_segments` tunable existing only in the prefill_chunk stage's
    template space, `PlanRouter.chunk_segments` falling back to
    single-segment on plans tuned before the segmented kernel, and the
    chunk-lane utilization metrics (`chunk_fill_frac`, `packed_segments`,
    `decode_only_steps`).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.plan import InferencePlan, OpChoice
from repro.core.schedules import AttentionTemplate, OpDesc
from repro.distributed.sharding import DEFAULT_RULES
from repro.launch.mesh import single_device_mesh
from repro.models import build_model
from repro.serve.kvcache import BlockAllocator, KVCacheConfig
from repro.serve.metrics import ServeMetrics
from repro.serve.router import DEFAULT_CHUNK_TOKENS, PlanRouter
from repro.serve.runtime import ContinuousEngine, RuntimeConfig
from repro.serve.scheduler import ContinuousScheduler, ServeRequest


# ------------------------------------------------------------------ kernel
def _packed_reference(q, k_pool, v_pool, seg_tables, seg_info):
    """Per-segment gather + per-row causally-masked softmax, GQA-grouped.
    Rows outside every segment are left as zeros (callers discard them)."""
    c, h, d = q.shape
    bs = k_pool.shape[1]
    hkv = k_pool.shape[2]
    nbt = seg_tables.shape[1]
    out = np.zeros((c, h, d), np.float32)
    for s, (q0, qn, kv0) in enumerate(np.asarray(seg_info)):
        if qn == 0:
            continue
        table = np.asarray(seg_tables)[s]
        k_ctx = np.asarray(k_pool)[table].reshape(nbt * bs, hkv, d)
        v_ctx = np.asarray(v_pool)[table].reshape(nbt * bs, hkv, d)
        qs = np.asarray(q)[q0:q0 + qn].reshape(qn, hkv, h // hkv, d)
        sc = np.einsum("qhgd,khd->hgqk", qs, k_ctx) / np.sqrt(d)
        qpos = kv0 + np.arange(qn)[None, None, :, None]
        kpos = np.arange(nbt * bs)[None, None, None, :]
        sc = np.where(kpos <= qpos, sc, -1e30)
        p = np.exp(sc - sc.max(-1, keepdims=True))
        p = p / p.sum(-1, keepdims=True)
        o = np.einsum("hgqk,khd->qhgd", p, v_ctx)
        out[q0:q0 + qn] = o.reshape(qn, h, d)
    return out


@pytest.mark.parametrize("seg_lens,kv_starts,block_q", [
    ((5, 9), (7, 0), None),          # 2 segments, one resuming mid-prompt
    ((3, 4, 2), (0, 11, 5), 4),      # 3 ragged segments, tiles straddle
    ((6, 1, 8, 3), (2, 0, 9, 0), 8),  # 4 segments incl. a 1-token one
    ((11,), (13,), 4),               # single segment (PR 4 shape)
])
def test_packed_prefill_kernel_matches_gather_reference(seg_lens, kv_starts,
                                                        block_q):
    """`flash_prefill_paged` (via the packed ops wrapper) must agree with a
    per-segment gather reference at segment boundaries: every row attends
    to its OWN request's committed rows only, causally, whatever block_q
    tiling cuts across the segment layout."""
    from repro.kernels import ops as K
    rng = np.random.default_rng(11)
    h, hkv, d, bs, nbt, nb = 4, 2, 16, 8, 6, 32
    c = sum(seg_lens) + 2                      # two trailing padding rows
    ns = len(seg_lens)
    q = jnp.asarray(rng.standard_normal((1, c, h, d)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((nb, bs, hkv, d)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((nb, bs, hkv, d)), jnp.float32)
    # disjoint per-segment tables (distinct requests own distinct blocks)
    perm = rng.permutation(np.arange(1, nb))
    seg_tables = np.asarray(perm[:ns * nbt]).reshape(ns, nbt).astype(np.int32)
    q0s = np.concatenate([[0], np.cumsum(seg_lens)[:-1]])
    seg_info = np.stack([q0s, seg_lens, kv_starts], axis=1).astype(np.int32)

    cfg = {"block_q": block_q} if block_q else None
    out = K.attention_prefill_packed(q, kp, vp, jnp.asarray(seg_tables),
                                     jnp.asarray(seg_info), config=cfg)
    ref = _packed_reference(q[0], kp, vp, seg_tables, seg_info)
    got = np.asarray(out[0])
    for q0, qn, _ in seg_info:                 # compare real rows only
        np.testing.assert_allclose(got[q0:q0 + qn], ref[q0:q0 + qn],
                                   rtol=2e-5, atol=2e-5)


def test_packed_row_map_assigns_rows_to_their_segments():
    from repro.models.attention import packed_row_map
    seg_info = np.asarray([[0, 3, 7], [3, 2, 0], [5, 0, 0], [5, 0, 0]],
                          np.int32)
    sid, pos, valid = jax.jit(lambda i: packed_row_map(i, 8))(seg_info)
    assert list(np.asarray(sid)[:5]) == [0, 0, 0, 1, 1]
    assert list(np.asarray(pos)) == [7, 8, 9, 0, 1, 0, 0, 0]
    assert list(np.asarray(valid)) == [True] * 5 + [False] * 3


# --------------------------------------------------------------- scheduler
def _scheduler(max_slots=3):
    kv_cfg = KVCacheConfig(num_blocks=64, block_size=4, max_blocks_per_seq=8)
    return ContinuousScheduler(max_slots, kv_cfg, BlockAllocator(kv_cfg))


def _req(rid, plen, max_new=4):
    return ServeRequest(rid=rid, prompt=np.arange(plen, dtype=np.int32),
                        max_new_tokens=max_new, arrival_time=0.0)


def test_next_chunks_greedy_fill_oldest_first():
    """Budget is packed oldest-admission-first: the head request may split
    mid-prompt, later requests ride in whatever budget remains (the tail
    segment splitting too), and `max_segments` caps the packing."""
    sched = _scheduler()
    for rid, plen in ((1, 10), (2, 3), (3, 5)):
        sched.submit(_req(rid, plen))
    sched.admit(now=0.0)

    chunks = sched.next_chunks(12, max_segments=4)
    assert [(c[0].rid, c[1], c[2]) for c in chunks] == [(1, 0, 10), (2, 0, 2)]
    for req, start, n in chunks:
        req.prefilled = start + n

    # head finished, the split request resumes at its split point
    chunks = sched.next_chunks(12, max_segments=4)
    assert [(c[0].rid, c[1], c[2]) for c in chunks] == [(2, 2, 1), (3, 0, 5)]

    # max_segments=1 restores the PR 4 single-chunk pick
    assert [(c[0].rid, c[1], c[2]) for c in sched.next_chunks(12, 1)] \
        == [(2, 2, 1)]
    # and no pending prompt work -> empty
    for req, start, n in sched.next_chunks(12, 4):
        req.prefilled = start + n
    assert sched.next_chunks(12, 4) == []


# -------------------------------------------------------------- engine e2e
@pytest.fixture(scope="module")
def tiny_lm():
    cfg = get_config("qwen3-1.7b").reduced(n_layers=2, d_model=64, d_ff=128,
                                           vocab=97)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(model, params, *, chunk_tokens, chunk_segments=4, num_blocks=None,
            max_slots=4, now_fn=None, router=None, max_new=10):
    return ContinuousEngine(
        model, params, single_device_mesh(), DEFAULT_RULES,
        RuntimeConfig(max_slots=max_slots, block_size=8, max_blocks_per_seq=6,
                      num_blocks=num_blocks, max_new_tokens=max_new,
                      chunk_tokens=chunk_tokens,
                      chunk_segments=chunk_segments),
        router=router, now_fn=now_fn)


def test_packed_vs_single_segment_identity_and_two_step_programs(tiny_lm):
    """Fast differential: a chunk_segments=4 engine, a single-segment one
    and an unlimited-budget one must emit byte-identical greedy streams;
    every engine owns EXACTLY two compiled step programs (unified +
    decode-only) with zero admission-time compiles; and the packed engine
    demonstrably packed (packed_segments > 0) while dispatching the
    decode-only fast path on chunk-less steps."""
    cfg, model, params = tiny_lm
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=int(rng.integers(3, 14)))
               .astype(np.int32) for _ in range(7)]
    budgets = [int(rng.integers(2, 10)) for _ in prompts]

    outs, engines = {}, {}
    for label, ct, segs in (("packed", 8, 4), ("single", 8, 1),
                            ("unlimited", None, 4)):
        eng = _engine(model, params, chunk_tokens=ct, chunk_segments=segs)
        with eng.mesh:
            for p, b in zip(prompts, budgets):
                eng.submit(p, max_new_tokens=b, arrival_time=0.0)
            eng.step()                           # warm: the unified program
            n_uni = eng._unified._cache_size()
            while eng.scheduler.has_work:
                eng.step()
        # exactly two step executables, each compiled exactly once, and
        # admission mid-run compiled nothing new
        assert eng._unified._cache_size() == n_uni == 1, label
        assert eng._decode_only._cache_size() == 1, label
        assert eng.metrics.decode_only_steps > 0, label
        outs[label] = {r.rid: r.output for r in eng._done}
        engines[label] = eng
        eng.cache.alloc.check_invariants()
        assert eng.cache.alloc.num_used == 0

    assert outs["packed"] == outs["single"] == outs["unlimited"]
    # the packed engine really packed: several requests' segments shared a
    # step, the single-segment engine never did, and packing bought strictly
    # fewer chunk-carrying steps for the same committed tokens
    mp, ms = engines["packed"].metrics, engines["single"].metrics
    assert mp.packed_segments > 0 and ms.packed_segments == 0
    assert mp.chunk_tokens_committed == ms.chunk_tokens_committed \
        == sum(len(p) for p in prompts)
    assert mp.chunk_steps < ms.chunk_steps
    assert mp.chunk_fill_frac() > ms.chunk_fill_frac()


def test_decode_only_fast_path_dispatches_on_chunkless_steps(tiny_lm):
    """Once a prompt is fully committed the remaining steps carry no chunk
    work and must run the decode-only program — counted by the metric the
    CI bench guard watches."""
    cfg, model, params = tiny_lm
    eng = _engine(model, params, chunk_tokens=8, max_new=6)
    rng = np.random.default_rng(4)
    eng.submit(rng.integers(0, cfg.vocab, size=5).astype(np.int32),
               arrival_time=0.0)
    with eng.mesh:
        eng.step()
        assert eng.metrics.chunk_steps == 1          # prompt fit one chunk
        assert eng.metrics.decode_only_steps == 0    # chunk lane ran
        while eng.scheduler.has_work:
            eng.step()
    # budget 6: 1 token from the completing chunk + 5 decode-only steps
    assert eng.metrics.decode_only_steps == 5
    assert eng._decode_only._cache_size() == 1
    assert len(eng._done) == 1 and len(eng._done[0].output) == 6


# ------------------------------------------------------- router / template
def test_max_segments_is_tuned_only_for_the_chunk_stage():
    """The attention template races `max_segments` (the segmented kernel's
    packing-grid axis) only for prefill_chunk-stage ops — decode/prefill
    spaces are unchanged."""
    t = AttentionTemplate()
    chunk_op = OpDesc.attention(1, 32, 96, 4, 64,
                                label="prefill_chunk.attention")
    assert "max_segments" in t.space(chunk_op)
    assert all(m <= 32 for m in t.space(chunk_op)["max_segments"])
    for label in ("decode.attention", "prefill.attention"):
        assert "max_segments" not in t.space(
            OpDesc.attention(1, 32, 96, 4, 64, label=label))
    # configs with the extra axis still validate (descriptors are scalars)
    cfg = {"block_q": 128, "block_kv": 128, "max_segments": 4}
    assert t.validate(chunk_op, cfg)


def test_chunk_segments_router_fallback():
    """No plan -> the engine's default packs; a plan whose prefill_chunk
    attention choice raced `max_segments` -> the tuned width; a PALLAS
    config tuned BEFORE the segmented kernel existed -> single-segment;
    an XLA choice (packing-invariant lane, nothing tuned to protect) ->
    the engine's default, whatever the plan's age."""
    assert PlanRouter(None).chunk_segments(default=8) == 8

    new_plan = InferencePlan("serve", "tpu_v5e")
    new_plan.choices["prefill_chunk.attention"] = OpChoice(
        "pallas_attention", {"block_q": 16, "block_kv": 32,
                             "max_segments": 2}, 1e-4)
    assert PlanRouter(new_plan).chunk_segments(default=8) == 2

    old_plan = InferencePlan("serve", "tpu_v5e")
    old_plan.choices["prefill_chunk.attention"] = OpChoice(
        "pallas_attention", {"block_q": 16, "block_kv": 32}, 1e-4)
    assert PlanRouter(old_plan).chunk_segments(default=8) == 1
    # prefill-only PALLAS plans (pre-chunk-stage) are old a fortiori
    older = InferencePlan("serve", "tpu_v5e")
    older.choices["prefill.attention"] = OpChoice(
        "pallas_attention", {"block_q": 16, "block_kv": 32}, 1e-4)
    assert PlanRouter(older).chunk_segments(default=8) == 1
    # an xla winner never caps packing — the gather lane is per-row
    # identical at every packing width
    xla_plan = InferencePlan("serve", "tpu_v5e")
    xla_plan.choices["prefill_chunk.attention"] = OpChoice("xla", {}, 1e-4)
    assert PlanRouter(xla_plan).chunk_segments(default=8) == 8


def test_old_pallas_plan_caps_engine_packing_to_single_segment(tiny_lm):
    cfg, model, params = tiny_lm
    old_plan = InferencePlan("serve", "tpu_v5e")
    old_plan.choices["prefill_chunk.attention"] = OpChoice(
        "pallas_attention", {"block_q": 8, "block_kv": 32}, 1e-4)
    eng = _engine(model, params, chunk_tokens=8,
                  router=PlanRouter(old_plan))
    assert eng._chunk_segments == 1   # cap sizes the compiled grid itself
    eng2 = _engine(model, params, chunk_tokens=8)
    assert eng2._chunk_segments == eng2.cfg.chunk_segments == 4


def test_max_segments_race_is_measurable_in_the_cost_model():
    """The tunable must not be decided by search-order tie-break: packing
    amortizes the launch overhead across the segments one invocation can
    commit, while the segment grid axis multiplies grid-step issue cost —
    a real, deterministic optimum interior to the space."""
    from repro.core.costmodel import pallas_time
    op = OpDesc.attention(1, 32, 96, 4, 64, label="prefill_chunk.attention")
    base = {"block_q": 128, "block_kv": 128}
    t = {ns: pallas_time(op, dict(base, max_segments=ns))
         for ns in (1, 4, 64)}
    assert t[4] < t[1]          # launch amortization wins at chunk shapes
    assert t[64] > t[1]         # runaway packing drowns in grid steps
    # configs without the key (decode/prefill stages) price as width 1
    assert pallas_time(op, base) == t[1]


def test_chunk_tokens_default_is_the_shared_constant():
    """Satellite: RuntimeConfig's default budget and the serve graph's
    fallback width come from one constant — they can't drift."""
    from repro.serve.router import build_serve_graph
    assert RuntimeConfig().chunk_tokens == DEFAULT_CHUNK_TOKENS
    g = build_serve_graph(get_config("qwen3-1.7b").reduced(
        n_layers=2, d_model=64, d_ff=128, vocab=97),
        prefill_len=48, slots=4, max_seq=96)
    assert g.tensors["x_chunk"].shape[1] == DEFAULT_CHUNK_TOKENS


# ----------------------------------------------------------------- metrics
def test_chunk_lane_utilization_metrics():
    m = ServeMetrics()
    m.record_chunk_step([4, 3], 16)       # packed step: 2 segments, 7/16
    m.record_chunk_step([16], 16)         # full single-segment step
    m.record_decode_only_step()
    assert m.chunk_steps == 2
    assert m.prefill_chunks == 3
    assert m.chunk_tokens_committed == 23
    assert m.packed_segments == 2         # only the shared step's segments
    assert m.decode_only_steps == 1
    assert m.chunk_fill_frac() == pytest.approx(23 / 32)
    s = m.summary()
    assert s["chunk_fill_frac"] == pytest.approx(23 / 32)
    assert s["packed_segments"] == 2.0
    assert s["decode_only_steps"] == 1.0
    assert s["chunk_steps"] == 2.0
    assert ServeMetrics().chunk_fill_frac() == 0.0


# ------------------------------------------------------------- slow fuzz
@pytest.mark.slow
def test_differential_fuzz_packed_poisson_traces(tiny_lm):
    """Slow differential fuzz on the Poisson harness: random arrival traces
    replayed through a packed engine, a single-segment engine and an
    unlimited one under the same virtual clock — every per-request greedy
    stream must match across seeds, with exactly two step executables and
    zero admission compiles, including runs where a shrunken pool layers
    preemption on top of packing."""
    cfg, model, params = tiny_lm
    for seed in range(3):
        rng = np.random.default_rng(seed)
        n = 10
        arrivals = np.cumsum(rng.exponential(0.2, size=n))
        prompts = [rng.integers(0, cfg.vocab,
                                size=int(rng.integers(3, 28))).astype(np.int32)
                   for _ in range(n)]
        budgets = [int(rng.integers(2, 14)) for _ in range(n)]

        def replay(chunk_tokens, chunk_segments, num_blocks=None):
            clock = {"t": 0.0}
            eng = _engine(model, params, chunk_tokens=chunk_tokens,
                          chunk_segments=chunk_segments,
                          num_blocks=num_blocks, max_slots=3,
                          now_fn=lambda: clock["t"])
            for a, p, b in zip(arrivals, prompts, budgets):
                eng.submit(p, max_new_tokens=b, arrival_time=float(a))
            with eng.mesh:
                while eng.scheduler.has_work:
                    ran = eng.step()
                    clock["t"] += 0.2 if ran else 0.05
            assert eng._unified._cache_size() == 1
            assert eng._decode_only._cache_size() <= 1
            eng.cache.alloc.check_invariants()
            assert eng.cache.alloc.num_used == 0
            return eng, {r.rid: r.output for r in eng._done}

        _, out_unl = replay(chunk_tokens=None, chunk_segments=4)
        packed, out_p = replay(chunk_tokens=6, chunk_segments=4)
        single, out_s = replay(chunk_tokens=6, chunk_segments=1)
        assert out_p == out_unl, f"packed stream diverged (seed {seed})"
        assert out_s == out_unl, f"single-seg stream diverged (seed {seed})"
        # both engines commit every prompt token; packing (greedy fill) can
        # only reduce the number of chunk-carrying steps
        total = sum(len(p) for p in prompts)
        assert packed.metrics.chunk_tokens_committed == total
        assert single.metrics.chunk_tokens_committed == total
        assert packed.metrics.chunk_steps <= single.metrics.chunk_steps
        small, out_small = replay(chunk_tokens=6, chunk_segments=4,
                                  num_blocks=8)
        assert out_small == out_unl, \
            f"packed+preempted stream diverged (seed {seed})"
        assert small.metrics.preemptions >= 1, f"no preemption (seed {seed})"
