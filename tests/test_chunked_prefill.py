"""Unified token-budget step: chunked prefill shares the step with the
decode batch through the block-table-aware prefill kernel.

The load-bearing contracts pinned here:

  * the chunked-prefill Pallas kernel agrees with a from-scratch gather
    reference at arbitrary chunk offsets, GQA groupings and block_q tiles;
  * chunking is invisible to the tokens: a `chunk_tokens`-limited engine
    emits byte-identical greedy streams to an unlimited one (whole prompt
    in one chunk), fast small case + slow multi-seed Poisson fuzz on the
    PR 3 differential harness;
  * the unified step compiles exactly ONCE — admission (including chunked
    admission of prompts far longer than any compiled-in shape) triggers
    zero new programs;
  * preemption mid-prefill swaps the committed chunks out and resumes the
    prompt where it stopped, still token-identical;
  * satellite regressions: `swap_in_time_s` is its own metric (resume no
    longer inflates `prefill_time_s`), and `run()` no longer re-arms
    `start_time` on virtual-clock replays starting at t=0.0.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.plan import InferencePlan, OpChoice
from repro.distributed.sharding import DEFAULT_RULES
from repro.launch.mesh import single_device_mesh
from repro.models import build_model
from repro.serve.router import PlanRouter
from repro.serve.runtime import ContinuousEngine, RuntimeConfig


# ------------------------------------------------------------------ kernel
def _chunk_reference(q, k_pool, v_pool, table, chunk_start):
    """Gather + per-row causally-masked softmax, GQA-grouped."""
    c, h, d = q.shape
    bs = k_pool.shape[1]
    hkv = k_pool.shape[2]
    nbt = len(table)
    k_ctx = np.asarray(k_pool)[np.asarray(table)].reshape(nbt * bs, hkv, d)
    v_ctx = np.asarray(v_pool)[np.asarray(table)].reshape(nbt * bs, hkv, d)
    qn = np.asarray(q).reshape(c, hkv, h // hkv, d)
    s = np.einsum("qhgd,khd->hgqk", qn, k_ctx) / np.sqrt(d)
    qpos = chunk_start + np.arange(c)[None, None, :, None]
    kpos = np.arange(nbt * bs)[None, None, None, :]
    s = np.where(kpos <= qpos, s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    out = np.einsum("hgqk,khd->qhgd", p, v_ctx)
    return out.reshape(c, h, d)


@pytest.mark.parametrize("chunk_start,block_q", [(0, None), (13, None),
                                                 (13, 4), (24, 8)])
def test_prefill_paged_kernel_matches_gather_reference(chunk_start, block_q):
    """`flash_prefill_paged` (via the ops wrapper) must agree with the XLA
    gather reference at arbitrary chunk offsets and query tilings — the
    generalisation of `flash_decode_paged` from 1 query row to a chunk."""
    from repro.kernels import ops as K
    rng = np.random.default_rng(7)
    c, h, hkv, d, bs, nbt, nb = 11, 4, 2, 16, 8, 6, 16
    q = jnp.asarray(rng.standard_normal((1, c, h, d)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((nb, bs, hkv, d)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((nb, bs, hkv, d)), jnp.float32)
    table = rng.permutation(np.arange(1, nb))[:nbt]
    tables = jnp.asarray(table[None], jnp.int32)

    cfg = {"block_q": block_q} if block_q else None
    out = K.attention_prefill_paged(q, kp, vp, tables,
                                    jnp.asarray(chunk_start, jnp.int32),
                                    jnp.asarray(c, jnp.int32), config=cfg)
    ref = _chunk_reference(q[0], kp, vp, table, chunk_start)
    np.testing.assert_allclose(np.asarray(out[0]), ref, rtol=2e-5, atol=2e-5)


# -------------------------------------------------------------- engine e2e
@pytest.fixture(scope="module")
def tiny_lm():
    cfg = get_config("qwen3-1.7b").reduced(n_layers=2, d_model=64, d_ff=128,
                                           vocab=97)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(model, params, *, chunk_tokens, num_blocks=None, max_slots=3,
            now_fn=None, router=None, max_new=16):
    return ContinuousEngine(
        model, params, single_device_mesh(), DEFAULT_RULES,
        RuntimeConfig(max_slots=max_slots, block_size=8, max_blocks_per_seq=6,
                      num_blocks=num_blocks, max_new_tokens=max_new,
                      chunk_tokens=chunk_tokens),
        router=router, now_fn=now_fn)


def test_chunked_vs_unchunked_identity_and_no_admission_compiles(tiny_lm):
    """Fast differential: a chunk_tokens=5 engine and an unlimited engine
    (whole prompt in one chunk) must emit byte-identical greedy streams,
    and neither may compile ANYTHING after the first step — admission of
    new prompts, of any length, is a pure data update."""
    cfg, model, params = tiny_lm
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=int(rng.integers(4, 24)))
               .astype(np.int32) for _ in range(6)]
    budgets = [int(rng.integers(2, 12)) for _ in prompts]

    outs, engines = {}, {}
    for label, ct in (("chunked", 5), ("unlimited", None)):
        eng = _engine(model, params, chunk_tokens=ct)
        with eng.mesh:
            eng.submit(prompts[0], max_new_tokens=budgets[0])
            eng.step()                          # warm: THE unified program
            n_compiles = eng._unified._cache_size()
            for p, b in zip(prompts[1:], budgets[1:]):
                eng.submit(p, max_new_tokens=b)  # admissions mid-flight
            while eng.scheduler.has_work:
                eng.step()
        assert eng._unified._cache_size() == n_compiles == 1, label
        outs[label] = {r.rid: r.output for r in eng._done}
        engines[label] = eng
        eng.cache.alloc.check_invariants()
        assert eng.cache.alloc.num_used == 0

    assert outs["chunked"] == outs["unlimited"]
    # the chunked engine really split prompts: more chunks than prompts,
    # same committed token total
    m = engines["chunked"].metrics
    assert m.prefill_chunks > len(prompts)
    assert m.chunk_tokens_committed == sum(len(p) for p in prompts)
    assert engines["unlimited"].metrics.prefill_chunks == len(prompts)


def test_chunk_accounting_and_ttft_spans_all_chunks(tiny_lm):
    """A 17-token prompt under a 4-token budget takes ceil(17/4)=5 chunk
    steps; the first token (and TTFT) appears exactly when the LAST chunk
    commits, and decode joins the following step."""
    cfg, model, params = tiny_lm
    clock = {"t": 0.0}

    def now():
        return clock["t"]

    eng = _engine(model, params, chunk_tokens=4, now_fn=now)
    prompt = np.arange(17, dtype=np.int32) % cfg.vocab
    eng.submit(prompt, max_new_tokens=4, arrival_time=0.0)
    with eng.mesh:
        for i in range(1, 6):
            clock["t"] = float(i)
            eng.step()
            req = next(r for r in eng.scheduler.slots if r is not None)
            assert req.prefilled == min(4 * i, 17)
            assert len(req.output) == (1 if req.prefilled == 17 else 0)
        assert eng.metrics.prefill_chunks == 5
        assert eng.metrics.chunk_tokens_committed == 17
        assert req.ttft_s == pytest.approx(5.0)   # spans all five chunks
        clock["t"] = 6.0
        eng.step()
        assert len(req.output) == 2               # joined the decode batch


def test_mid_prefill_preemption_resumes_token_identical(tiny_lm):
    """A request preempted with only part of its prompt committed must swap
    its chunks out, resume, finish the prompt from where it stopped, and
    still match the unconstrained engine byte-for-byte."""
    cfg, model, params = tiny_lm
    rng = np.random.default_rng(5)
    # two quick decoders grow while a 30-token prompt trickles in at 3
    # tokens/step — with this pool their growth preempts the long request
    # at prefilled=15 of 30, i.e. with half its chunks already committed
    prompts = [rng.integers(0, cfg.vocab, size=3).astype(np.int32)
               for _ in range(2)]
    prompts.append(rng.integers(0, cfg.vocab, size=30).astype(np.int32))

    def drive(num_blocks):
        eng = _engine(model, params, chunk_tokens=3, num_blocks=num_blocks,
                      max_new=14)
        for p in prompts:
            eng.submit(p, arrival_time=0.0)
        return eng, eng.run()

    small, done_s = drive(num_blocks=8)
    big, done_b = drive(num_blocks=None)
    assert small.metrics.preemptions >= 1
    long_req = next(r for r in done_s if r.rid == 3)
    assert long_req.preemptions >= 1 and long_req.stall_s > 0
    assert ({r.rid: r.output for r in done_s}
            == {r.rid: r.output for r in done_b})
    assert len(long_req.output) == 14
    assert small._unified._cache_size() == 1
    small.cache.alloc.check_invariants()
    assert small.cache.alloc.num_used == 0


@pytest.mark.slow
def test_differential_fuzz_chunked_poisson_traces(tiny_lm):
    """Slow differential fuzz on the PR 3 Poisson harness: random arrival
    traces replayed through a chunk_tokens-limited engine and an unlimited
    one under the same virtual clock — every per-request greedy stream
    must match across seeds, with zero admission compiles, including runs
    where a shrunken pool layers preemption on top of chunking."""
    cfg, model, params = tiny_lm
    for seed in range(3):
        rng = np.random.default_rng(seed)
        n = 10
        arrivals = np.cumsum(rng.exponential(0.3, size=n))
        prompts = [rng.integers(0, cfg.vocab,
                                size=int(rng.integers(4, 30))).astype(np.int32)
                   for _ in range(n)]
        budgets = [int(rng.integers(2, 16)) for _ in range(n)]

        def replay(chunk_tokens, num_blocks=None):
            clock = {"t": 0.0}
            eng = _engine(model, params, chunk_tokens=chunk_tokens,
                          num_blocks=num_blocks,
                          now_fn=lambda: clock["t"])
            for a, p, b in zip(arrivals, prompts, budgets):
                eng.submit(p, max_new_tokens=b, arrival_time=float(a))
            with eng.mesh:
                while eng.scheduler.has_work:
                    ran = eng.step()
                    clock["t"] += 0.2 if ran else 0.05
            assert eng._unified._cache_size() == 1
            eng.cache.alloc.check_invariants()
            assert eng.cache.alloc.num_used == 0
            return eng, {r.rid: r.output for r in eng._done}

        _, out_unl = replay(chunk_tokens=None)
        chunked, out_ch = replay(chunk_tokens=4)
        assert out_ch == out_unl, f"chunked stream diverged (seed {seed})"
        assert chunked.metrics.prefill_chunks > n
        small, out_small = replay(chunk_tokens=4, num_blocks=8)
        assert out_small == out_unl, \
            f"chunked+preempted stream diverged (seed {seed})"
        assert small.metrics.preemptions >= 1, f"no preemption (seed {seed})"


# ---------------------------------------------------------- router fallback
def test_prefill_chunk_stage_falls_back_to_prefill_choice():
    """Plans tuned before the prefill_chunk stage existed route the chunk
    lane through the prefill stage's choice instead of dropping to
    untuned XLA."""
    plan = InferencePlan("serve", "tpu_v5e")
    plan.choices["prefill.attention"] = OpChoice(
        "pallas_attention", {"block_q": 16, "block_kv": 32}, 1e-4)
    plan.choices["prefill.qkv_proj"] = OpChoice(
        "pallas_matmul", {"bm": 8, "bn": 128, "bk": 128}, 1e-4)
    router = PlanRouter(plan)
    backend, config = router.attention_backend("prefill_chunk")
    assert backend == "pallas_attention"
    assert config["block_q"] == 16
    assert router.matmul_config("prefill_chunk", "qkv_proj")[0] == "pallas_matmul"
    # an explicit prefill_chunk choice wins over the fallback
    plan.choices["prefill_chunk.attention"] = OpChoice("xla", {}, 1e-4)
    assert router.attention_backend("prefill_chunk") == ("xla", {})


# ------------------------------------------------------ satellite: metrics
def test_swap_in_time_not_booked_as_prefill_time(tiny_lm):
    """Regression: `_resume`'s swap-in scatter used to land in
    `prefill_time_s`.  It must now accrue in `swap_in_time_s` only."""
    cfg, model, params = tiny_lm
    eng = _engine(model, params, chunk_tokens=None, max_slots=2, max_new=8)
    rng = np.random.default_rng(1)
    eng.submit(rng.integers(0, cfg.vocab, size=9).astype(np.int32))
    with eng.mesh:
        eng.step()                                   # prefill completes
        req = next(r for r in eng.scheduler.slots if r is not None)
        prefill_s = eng.metrics.prefill_time_s
        assert prefill_s > 0
        assert eng.metrics.swap_in_time_s == 0.0
        eng._preempt(req)                            # force a swap-out
        while eng.scheduler.has_work:                # resume + finish
            eng.step()
    assert eng.metrics.swap_in_time_s > 0
    assert eng.metrics.prefill_time_s == prefill_s   # untouched by resume
    assert len(eng._done) == 1 and len(eng._done[0].output) == 8
    s = eng.metrics.summary()
    assert s["swap_in_time_s"] == eng.metrics.swap_in_time_s


def test_run_keeps_explicit_zero_start_time(tiny_lm):
    """Regression: run() used to re-arm on `start_time == 0.0`, clobbering
    virtual-clock replays that legitimately start at t=0.0.  The unset
    sentinel is None now."""
    cfg, model, params = tiny_lm
    clock = {"t": 3.0}   # the virtual clock is PAST zero when run() starts
    eng = _engine(model, params, chunk_tokens=None,
                  now_fn=lambda: clock["t"])
    rng = np.random.default_rng(2)
    eng.submit(rng.integers(0, cfg.vocab, size=6).astype(np.int32),
               max_new_tokens=3, arrival_time=0.0)
    eng.metrics.start_time = 0.0       # replay measured from t=0.0
    orig_step = eng.step

    def step_and_tick():
        ran = orig_step()
        clock["t"] += 0.5
        return ran

    eng.step = step_and_tick
    eng.run()
    assert eng.metrics.start_time == 0.0          # NOT re-armed to now()
    assert eng.metrics.end_time == clock["t"]
    assert eng.metrics.wall_s == pytest.approx(clock["t"])
    # and the None sentinel still arms lazily when nothing was set
    eng2 = _engine(model, params, chunk_tokens=None,
                   now_fn=lambda: clock["t"])
    eng2.submit(rng.integers(0, cfg.vocab, size=6).astype(np.int32),
                max_new_tokens=2, arrival_time=0.0)
    assert eng2.metrics.start_time is None
    eng2.run()
    assert eng2.metrics.start_time is not None
    assert not math.isnan(eng2.metrics.summary()["tokens_per_s"])
