"""Graph-optimization pass tests: each pass must preserve semantics, and the
full pipeline must be equivalent to the reference execution (hypothesis
property test over randomly generated graphs)."""

import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import Engine, Graph, optimize_graph
from repro.core.passes import (
    common_subexpression_elimination,
    constant_folding,
    dead_code_elimination,
    fuse_operators,
    remove_identities,
    transform_layout,
)


def _exec(g, *inputs):
    return [np.asarray(o, np.float32) for o in Engine(g, None, None, jit=False)(*inputs)]


def test_remove_identities_and_dropout():
    g = Graph("t")
    x = g.add_input("x", (2, 4))
    a = g.add_node("identity", [x], (2, 4))
    b = g.add_node("dropout", [a], (2, 4))
    c = g.add_node("relu", [b], (2, 4))
    g.set_outputs([c])
    g2 = remove_identities(g)
    assert g2.op_histogram() == {"relu": 1}
    xin = jnp.asarray(np.random.randn(2, 4).astype(np.float32))
    np.testing.assert_allclose(_exec(g2, xin)[0], _exec(g, xin)[0])


def test_dce_removes_dead_branch():
    g = Graph("t")
    x = g.add_input("x", (2, 4))
    live = g.add_node("relu", [x], (2, 4))
    g.add_node("gelu", [x], (2, 4))  # dead
    g.set_outputs([live])
    g2 = dead_code_elimination(g)
    assert g2.op_histogram() == {"relu": 1}


def test_cse_merges_duplicates():
    g = Graph("t")
    x = g.add_input("x", (2, 4))
    a = g.add_node("relu", [x], (2, 4))
    b = g.add_node("relu", [x], (2, 4))
    out = g.add_node("add", [a, b], (2, 4))
    g.set_outputs([out])
    g2 = common_subexpression_elimination(g)
    assert g2.op_histogram()["relu"] == 1
    xin = jnp.asarray(np.random.randn(2, 4).astype(np.float32))
    np.testing.assert_allclose(_exec(g2, xin)[0], _exec(g, xin)[0])


def test_constant_folding_folds_static_subgraph():
    g = Graph("t")
    x = g.add_input("x", (2, 4))
    c1 = g.add_constant("c1", np.ones((2, 4), np.float32))
    c2 = g.add_constant("c2", np.full((2, 4), 2.0, np.float32))
    s = g.add_node("add", [c1, c2], (2, 4))       # static
    r = g.add_node("relu", [s], (2, 4))           # static
    out = g.add_node("mul", [x, r], (2, 4))       # dynamic
    g.set_outputs([out])
    g2 = constant_folding(g)
    assert g2.op_histogram() == {"mul": 1}
    xin = jnp.asarray(np.random.randn(2, 4).astype(np.float32))
    np.testing.assert_allclose(_exec(g2, xin)[0], _exec(g, xin)[0])


def test_fusion_conv_bn_relu_single_node():
    rng = np.random.default_rng(0)
    g = Graph("t")
    x = g.add_input("x", (1, 3, 8, 8))
    w = g.add_constant("w", rng.standard_normal((4, 3, 3, 3)).astype(np.float32))
    c = g.add_node("conv2d", [x, w], (1, 4, 8, 8), {"stride": 1, "padding": "SAME"})
    sc = g.add_constant("sc", (rng.random(4) + 0.5).astype(np.float32))
    sh = g.add_constant("sh", rng.standard_normal(4).astype(np.float32))
    b = g.add_node("batch_norm", [c, sc, sh], (1, 4, 8, 8))
    r = g.add_node("relu", [b], (1, 4, 8, 8))
    g.set_outputs([r])
    g2 = fuse_operators(g)
    assert g2.op_histogram() == {"fused_conv2d": 1}
    assert g2.nodes[0].attrs["activation"] == "relu"
    xin = jnp.asarray(rng.standard_normal((1, 3, 8, 8)).astype(np.float32))
    np.testing.assert_allclose(_exec(g2, xin)[0], _exec(g, xin)[0], rtol=1e-4, atol=1e-4)


def test_fusion_never_fuses_multi_consumer():
    g = Graph("t")
    x = g.add_input("x", (2, 4))
    a = g.add_node("relu", [x], (2, 4))
    b = g.add_node("gelu", [a], (2, 4))
    c = g.add_node("tanh", [a], (2, 4))   # second consumer of a
    out = g.add_node("add", [b, c], (2, 4))
    g.set_outputs([out])
    g2 = fuse_operators(g)
    # 'a' feeds two consumers -> must stay
    assert "relu" in g2.op_histogram() or any(
        n.op == "fused_elementwise" and len(g2.consumers(n.outputs[0])) == 2
        for n in g2.nodes)
    xin = jnp.asarray(np.random.randn(2, 4).astype(np.float32))
    np.testing.assert_allclose(_exec(g2, xin)[0], _exec(g, xin)[0], rtol=1e-5, atol=1e-5)


def test_layout_transform_nhwc_equivalence():
    rng = np.random.default_rng(1)
    g = Graph("t")
    x = g.add_input("x", (2, 3, 8, 8))
    w = g.add_constant("w", rng.standard_normal((4, 3, 3, 3)).astype(np.float32) * 0.5)
    c = g.add_node("conv2d", [x, w], (2, 4, 4, 4), {"stride": 2, "padding": "SAME"})
    g.set_outputs([c])
    g2 = transform_layout(g, "NHWC")
    conv = [n for n in g2.nodes if "conv" in n.op][0]
    assert conv.attrs["layout"] == "NHWC"
    xin = jnp.asarray(rng.standard_normal((2, 3, 8, 8)).astype(np.float32))
    np.testing.assert_allclose(_exec(g2, xin)[0], _exec(g, xin)[0], rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- property
_UNARY = ["relu", "gelu", "tanh", "sigmoid", "identity", "dropout"]


@st.composite
def random_graphs(draw):
    """Random elementwise DAGs with occasional constants and matmuls."""
    g = Graph("rand")
    n_in = draw(st.integers(1, 2))
    dim = draw(st.sampled_from([3, 4, 8]))
    tensors = []
    for i in range(n_in):
        tensors.append(g.add_input(f"x{i}", (2, dim)))
    seed = draw(st.integers(0, 2**16))
    rng = np.random.default_rng(seed)
    n_ops = draw(st.integers(1, 8))
    for i in range(n_ops):
        kind = draw(st.sampled_from(["unary", "binary", "const", "matmul"]))
        src = draw(st.sampled_from(tensors))
        if kind == "unary":
            op = draw(st.sampled_from(_UNARY))
            tensors.append(g.add_node(op, [src], g.tensors[src].shape))
        elif kind == "binary":
            other = draw(st.sampled_from(tensors))
            if g.tensors[other].shape == g.tensors[src].shape:
                op = draw(st.sampled_from(["add", "mul", "sub"]))
                tensors.append(g.add_node(op, [src, other], g.tensors[src].shape))
        elif kind == "const":
            c = g.add_constant(g.fresh("c"),
                               rng.standard_normal(g.tensors[src].shape).astype(np.float32))
            tensors.append(g.add_node("add", [src, c], g.tensors[src].shape))
        else:
            w = g.add_constant(g.fresh("w"),
                               (rng.standard_normal((g.tensors[src].shape[-1], dim))
                                * 0.3).astype(np.float32))
            tensors.append(g.add_node("matmul", [src, w],
                                      g.tensors[src].shape[:-1] + (dim,)))
    g.set_outputs([tensors[-1]])
    return g, n_in, dim, seed


@given(random_graphs())
@settings(max_examples=25, deadline=None)
def test_optimize_graph_preserves_semantics(gspec):
    g, n_in, dim, seed = gspec
    rng = np.random.default_rng(seed + 1)
    inputs = [jnp.asarray(rng.standard_normal((2, dim)).astype(np.float32))
              for _ in range(n_in)]
    ref = _exec(g, *inputs)
    gopt = optimize_graph(g, layout=None)
    got = _exec(gopt, *inputs)
    for a, b in zip(ref, got):
        np.testing.assert_allclose(b, a, rtol=1e-4, atol=1e-4)
