"""Plan-driven matmul dispatch: lane registry semantics, tuned-Pallas vs
XLA lane equivalence at serve shapes, and the continuous engine routing its
stage matmuls through a tuned plan without recompiling on admission."""

import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.plan import InferencePlan, OpChoice
from repro.distributed.sharding import DEFAULT_RULES
from repro.kernels import dispatch
from repro.launch.mesh import single_device_mesh
from repro.models import build_model
from repro.models.common import dense
from repro.serve.router import PlanRouter
from repro.serve.runtime import ContinuousEngine, RuntimeConfig

MM_CFG = {"bm": 8, "bn": 128, "bk": 128, "order": "mn", "k_unroll": 1}


def _forced_pallas_plan() -> InferencePlan:
    """A serve plan whose every stage matmul picks the tuned Pallas lane."""
    plan = InferencePlan("serve", "tpu_v5e")
    for stage in ("prefill", "decode", "prefill_chunk"):
        for op in dispatch.MATMUL_ROLES:
            plan.choices[f"{stage}.{op}"] = OpChoice(
                "pallas_matmul", dict(MM_CFG), 1e-4)
    return plan


# ------------------------------------------------------------------ registry
def test_lane_registry_has_both_lanes():
    lanes = dispatch.lanes()
    assert "xla" in lanes and "pallas_matmul" in lanes


def test_unknown_backend_raises_inside_context():
    x = jnp.ones((2, 8))
    w = jnp.ones((8, 8))
    with dispatch.matmul_dispatch({"qkv_proj": ("no_such_lane", {})}):
        with pytest.raises(KeyError, match="no_such_lane"):
            dispatch.dispatch_dense("qkv_proj", x, w)


def test_dense_outside_context_is_plain_matmul():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((3, 5, 16)), jnp.float32)
    p = {"w": jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)}
    assert dispatch.active_table() is None
    np.testing.assert_array_equal(np.asarray(dense(p, x, role="qkv_proj")),
                                  np.asarray(x @ p["w"]))


def test_unnamed_role_falls_back_to_xla_inside_context():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((4, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    table = {"mlp_up": ("pallas_matmul", dict(MM_CFG))}
    with dispatch.matmul_dispatch(table):
        assert dispatch.active_table() == table
        out = dispatch.dispatch_dense("qkv_proj", x, w)   # role not in table
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x @ w))


# -------------------------------------------------- lane equivalence (shapes)
# Serve-shaped stage matmuls of a small DecoderLM: d=64, h=4/hkv=2, hd=16,
# d_ff=128, vocab=97.  Decode: B=slots, L=1.  Prefill: B=1, long L.
_D, _QKV, _FF, _V = 64, (4 + 2 * 2) * 16, 128, 97
SERVE_MATMULS = [
    ("decode.qkv_proj", (4, 1, _D), _QKV, None),
    ("decode.mlp_up", (4, 1, _D), _FF, "silu"),
    ("decode.mlp_down", (4, 1, _FF), _D, None),
    ("decode.lm_head", (4, 1, _D), _V, None),
    ("prefill.qkv_proj", (1, 48, _D), _QKV, None),
    ("prefill.mlp_up", (1, 48, _D), _FF, "silu"),
    ("prefill.mlp_down", (1, 48, _FF), _D, None),
    ("prefill.lm_head", (1, 48, _D), _V, None),
]


@pytest.mark.parametrize("name,xshape,n,act", SERVE_MATMULS,
                         ids=[m[0] for m in SERVE_MATMULS])
def test_tuned_lane_matches_xla_lane_at_serve_shapes(name, xshape, n, act):
    """The paper's race is only sound if every lane computes the same
    function: tuned Pallas vs XLA within f32 tolerance at serve shapes."""
    rng = np.random.default_rng(zlib.crc32(name.encode()))
    x = jnp.asarray(rng.standard_normal(xshape), jnp.float32)
    w = jnp.asarray(rng.standard_normal((xshape[-1], n)), jnp.float32)
    ref = dispatch.xla_lane(x, w, activation=act)
    out = dispatch.pallas_matmul_lane(x, w, config=dict(MM_CFG),
                                      activation=act, interpret=True)
    assert out.shape == ref.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_fused_activation_matches_unfused():
    """activation= in the tuned kernel's epilogue == act(x @ w)."""
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((4, 1, _D)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((_D, _FF)), jnp.float32)
    fused = dispatch.pallas_matmul_lane(x, w, config=dict(MM_CFG),
                                        activation="silu")
    unfused = jax.nn.silu(x @ w)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                               rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------- engine routing
@pytest.fixture(scope="module")
def tiny_f32_lm():
    # float32 so greedy argmax cannot flip on bf16-resolution near-ties
    # between the (equivalent) lanes.
    cfg = get_config("qwen3-1.7b").reduced(n_layers=2, d_model=64, d_ff=128,
                                           vocab=97, dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _drive(model, params, router, prompts):
    eng = ContinuousEngine(
        model, params, single_device_mesh(), DEFAULT_RULES,
        RuntimeConfig(max_slots=2, block_size=8, max_blocks_per_seq=6,
                      max_new_tokens=6),
        router=router)
    with eng.mesh:
        eng.submit(prompts[0])
        eng.step()
        eng.step()
        n_compiles = eng._unified._cache_size()
        eng.submit(prompts[1])              # mid-flight admission
        while eng.scheduler.has_work:
            eng.step()
    # plan-dispatched matmuls active or not, admission compiles nothing new
    assert eng._unified._cache_size() == n_compiles == 1
    eng.cache.alloc.check_invariants()
    return {r.rid: r.output for r in eng._done}


def test_engine_routes_plan_matmuls_both_stages_no_recompile(tiny_f32_lm):
    """With a serve plan whose stage matmul choices all pick pallas_matmul,
    the unified step's chunk lane AND decode lane run the tuned matmuls —
    greedy outputs must match the XLA-lane engine exactly (f32) and the
    unified program must still never recompile across admissions."""
    cfg, model, params = tiny_f32_lm
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=s).astype(np.int32)
               for s in (11, 17)]

    router = PlanRouter(_forced_pallas_plan())
    table = router.matmul_table("decode")
    assert all(b == "pallas_matmul" for b, _ in table.values())

    out_xla = _drive(model, params, PlanRouter(None), prompts)
    out_tuned = _drive(model, params, router, prompts)
    assert out_tuned == out_xla


def test_router_matmul_table_covers_all_roles():
    router = PlanRouter(_forced_pallas_plan())
    for stage in ("prefill", "decode", "prefill_chunk"):
        table = router.matmul_table(stage)
        assert set(table) == set(dispatch.MATMUL_ROLES)
    # planless router: every role on the XLA lane
    bare = PlanRouter(None).matmul_table("decode")
    assert all(choice == ("xla", {}) for choice in bare.values())
