"""InferencePlan persistence + selection determinism + serve-plan routing."""

import numpy as np
import pytest

from repro.core.graph import Graph
from repro.core.plan import InferencePlan, OpChoice
from repro.core.search.tuner import Tuner
from repro.core.selection import select
from repro.serve.router import PlanRouter, build_serve_graph, build_serve_plan
from repro.configs import get_config


def _small_graph() -> Graph:
    g = Graph("unit")
    x = g.add_input("x", (4, 64, 128))
    w = g.add_input("w", (128, 256))
    mm = g.add_node("matmul", [x, w], (4, 64, 256), name="proj")
    q = g.add_input("q", (2, 64, 4, 32))
    k = g.add_input("k", (2, 64, 2, 32))
    att = g.add_node("attention", [q, k, k], (2, 64, 4, 32), name="attn")
    g.set_outputs([mm, att])
    return g


def _fast_tuner(seed: int = 0) -> Tuner:
    return Tuner(methods=("random",), random_budget=8, seed=seed)


# ------------------------------------------------------------- round-trip
def test_plan_save_load_roundtrip(tmp_path):
    plan = InferencePlan("g", "tpu_v5e")
    plan.choices["a"] = OpChoice("pallas_matmul", {"bm": 128, "bn": 128},
                                 1.5e-4, {"xla": 2e-4, "pallas_matmul": 1.5e-4})
    plan.choices["b"] = OpChoice("xla", {}, 3e-5)
    path = tmp_path / "plan.json"
    plan.save(str(path))
    back = InferencePlan.load(str(path))
    assert back.graph_name == plan.graph_name
    assert back.chip == plan.chip
    assert back.to_json() == plan.to_json()
    assert back.choice("a").config == {"bm": 128, "bn": 128}
    assert back.choice("missing") is None
    assert back.total_modeled_time_s() == pytest.approx(
        plan.total_modeled_time_s())


def test_selected_plan_roundtrips_through_json(tmp_path):
    plan = select(_small_graph(), tuner=_fast_tuner())
    path = tmp_path / "plan.json"
    plan.save(str(path))
    back = InferencePlan.load(str(path))
    assert back.to_json() == plan.to_json()


# ----------------------------------------------------------- determinism
def test_select_deterministic_same_seed():
    """Same graph + same tuner seed -> byte-identical plan."""
    p1 = select(_small_graph(), tuner=_fast_tuner(seed=3))
    p2 = select(_small_graph(), tuner=_fast_tuner(seed=3))
    assert p1.to_json() == p2.to_json()


def test_select_covers_all_tunable_nodes():
    plan = select(_small_graph(), tuner=_fast_tuner())
    assert set(plan.choices) == {"proj", "attn"}
    for c in plan.choices.values():
        assert c.modeled_time_s > 0
        assert "xla" in c.candidates  # the vendor lane always raced


# ------------------------------------------------------------ serve plan
def test_serve_graph_has_stage_qualified_nodes():
    cfg = get_config("qwen3-1.7b").reduced(n_layers=2)
    g = build_serve_graph(cfg, prefill_len=32, slots=4, max_seq=64)
    names = {n.name for n in g.nodes}
    for stage in ("prefill", "decode", "prefill_chunk"):
        for op in ("qkv_proj", "attention", "mlp_up", "mlp_down", "lm_head"):
            assert f"{stage}.{op}" in names


def test_serve_graph_tensors_carry_requested_dtype():
    cfg = get_config("qwen3-1.7b").reduced(n_layers=2)
    g = build_serve_graph(cfg, prefill_len=32, slots=4, max_seq=64,
                          dtype="bfloat16")
    assert {t.dtype for t in g.tensors.values()} == {"bfloat16"}


def test_serve_plan_builds_graph_with_plan_dtype(monkeypatch):
    """Regression: build_serve_plan must forward its dtype to
    build_serve_graph — a bf16 plan tuned over a float32 graph shows every
    dtype-sensitive validation the wrong operand widths."""
    import repro.serve.router as R
    cfg = get_config("qwen3-1.7b").reduced(n_layers=2)
    seen = {}
    orig = R.build_serve_graph

    def spy(*args, **kwargs):
        g = orig(*args, **kwargs)
        seen["dtypes"] = {t.dtype for t in g.tensors.values()}
        return g

    monkeypatch.setattr(R, "build_serve_graph", spy)
    R.build_serve_plan(cfg, prefill_len=16, slots=2, max_seq=32,
                       tuner=_fast_tuner(), dtype="bfloat16")
    assert seen["dtypes"] == {"bfloat16"}


def test_router_stage_lookup_and_fallback():
    cfg = get_config("qwen3-1.7b").reduced(n_layers=2)
    plan = build_serve_plan(cfg, prefill_len=32, slots=4, max_seq=64,
                            tuner=_fast_tuner())
    router = PlanRouter(plan)
    for stage in ("prefill", "decode", "prefill_chunk"):
        backend, config = router.attention_backend(stage)
        assert backend in ("xla", "pallas_attention")
        assert isinstance(config, dict)
        backend, config = router.matmul_config(stage, "qkv_proj")
        assert backend in ("xla", "pallas_matmul")
        table = router.matmul_table(stage)
        assert set(table) == {"qkv_proj", "mlp_up", "mlp_down", "lm_head"}
        for b, c in table.values():
            assert b in ("xla", "pallas_matmul")
            assert isinstance(c, dict)
    # every serve op resolved per-stage (5 ops x 3 stages)
    assert len(router.describe()) == 15

    # no plan -> always the XLA lane, never an error
    bare = PlanRouter(None)
    assert bare.attention_backend("decode") == ("xla", {})
    assert bare.matmul_config("prefill") == ("xla", {})
    assert bare.describe() == {}
