"""Continuous-batching runtime: paged KV-cache invariants, scheduler
admission under overload, mid-flight admission without recompilation, and
paged-vs-monolithic decode equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.distributed.sharding import DEFAULT_RULES
from repro.launch.mesh import single_device_mesh
from repro.models import build_model
from repro.serve.kvcache import NULL_BLOCK, BlockAllocator, KVCacheConfig, PagedKVCache
from repro.serve.metrics import percentile
from repro.serve.runtime import ContinuousEngine, RuntimeConfig
from repro.serve.scheduler import ContinuousScheduler, ServeRequest


# ------------------------------------------------------------ block allocator
def test_alloc_free_invariants():
    cfg = KVCacheConfig(num_blocks=9, block_size=4, max_blocks_per_seq=4)
    alloc = BlockAllocator(cfg)
    assert alloc.num_free == 8          # block 0 reserved as null sink
    a = alloc.allocate(1, 3)
    b = alloc.allocate(2, 2)
    assert NULL_BLOCK not in a + b
    assert set(a).isdisjoint(b)
    alloc.check_invariants()
    assert alloc.num_used == 5
    assert alloc.occupancy() == pytest.approx(5 / 8)
    alloc.free(1)
    alloc.check_invariants()
    assert alloc.num_free == 6
    alloc.free(2)
    assert alloc.num_free == 8
    alloc.check_invariants()


def test_alloc_exhaustion_and_double_alloc():
    cfg = KVCacheConfig(num_blocks=5, block_size=4)
    alloc = BlockAllocator(cfg)
    alloc.allocate(1, 3)
    assert not alloc.can_allocate(2)
    with pytest.raises(MemoryError):
        alloc.allocate(2, 2)
    with pytest.raises(ValueError):
        alloc.allocate(1, 1)            # rid already holds blocks
    alloc.check_invariants()


def test_alloc_extend_and_randomized_churn():
    cfg = KVCacheConfig(num_blocks=17, block_size=4, max_blocks_per_seq=8)
    alloc = BlockAllocator(cfg)
    alloc.allocate(7, 1)
    assert alloc.extend(7, 9)           # 9 tokens -> 3 blocks total
    assert len(alloc.tables[7]) == 3
    assert alloc.extend(7, 9)           # no-op growth stays True
    alloc.free(7)

    # seeded random walk over alloc/extend/free/swap (the hypothesis suite
    # in test_kv_alloc_properties.py searches the same space adversarially;
    # this runs even without the optional dependency)
    rng = np.random.default_rng(0)
    live, swapped = [], []
    for step in range(300):
        op = rng.random()
        if live and (op < 0.3 or alloc.num_free < 2):
            rid = live.pop(int(rng.integers(len(live))))
            alloc.free(rid)
            with pytest.raises(KeyError):
                alloc.free(rid)                     # double-free always loud
        elif live and op < 0.45:
            rid = live[int(rng.integers(len(live)))]
            before = list(alloc.tables[rid])
            alloc.extend(rid, int(rng.integers(1, 33)))
            assert alloc.tables[rid][: len(before)] == before
        elif live and op < 0.6:
            rid = live.pop(int(rng.integers(len(live))))
            held = len(alloc.tables[rid])
            free_before = alloc.num_free
            assert alloc.swap_out(rid) == held
            assert alloc.num_free == free_before + held
            swapped.append(rid)
        elif swapped and op < 0.75:
            rid = swapped[int(rng.integers(len(swapped)))]
            if alloc.can_allocate(alloc.swapped[rid]):
                n = alloc.swapped[rid]
                assert len(alloc.swap_in(rid)) == n
                swapped.remove(rid)
                live.append(rid)
        else:
            rid = step + 100
            n = int(rng.integers(1, 4))
            if alloc.can_allocate(n):
                alloc.allocate(rid, n)
                live.append(rid)
        alloc.check_invariants()
    assert swapped or live                          # the walk exercised state


def test_table_array_null_padding():
    cfg = KVCacheConfig(num_blocks=9, block_size=2, max_blocks_per_seq=4)
    cache = PagedKVCache(cfg, n_layers=1, n_kv_heads=1, head_dim=4)
    blocks = cache.alloc.allocate(5, 2)
    arr = cache.table_array([5, None])
    assert arr.shape == (2, 4)
    assert list(arr[0, :2]) == blocks
    assert (arr[0, 2:] == NULL_BLOCK).all()
    assert (arr[1] == NULL_BLOCK).all()


# --------------------------------------------------------------- scheduler
def _req(rid, plen, max_new=4, arrival=0.0):
    return ServeRequest(rid=rid, prompt=np.zeros(plen, np.int32),
                        max_new_tokens=max_new, arrival_time=arrival)


def test_scheduler_admission_under_overload():
    kv = KVCacheConfig(num_blocks=9, block_size=4, max_blocks_per_seq=8)
    alloc = BlockAllocator(kv)
    sched = ContinuousScheduler(max_slots=2, kv_cfg=kv, alloc=alloc)
    for rid in range(1, 7):
        sched.submit(_req(rid, plen=8, max_new=4))   # 3 blocks each
    admitted = sched.admit(now=1.0)
    # 2 slots but only 8 usable blocks -> 2 requests of 3 blocks fit
    assert [r.rid for r in admitted] == [1, 2]
    assert sched.num_waiting == 4
    assert sched.admit(now=2.0) == []                # full: queue, don't fail
    sched.retire(sched.slots[0], now=3.0)
    alloc.check_invariants()
    nxt = sched.admit(now=3.0)
    assert [r.rid for r in nxt] == [3]               # FIFO order preserved
    assert sched.slots[0].rid == 3


def test_scheduler_rejects_oversized_request():
    kv = KVCacheConfig(num_blocks=9, block_size=4, max_blocks_per_seq=2)
    sched = ContinuousScheduler(2, kv, BlockAllocator(kv))
    with pytest.raises(ValueError):
        sched.submit(_req(1, plen=8, max_new=4))     # 12 > max_seq 8


def test_scheduler_rejects_request_larger_than_pool():
    # max_seq allows 5 blocks but the pool only holds 3 usable ones: the
    # request could never be admitted, so submit() must fail fast instead
    # of leaving the engine waiting forever.
    kv = KVCacheConfig(num_blocks=4, block_size=4, max_blocks_per_seq=8)
    sched = ContinuousScheduler(2, kv, BlockAllocator(kv))
    with pytest.raises(ValueError, match="KV blocks"):
        sched.submit(_req(1, plen=16, max_new=4))    # needs 5 > 3 usable


def test_scheduler_defers_future_arrivals():
    kv = KVCacheConfig(num_blocks=9, block_size=4, max_blocks_per_seq=4)
    sched = ContinuousScheduler(2, kv, BlockAllocator(kv))
    sched.submit(_req(1, 4, arrival=5.0))
    assert sched.admit(now=1.0) == []
    assert [r.rid for r in sched.admit(now=5.0)] == [1]


def test_percentile_nearest_rank():
    assert percentile([], 95) == 0.0
    xs = [float(i) for i in range(1, 101)]
    # with n=100 the nearest rank IS the percentile value, exactly
    assert percentile(xs, 50) == 50.0
    assert percentile(xs, 95) == 95.0
    assert percentile(xs, 100) == 100.0


def test_percentile_nearest_rank_small_samples():
    """Regression: the old round(p/100*(n-1)) rounded-interpolation index
    is NOT nearest-rank.  ceil(p/100*n) is: the smallest sample covering at
    least p percent of the distribution."""
    xs = [1.0, 2.0, 3.0, 4.0]
    assert percentile(xs, 25) == 1.0   # ceil(1.0) -> rank 1 (old: rank 2)
    assert percentile(xs, 50) == 2.0
    assert percentile(xs, 75) == 3.0
    assert percentile(xs, 95) == 4.0
    assert percentile([7.0], 95) == 7.0
    assert percentile([1.0, 9.0], 50) == 1.0   # ceil(1.0) -> rank 1
    # p=0 degenerates to the smallest sample, never an index error
    assert percentile(xs, 0) == 1.0


# ------------------------------------------------------------ engine e2e
@pytest.fixture(scope="module")
def tiny_lm():
    cfg = get_config("qwen3-1.7b").reduced(n_layers=2, d_model=64, d_ff=128,
                                           vocab=97)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _reference_greedy(model, params, prompt, n_new, max_seq=64):
    toks = jnp.asarray(prompt[None], jnp.int32)
    logits, cache = model.prefill(params, {"tokens": toks}, max_seq=max_seq)
    out = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(n_new - 1):
        nxt = jnp.asarray([[out[-1]]], jnp.int32)
        logits, cache = model.decode_step(params, cache, nxt)
        out.append(int(jnp.argmax(logits[0, -1])))
    return out


def test_midflight_admission_no_recompile_and_exact_decode(tiny_lm):
    """A request admitted into an in-flight decode batch must (a) not
    trigger recompilation of the unified step program and (b) leave every
    request's greedy output identical to the sequential reference."""
    cfg, model, params = tiny_lm
    eng = ContinuousEngine(
        model, params, single_device_mesh(), DEFAULT_RULES,
        RuntimeConfig(max_slots=2, block_size=8, max_blocks_per_seq=6,
                      max_new_tokens=10))
    rng = np.random.default_rng(0)
    p1 = rng.integers(0, cfg.vocab, size=11).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab, size=17).astype(np.int32)

    with eng.mesh:
        eng.submit(p1)
        for _ in range(4):                 # p1 alone in flight
            eng.step()
        assert eng.scheduler.num_active == 1
        n_compiles = eng._unified._cache_size()
        eng.submit(p2)                     # joins mid-decode
        while eng.scheduler.has_work:
            eng.step()
    assert eng._unified._cache_size() == n_compiles == 1
    done = {r.rid: r.output for r in eng._done}
    assert done[1] == _reference_greedy(model, params, p1, 10)
    assert done[2] == _reference_greedy(model, params, p2, 10)
    eng.cache.alloc.check_invariants()
    assert eng.cache.alloc.num_used == 0   # everything returned to the pool


def test_admitted_request_lifecycle_under_unified_step(tiny_lm):
    """Pinning the documented lifecycle: the step whose chunk completes the
    prompt emits the FIRST token (from the unified program's prefill lane);
    the request joins the decode batch the NEXT step — 1 token after the
    completing step, 2 after the following one."""
    cfg, model, params = tiny_lm
    eng = ContinuousEngine(
        model, params, single_device_mesh(), DEFAULT_RULES,
        RuntimeConfig(max_slots=2, block_size=8, max_blocks_per_seq=6,
                      max_new_tokens=8))
    rng = np.random.default_rng(3)
    eng.submit(rng.integers(0, cfg.vocab, size=9).astype(np.int32))
    with eng.mesh:
        assert eng.step()
        req = next(r for r in eng.scheduler.slots if r is not None)
        assert req.prefilled == req.prompt_len      # 9 <= chunk budget
        assert len(req.output) == 1                 # the prefill-lane token
        assert eng.step()
        assert len(req.output) == 2                 # decode-batch member now


@pytest.mark.slow
def test_poisson_replay_virtual_clock(tiny_lm):
    """Poisson-replay under a virtual clock: the injectable now_fn drives
    scheduling, every request completes, and TTFT/latency are measured in
    virtual seconds (deterministic, no wall-clock sleeps in the numbers)."""
    cfg, model, params = tiny_lm
    clock = {"t": 0.0}
    eng = ContinuousEngine(
        model, params, single_device_mesh(), DEFAULT_RULES,
        RuntimeConfig(max_slots=2, block_size=8, max_blocks_per_seq=6,
                      max_new_tokens=6),
        now_fn=lambda: clock["t"])
    rng = np.random.default_rng(0)
    arrivals = np.cumsum(rng.exponential(0.5, size=8))
    for a in arrivals:
        eng.submit(rng.integers(0, cfg.vocab,
                                size=int(rng.integers(4, 20))).astype(np.int32),
                   max_new_tokens=4, arrival_time=float(a))
    eng.metrics.start_time = 0.0
    with eng.mesh:
        while eng.scheduler.has_work:
            ran = eng.step()
            clock["t"] += 0.25 if ran else 0.05   # virtual step cost
    eng.metrics.end_time = clock["t"]
    done = eng._done
    assert len(done) == 8
    assert all(len(r.output) == 4 for r in done)
    s = eng.metrics.summary()
    assert s["requests"] == 8
    # virtual-clock sanity: every TTFT positive and bounded by the run
    assert all(0 < t <= clock["t"] for t in eng.metrics.ttfts_s)
    assert s["latency_p95_s"] <= clock["t"]
    eng.cache.alloc.check_invariants()


def test_engine_overload_queues_and_completes(tiny_lm):
    """More requests than slots+blocks: extras wait, everyone finishes."""
    cfg, model, params = tiny_lm
    eng = ContinuousEngine(
        model, params, single_device_mesh(), DEFAULT_RULES,
        RuntimeConfig(max_slots=2, block_size=8, max_blocks_per_seq=3,
                      num_blocks=7, max_new_tokens=6))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, size=9).astype(np.int32)
               for _ in range(5)]
    for p in prompts:
        eng.submit(p)
    assert eng.scheduler.num_waiting == 5
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.output) == 6 for r in done)
    s = eng.metrics.summary()
    assert s["requests"] == 5
    assert s["tokens_out"] == 30
    assert 0 < s["cache_occupancy_max"] <= 1.0
    eng.cache.alloc.check_invariants()


def test_serve_engine_wrapper_stats_across_cycles(tiny_lm):
    """Repeated submit/run cycles through the compat wrapper must count
    each request exactly once."""
    from repro.serve import ServeConfig, ServeEngine
    cfg, model, params = tiny_lm
    eng = ServeEngine(model, params, single_device_mesh(), DEFAULT_RULES,
                      ServeConfig(batch_size=2, max_seq=32, max_new_tokens=4))
    rng = np.random.default_rng(0)
    for _ in range(3):
        eng.submit(rng.integers(0, cfg.vocab, size=6))
        eng.submit(rng.integers(0, cfg.vocab, size=6))
        done = eng.run()
        assert len(done) == 2
    assert eng.stats["requests"] == 6
    assert eng.stats["tokens_out"] == 24


def test_paged_pallas_matches_xla_gather():
    """The block-table Pallas kernel must agree with the XLA gather lane
    (f32 pools -> tight tolerance)."""
    from repro.kernels import ops as K
    rng = np.random.default_rng(0)
    b, h, hkv, d, bs, nbt, nb = 3, 4, 2, 16, 8, 4, 16
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((nb, bs, hkv, d)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((nb, bs, hkv, d)), jnp.float32)
    tables = jnp.asarray(
        rng.permutation(np.arange(1, nb))[: b * nbt].reshape(b, nbt))
    lengths = jnp.asarray([5, 19, 32], jnp.int32)

    out = K.attention_decode_paged(q, kp, vp, lengths, tables)

    # reference: gather + masked softmax per KV-head group
    k_ctx = np.asarray(kp)[np.asarray(tables)].reshape(b, nbt * bs, hkv, d)
    v_ctx = np.asarray(vp)[np.asarray(tables)].reshape(b, nbt * bs, hkv, d)
    qn = np.asarray(q).reshape(b, hkv, h // hkv, d)
    s = np.einsum("bhgd,bkhd->bhgk", qn, k_ctx) / np.sqrt(d)
    pos = np.arange(nbt * bs)[None, None, None]
    s = np.where(pos < np.asarray(lengths)[:, None, None, None], s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    ref = np.einsum("bhgk,bkhd->bhgd", p, v_ctx).reshape(b, h, d)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-5, atol=2e-5)
