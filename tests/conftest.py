# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device; only launch/dryrun.py forces 512 host devices.
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest

try:
    # CI runs the property suites under a pinned, derandomized profile
    # (HYPOTHESIS_PROFILE=ci) so the fast job is reproducible run-to-run;
    # local runs keep hypothesis' default randomized search.
    from hypothesis import HealthCheck, settings

    settings.register_profile(
        "ci", max_examples=60, derandomize=True, deadline=None,
        suppress_health_check=[HealthCheck.too_slow])
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "default"))
except ImportError:                       # hypothesis is an optional dev dep
    pass


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
