"""Figure 3b reproduction: genetic-search speed per operator + caching.

Paper: average 8.9 min per ResNet-18 conv (min 1.4, max 27.9) on a real GPU
— the time is dominated by JIT compile + on-device runs.  Here the fitness
is the analytical TPU model, so absolute times are milliseconds; the
*shape* of the result (per-op variance, cache -> near-zero warm time,
"family of models from the same backbone reuse results" §3.3) is what is
reproduced.  With `WallClockFitness` (interpret-mode timing) the same
harness reproduces the minutes-scale behaviour.
"""

import time

from repro.core import SearchCache, Tuner
from repro.models.resnet import conv_groups


def run(csv_rows):
    cache = SearchCache()
    tuner = Tuner(methods=("genetic",), cache=cache)
    cold_times = []
    for name, op in conv_groups(batch=1, image=224):
        t0 = time.perf_counter()
        tuner.tune(op)
        dt = time.perf_counter() - t0
        cold_times.append(dt)
        csv_rows.append((f"search_fig3b_cold_{name}", dt * 1e6,
                         f"evals={tuner.log[-1].evals}"))

    # warm pass — same backbone, §3.3 cache reuse
    t0 = time.perf_counter()
    for name, op in conv_groups(batch=1, image=224):
        tuner.tune(op)
    warm = time.perf_counter() - t0
    csv_rows.append(("search_fig3b_warm_all", warm * 1e6,
                     f"cache_hits={cache.hits} speedup_vs_cold="
                     f"{sum(cold_times) / max(warm, 1e-9):.0f}x "
                     f"(paper: avg 8.9min cold, cache 'further expedites')"))
    return csv_rows
