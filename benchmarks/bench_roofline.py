"""Roofline table from the multi-pod dry-run artifacts (deliverable g).

Per (arch x shape x mesh):
  compute term    = HLO_FLOPs  / (chips * peak_FLOP/s)
  memory term     = HLO_bytes  / (chips * HBM_bw)
  collective term = collective_bytes / (chips * link_bw)
with HLO_FLOPs/bytes taken from the *unrolled cost program*'s
cost_analysis() (per-device values x chips = global), collective bytes
parsed per-device from its optimized HLO.  MODEL_FLOPS = 6*N*D (train,
N=active params for MoE) or 2*N*D (inference) gives the usefulness ratio.
"""

import glob
import json
import os

from repro import hw

CHIP = hw.TPU_V5E


def roofline_from_artifact(d):
    chips = d["n_devices"]
    flops_dev = d["cost"]["flops_per_device"]
    bytes_dev = d["cost"]["bytes_accessed_per_device"]
    coll_dev = d["collectives"]["total_bytes"]
    compute_s = flops_dev / CHIP.peak_bf16_flops
    memory_s = bytes_dev / CHIP.hbm_bw
    collective_s = coll_dev / CHIP.ici_link_bw
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    n = d["active_params"] if d["kind"] == "train" else d["active_params"]
    mult = 6.0 if d["kind"] == "train" else 2.0
    model_flops = mult * n * d["tokens"]
    hlo_global = flops_dev * chips
    bound = max(compute_s, memory_s, collective_s)
    ideal = (model_flops / chips) / CHIP.peak_bf16_flops
    return {
        "arch": d["arch"], "shape": d["shape"], "mesh": d["mesh"],
        **{k: round(v, 6) for k, v in terms.items()},
        "dominant": dominant.replace("_s", ""),
        "model_flops": model_flops,
        "hlo_flops_global": hlo_global,
        "useful_ratio": round(model_flops / max(hlo_global, 1), 3),
        "roofline_fraction": round(ideal / max(bound, 1e-12), 4),
        "peak_gib_per_dev": round(d["memory"]["peak_bytes_per_device"] / 2**30, 2),
        "fits_hbm": d["memory"]["peak_bytes_per_device"] <= CHIP.hbm_bytes,
    }


def run(csv_rows, art_dir="artifacts/dryrun"):
    rows = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            d = json.load(f)
        if d.get("status") != "ok":
            tag = f"{d['arch']}__{d['shape']}__{d.get('mesh', '?')}"
            csv_rows.append((f"roofline_{tag}", 0.0,
                             d.get("reason", d.get("error", "?"))[:100]))
            continue
        r = roofline_from_artifact(d)
        rows.append(r)
        csv_rows.append((
            f"roofline_{r['arch']}__{r['shape']}__{r['mesh']}",
            max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
            f"compute_ms={r['compute_s'] * 1e3:.1f} "
            f"memory_ms={r['memory_s'] * 1e3:.1f} "
            f"collective_ms={r['collective_s'] * 1e3:.1f} "
            f"dominant={r['dominant']} useful={r['useful_ratio']} "
            f"roofline_frac={r['roofline_fraction']} "
            f"gib/dev={r['peak_gib_per_dev']}"))
    return csv_rows


def table(art_dir="artifacts/dryrun"):
    out = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            d = json.load(f)
        if d.get("status") == "ok":
            out.append(roofline_from_artifact(d))
    return out
