"""Serving benchmark: continuous batching + paged KV-cache vs fixed batches.

Both engines serve the SAME workload — Poisson arrivals, mixed prompt
lengths, mixed per-request generation budgets — against the same model and
the same wall clock:

  fixed      — the original `FixedBatchEngine` drain loop driven
               arrival-aware: a batch forms from whatever has arrived,
               prompts pad to the provisioned maximum, and every batch
               decodes the full worst-case token budget (a static-batch
               server cannot stop per-request);
  continuous — `ContinuousEngine`: one unified token-budget step — each
               engine step carries up to `chunk_tokens` of prompt work
               alongside a decode token for EVERY in-flight request, KV
               lives in pages, and each request retires at exactly its
               own budget.

Reported per engine: useful tokens/s (only the tokens each request asked
for count), latency p50/p95 (completion - arrival), and for the continuous
engine TTFT and occupancy.  The paper's §3.4 claim shape (e2e serving
speedup at matched latency) reproduces here as the tokens/s ratio at the
reported p95s.

A third section (`--pressure`) is the pool-pressure sweep: the same Poisson
workload replayed with the KV block pool shrunk to 1.0x / 0.5x / 0.25x of
the worst-case demand (slots x max table width).  Worst-case reservation
simply could not run below 1.0x; on-demand growth + preemption completes
the full workload at every size — the sweep reports tokens/s, p95,
preemption count, swap traffic and stall time per pool size, making the
reservation-vs-preemption trade measurable.

A fourth section (`--interference`) is the PREFILL-INTERFERENCE sweep: a
Poisson mix of long and short prompts replayed through a chunked engine
(`chunk_tokens` budget slices every long prompt across steps) and an
unchunked one (whole prompt in one chunk) under a deterministic virtual
clock whose per-step cost is linear in the tokens the step carries
(c0 + c_tok x (decode rows + chunk tokens)).  The headline number is
decode TIME-BETWEEN-TOKENS p95: every in-flight decoder samples each of
its steps' cost as one inter-token gap, so a prompt monopolizing a step
is a gap spike suffered by the whole decode batch.  Chunking must hold
decode TBT p95 at or below the unchunked engine's while trading a bounded
amount of long-prompt TTFT (their prefill now spans several steps).

A fifth section (`--packing`) is the SEGMENT-PACKING sweep: a short-prompt-
heavy Poisson workload replayed through a packed engine (one step's chunk
carries prompt segments from up to `chunk_segments` requests) and a
single-segment one (each step's chunk carries one request's slice, PR 4
behaviour) under the same virtual clock.  The compiled chunk lane executes
at its full width whenever it runs, so the cost model charges chunk-
carrying steps the LANE WIDTH (not the tokens committed) and decode-only
steps nothing for the lane — which is exactly the game: packing fills the
width with useful prompt tokens (chunk fill fraction -> 1), and the
compiled decode-only fast path skips the lane when there is no prompt
work at all.  Reported per engine: useful tokens/s, TTFT p95, chunk fill
fraction, packed segments and decode-only step counts.

A sixth section (`--prefix`) is the PREFIX-SHARING sweep: a Poisson
workload where most requests begin with one hot system prompt, replayed
with prefix sharing on vs off under the same virtual clock.  Sharing-on
admissions adopt the system prompt's KV blocks from the allocator's
prefix index (refcounted, copy-on-write on divergence) and start prefill
at the first unshared token, so the hot prefix is prefilled once, ever —
reported as chunk tokens committed, prefix-hit tokens, CoW copies,
tokens/s and TTFT p95 per setting (the streams themselves are pinned
byte-identical by the test suite).

A second section (`--lanes`) reports the PER-LANE breakdown of the plan's
stage matmul dispatch: the same Poisson workload replayed through an
xla-only plan, the tuned serve plan (`build_serve_plan` — each stage
matmul raced per the paper's system-level exploration), and a forced
all-Pallas plan, with each run's `PlanRouter.describe()` lane table.  On
this CPU container the Pallas lanes execute in interpret mode, so their
tokens/s is NOT a TPU performance statement — the section demonstrates
observable plan-driven dispatch and measures the xla-vs-tuned delta.

`--family ssm` swaps the model family: the SAME continuous scheduler
serves Mamba2 through the `SSMFamilyAdapter` (fixed-size slot-pooled
conv+SSM state rows instead of paged KV blocks — repro.serve.statecache),
with the state pool provisioned one row short of the slot count so the
replay exercises slot preemption + host swap, vs the arrival-aware
`FixedBatchEngine` drain on the same Poisson workload.  Reported: useful
tokens/s both engines, TTFT p95, preemption count, and a zero-errors
guard (every submitted request must complete).

A TENSOR-PARALLEL sweep (skip with `--no-tp`; needs `--devices 4`)
replays a workload prefix at serving-mesh widths 1x1 / 1x2 / 1x4 —
device-subset meshes over virtual host devices (repro.platform sets
--xla_force_host_platform_device_count before jax imports) — each width
under its own tuned plan (`build_serve_plan(model_parallel=tp)` races
replicated vs model-parallel per stage matmul, pricing the implied
collectives), plus tuned-vs-forced-replicated at the widest mesh.  Token
streams are byte-identical across widths (pinned by
tests/test_tp_serving.py); with fewer than 4 host devices the sweep's
CSV rows emit 0.0 with a "skipped" note so the schema never moves.

`--sampling mixed` gives every headline request per-request
SamplingParams from a fixed cycle (greedy / temperature / temperature+
top-k / temperature+top-p, unique seed each) instead of all-greedy — the
knobs and keys are traced data, so the run still uses the same two
compiled step programs.  A SAMPLED-DIFFERENTIAL section (skip with
`--no-sampled`) always replays a mixed-sampling prefix of the workload
through a fresh continuous engine AND the B=1 fixed drain with aligned
rids: because every token's draw is keyed by (seed, rid, token_index),
the two engines must produce byte-identical sampled streams; any
mismatch is a non-zero exit.

`--trace out.json` additionally records the headline continuous run's
structured event trace (repro.serve.trace): the file is Chrome-trace JSON
(drop it on ui.perfetto.dev for one timeline track per request plus
scheduler/pool tracks), the raw events ride along under the "reproServe"
key, the run's ServeMetrics are cross-validated against the events by
repro.serve.traceview (non-zero exit on any violation), and the report
gains a per-request time-attribution table (queued / prefill / stall /
decode fractions of each request's life).

Run:  PYTHONPATH=src python benchmarks/bench_serving.py [--requests 32]
"""

from __future__ import annotations

import argparse
import time
from collections import deque
from typing import List

# must run before anything imports jax: --devices N asks the CPU backend
# for N virtual host devices, and the backend latches XLA_FLAGS at the
# first jax import (see repro.platform) — the TP mesh sweep needs 4
from repro import platform

platform.configure_from_argv()

import jax
import numpy as np

from repro.configs import get_config
from repro.core.plan import InferencePlan, OpChoice
from repro.core.search.tuner import Tuner
from repro.distributed.sharding import DEFAULT_RULES
from repro.kernels.dispatch import MATMUL_ROLES
from repro.launch.mesh import single_device_mesh, tp_mesh
from repro.models import build_model
from repro.serve import (
    ContinuousEngine,
    FixedBatchEngine,
    PlanRouter,
    RuntimeConfig,
    SamplingParams,
    ServeConfig,
    TraceRecorder,
    build_serve_plan,
    percentile,
    write_trace,
)
from repro.serve import traceview


def mixed_sampling(i: int) -> SamplingParams:
    """Per-request sampling cycle for `--sampling mixed` and the sampled
    differential: greedy / pure temperature / temperature+top-k /
    temperature+top-p, each sampled request with its own seed."""
    r = i % 4
    if r == 0:
        return SamplingParams()
    if r == 1:
        return SamplingParams(temperature=0.8, seed=1000 + i)
    if r == 2:
        return SamplingParams(temperature=1.0, top_k=8, seed=1000 + i)
    return SamplingParams(temperature=0.9, top_p=0.85, seed=1000 + i)


def make_workload(rng: np.random.Generator, n: int, vocab: int, rate_hz: float,
                  prompt_lo: int = 8, prompt_hi: int = 48,
                  new_lo: int = 2, new_hi: int = 32):
    """Poisson arrivals with mixed prompt lengths and generation budgets."""
    gaps = rng.exponential(1.0 / rate_hz, size=n)
    arrivals = np.cumsum(gaps)
    out = []
    for i in range(n):
        plen = int(rng.integers(prompt_lo, prompt_hi + 1))
        out.append({
            "prompt": rng.integers(0, vocab, size=plen).astype(np.int32),
            "max_new": int(rng.integers(new_lo, new_hi + 1)),
            "arrival": float(arrivals[i]),
        })
    return out


# ----------------------------------------------------------------- continuous
def drive_continuous(engine: ContinuousEngine, workload) -> dict:
    t0 = time.perf_counter()
    engine.metrics.start_time = t0
    for w in workload:
        engine.submit(w["prompt"], max_new_tokens=w["max_new"],
                      arrival_time=t0 + w["arrival"],
                      sampling=w.get("sampling"))
    done = engine.run()
    s = engine.metrics.summary()
    return {
        "tokens_per_s": s["tokens_per_s"],
        "latency_p50_s": s["latency_p50_s"],
        "latency_p95_s": s["latency_p95_s"],
        "ttft_p50_s": s["ttft_p50_s"],
        "slot_occupancy": s["slot_occupancy_mean"],
        "cache_occupancy": s["cache_occupancy_mean"],
        "chunk_fill_frac": s["chunk_fill_frac"],
        "packed_segments": int(s["packed_segments"]),
        "decode_only_steps": int(s["decode_only_steps"]),
        "tokens": int(s["tokens_out"]),
        "done": len(done),
    }


# ---------------------------------------------------------------- fixed batch
def drive_fixed(model, params, mesh, cfg: ServeConfig, prompt_pad: int,
                workload) -> dict:
    """Arrival-aware driver around the static drain loop: batches form from
    arrived requests only; prompts pad to the provisioned max; every batch
    decodes the full worst-case budget."""
    eng = FixedBatchEngine(model, params, mesh, DEFAULT_RULES, cfg)

    def pad(p):
        out = np.zeros((prompt_pad,), np.int32)
        out[prompt_pad - len(p):] = p          # static server left-pads
        return out

    # warm the two compiled programs outside the timed region
    eng.submit(pad(workload[0]["prompt"]))
    eng.run()
    eng.stats = {k: 0 if isinstance(v, int) else 0.0
                 for k, v in eng.stats.items()}

    pending = deque(workload)
    latencies: List[float] = []
    useful_tokens = 0
    t0 = time.perf_counter()
    t_last = t0
    while pending:
        now = time.perf_counter() - t0
        batch = []
        while (pending and pending[0]["arrival"] <= now
               and len(batch) < cfg.batch_size):
            batch.append(pending.popleft())
        if not batch:
            time.sleep(min(1e-3, pending[0]["arrival"] - now))
            continue
        for w in batch:
            eng.submit(pad(w["prompt"]), sampling=w.get("sampling"))
        eng.run()
        t_done = time.perf_counter()
        t_last = t_done
        for w in batch:
            latencies.append((t_done - t0) - w["arrival"])
            useful_tokens += w["max_new"]
    wall = max(1e-9, t_last - t0)
    return {
        "tokens_per_s": useful_tokens / wall,
        "latency_p50_s": percentile(latencies, 50),
        "latency_p95_s": percentile(latencies, 95),
        "tokens": useful_tokens,
        "done": len(latencies),
    }


# -------------------------------------------------- per-lane plan breakdown
def _lane_histogram(router: PlanRouter) -> dict:
    hist: dict = {}
    for _, backend in router.describe().items():
        hist[backend] = hist.get(backend, 0) + 1
    return hist


def _forced_pallas_plan(tuned: InferencePlan) -> InferencePlan:
    """The tuned plan with every stage matmul overridden onto the Pallas
    lane (tuned config where the race produced one; {} otherwise, which
    `ops.matmul` fills with the kernel's own aligned defaults)."""
    forced = InferencePlan(tuned.graph_name, tuned.chip)
    for name, c in tuned.choices.items():
        op = name.split(".", 1)[1]
        if op in MATMUL_ROLES:
            cfg = dict(c.config) if c.backend == "pallas_matmul" else {}
            forced.choices[name] = OpChoice("pallas_matmul", cfg,
                                            c.modeled_time_s, dict(c.candidates))
        else:
            forced.choices[name] = c
    return forced


def lane_breakdown(model, params, mesh, cfg, rcfg: RuntimeConfig,
                   workload, verbose: bool = True) -> dict:
    """Replay the same Poisson workload through xla-only / tuned / forced
    Pallas matmul plans — the observable proof that the serve forward pass
    dispatches the plan's stage matmul choices."""
    prompt_hi = max(len(w["prompt"]) for w in workload)
    tuned = build_serve_plan(cfg, prefill_len=prompt_hi, slots=rcfg.max_slots,
                             max_seq=rcfg.max_seq,
                             tuner=Tuner(methods=("random",), random_budget=16))
    plans = {
        "xla-only": None,
        "tuned plan": tuned,
        "forced pallas": _forced_pallas_plan(tuned),
    }
    results = {}
    for label, plan in plans.items():
        router = PlanRouter(plan)
        engine = ContinuousEngine(model, params, mesh, DEFAULT_RULES, rcfg,
                                  router=router)
        warm_engine(engine, cfg.vocab, prompt_hi)
        r = drive_continuous(engine, workload)
        r["lanes"] = _lane_histogram(router)
        results[label] = r
        if verbose:
            matmuls = {k: v for k, v in router.describe().items()
                       if k.split(".", 1)[1] in MATMUL_ROLES}
            lanes = (", ".join(f"{k}={v}" for k, v in sorted(r["lanes"].items()))
                     or "xla (no plan)")
            print(f"{label:14s}: {r['tokens_per_s']:8.1f} tok/s | "
                  f"p95 {r['latency_p95_s']:6.2f}s | lanes: {lanes}")
            if matmuls and label != "xla-only":
                for name, backend in sorted(matmuls.items()):
                    print(f"                 {name:18s} -> {backend}")
    return results


def warm_engine(engine: ContinuousEngine, vocab: int, prompt_hi: int) -> None:
    """Compile THE unified step program outside a timed replay.  One short
    request suffices: chunk geometry is data, so every prompt length —
    longer than any seen here included — reuses the same program."""
    rng = np.random.default_rng(0)
    engine.submit(rng.integers(0, vocab, size=min(8, prompt_hi))
                  .astype(np.int32), max_new_tokens=2)
    engine.run()
    engine.reset_metrics()


# --------------------------------------------------- tensor-parallel sweep
TP_WIDTHS = (1, 2, 4)

# the replicated baseline: DEFAULT_RULES with every model-axis rule the
# serving path shards knocked out, so the engine serves a WIDE mesh with
# fully replicated params and pools (serve_rules only ever narrows, so
# this stays replicated whatever the plan's layout verdicts say)
REPLICATED_RULES = DEFAULT_RULES.replace(
    heads=None, kv_heads=None, ffn=None, experts=None, vocab=None,
    embed_vec=None, ssm_heads=None, conv_dim=None)


def _layout_summary(router: PlanRouter, stage: str = "decode") -> str:
    """Compressed per-stage layout table for CSV derived columns and trace
    metadata: 'attention:mp,lm_head:rep,...'."""
    table = router.layout_table(stage)
    return ",".join(f"{k}:{'mp' if v == 'model_parallel' else 'rep'}"
                    for k, v in sorted(table.items()))


def tp_sweep(model, params, cfg, rcfg: RuntimeConfig, workload,
             verbose: bool = True) -> dict:
    """Tensor-parallel mesh sweep: the same Poisson workload replayed at
    mesh widths 1/2/4 — device-SUBSET meshes (`tp_mesh`), so one
    --xla_force_host_platform_device_count=4 process races all three —
    each width under its own tuned plan (`build_serve_plan(model_parallel=
    tp)`: the layout race prices the implied collectives next to the
    matmul lanes, and the winning per-stage layouts reach the step
    builders through `serve_rules`).  A second leg compares the widest
    mesh's TUNED layouts against a forced-replicated baseline with the
    same backend lanes.  Token streams are byte-identical across widths
    (pinned by tests/test_tp_serving.py); on this CPU container the
    tokens/s deltas measure dispatch/collective overhead on virtual
    devices, not TPU interconnect behaviour."""
    n_dev = jax.local_device_count()
    results: dict = {"devices": n_dev, "skipped": n_dev < max(TP_WIDTHS)}
    if results["skipped"]:
        if verbose:
            print(f"tp sweep skipped: {n_dev} host device(s) < "
                  f"{max(TP_WIDTHS)} (relaunch with --devices "
                  f"{max(TP_WIDTHS)})")
        return results
    prompt_hi = max(len(w["prompt"]) for w in workload)
    widest_router = None
    for tp in TP_WIDTHS:
        plan = build_serve_plan(
            cfg, prefill_len=prompt_hi, slots=rcfg.max_slots,
            max_seq=rcfg.max_seq, chunk_tokens=rcfg.chunk_width,
            tuner=Tuner(methods=("random",), random_budget=16),
            model_parallel=tp)
        router = PlanRouter(plan)
        engine = ContinuousEngine(model, params, tp_mesh(tp), DEFAULT_RULES,
                                  rcfg, router=router)
        warm_engine(engine, cfg.vocab, prompt_hi)
        r = drive_continuous(engine, workload)
        s = engine.metrics.summary()
        r.update(ttft_p95_s=s["ttft_p95_s"], mesh=engine.mesh_tag,
                 layouts=_layout_summary(router))
        results[tp] = r
        if tp == max(TP_WIDTHS):
            widest_router = router
        if verbose:
            print(f"mesh {engine.mesh_tag}: {r['tokens_per_s']:8.1f} tok/s "
                  f"| ttft p95 {r['ttft_p95_s']:6.2f}s | "
                  f"layouts {r['layouts']}")
    # tuned-vs-replicated at the widest mesh: same plan (same backend
    # lanes), base rules knocked down to replicated — isolates the layout
    # dimension the tuner races
    engine = ContinuousEngine(model, params, tp_mesh(max(TP_WIDTHS)),
                              REPLICATED_RULES, rcfg, router=widest_router)
    warm_engine(engine, cfg.vocab, prompt_hi)
    r = drive_continuous(engine, workload)
    s = engine.metrics.summary()
    r.update(ttft_p95_s=s["ttft_p95_s"], mesh=engine.mesh_tag,
             layouts="forced replicated")
    results["replicated"] = r
    results["tuned"] = results[max(TP_WIDTHS)]
    if verbose:
        t, p = results["tuned"], results["replicated"]
        print(f"mesh {t['mesh']} tuned layouts vs replicated: "
              f"{t['tokens_per_s']:8.1f} vs {p['tokens_per_s']:8.1f} tok/s "
              f"| ttft p95 {t['ttft_p95_s']:.2f}s vs {p['ttft_p95_s']:.2f}s")
    return results


# ------------------------------------------------------- pool-pressure sweep
def pressure_sweep(model, params, mesh, cfg, rcfg: RuntimeConfig, workload,
                   factors=(1.0, 0.5, 0.25), verbose: bool = True) -> dict:
    """Replay the same Poisson workload with the block pool shrunk to
    `factor` x worst-case demand (max_slots x max_blocks_per_seq).  The
    old worst-case-reservation admission would serialize or starve below
    1.0x; on-demand growth + preemption must complete every request at
    every factor, trading throughput/p95 for memory."""
    import dataclasses as _dc

    worst = rcfg.max_slots * rcfg.max_blocks_per_seq
    prompt_hi = max(len(w["prompt"]) for w in workload)
    results = {}
    for f in factors:
        usable = max(rcfg.max_blocks_per_seq, int(round(worst * f)))
        sized = _dc.replace(rcfg, num_blocks=usable + 1)
        engine = ContinuousEngine(model, params, mesh, DEFAULT_RULES, sized)
        warm_engine(engine, cfg.vocab, prompt_hi)
        r = drive_continuous(engine, workload)
        s = engine.metrics.summary()
        errors = len(workload) - r["done"]
        r.update(pool_blocks=usable, factor=f, errors=errors,
                 preemptions=int(s["preemptions"]),
                 swap_mb=(s["swap_out_bytes"] + s["swap_in_bytes"]) / 2**20,
                 stall_s=s["stall_s"], swap_in_time_s=s["swap_in_time_s"])
        results[f] = r
        if verbose:
            print(f"pool {f:4.2f}x ({usable:3d} blocks): "
                  f"{r['tokens_per_s']:8.1f} tok/s | "
                  f"p95 {r['latency_p95_s']:6.2f}s | "
                  f"preemptions {r['preemptions']:3d} | "
                  f"swap {r['swap_mb']:6.2f} MiB | "
                  f"swap-in {r['swap_in_time_s']:5.2f}s | "
                  f"stall {r['stall_s']:5.2f}s | errors {errors}")
    full = results[min(factors)]
    if verbose:
        ok = full["errors"] == 0 and full["preemptions"] >= 1
        print(f"pool-pressure check (smallest pool completes full workload "
              f"via preemption): {'PASS' if ok else 'MISS'}")
    return results


# ------------------------------------------------ prefill-interference sweep
def interference_workload(rng: np.random.Generator, n: int, vocab: int,
                          rate_hz: float, short_hi: int = 12,
                          long_len: int = 64, long_frac: float = 0.5,
                          new_lo: int = 8, new_hi: int = 16):
    """Poisson mix of short (decode-dominated) and long (prefill-heavy)
    prompts — the workload where a monopolizing prefill shows up as
    decode-side head-of-line latency.  Built on `make_workload` (same
    arrival process); a `long_frac` share of requests get their prompt
    replaced by a `long_len`-token one and tagged `long`."""
    out = make_workload(rng, n, vocab, rate_hz, prompt_lo=4,
                        prompt_hi=short_hi, new_lo=new_lo, new_hi=new_hi)
    for w in out:
        w["long"] = bool(rng.random() < long_frac)
        if w["long"]:
            w["prompt"] = rng.integers(0, vocab, size=long_len).astype(np.int32)
    return out


def _replay_virtual(model, params, mesh, rcfg: RuntimeConfig, workload,
                    chunk_tokens, chunk_segments: int = None,
                    prefix_sharing: bool = None,
                    c0: float = 0.25, c_tok: float = 0.125):
    """Replay the workload under a deterministic virtual clock: a step
    that carries prompt work costs c0 + c_tok x (decode rows + the chunk
    lane's COMPILED width), a decode-only step costs c0 + c_tok x decode
    rows.  The lane-width charge is the honest price of the unified step —
    the compiled chunk lane executes at full width however little of it is
    filled — so the model makes both wins measurable: segment packing
    raises the useful tokens bought per lane charge (fill fraction), and
    the decode-only fast path drops the charge entirely on chunk-less
    steps.  Same cost model for every engine, so comparisons isolate
    SCHEDULING — how prompt work is sliced and packed — from kernel speed.

    The headline interference metric is the DECODE TIME-BETWEEN-TOKENS
    distribution: every (in-flight decoder, step) pair contributes that
    step's cost as one inter-token gap sample.  A prompt monopolizing a
    step shows up as a gap spike suffered by every concurrent decoder —
    exactly the head-of-line stall chunking exists to remove."""
    import dataclasses as _dc

    clock = {"t": 0.0}
    sized = _dc.replace(rcfg, chunk_tokens=chunk_tokens)
    if chunk_segments is not None:
        sized = _dc.replace(sized, chunk_segments=chunk_segments)
    if prefix_sharing is not None:
        sized = _dc.replace(sized, prefix_sharing=prefix_sharing)
    eng = ContinuousEngine(model, params, mesh, DEFAULT_RULES, sized,
                           now_fn=lambda: clock["t"])
    by_rid = {}
    for w in workload:
        rid = eng.submit(w["prompt"], max_new_tokens=w["max_new"],
                         arrival_time=w["arrival"])
        by_rid[rid] = w
    eng.metrics.start_time = 0.0
    tbt_gaps: List[float] = []
    with eng.mesh:
        while eng.scheduler.has_work:
            n_occ = len(eng.metrics.slot_occupancy)
            n_chunk_steps = eng.metrics.chunk_steps
            if eng.step():
                dec_rows = 0
                if len(eng.metrics.slot_occupancy) > n_occ:
                    dec_rows = round(eng.metrics.slot_occupancy[-1]
                                     * eng.cfg.max_slots)
                lane = (eng.cfg.chunk_width
                        if eng.metrics.chunk_steps > n_chunk_steps else 0)
                cost = c0 + c_tok * (dec_rows + lane)
                clock["t"] += cost
                tbt_gaps.extend([cost] * dec_rows)
            else:
                clock["t"] += c0 / 4          # idle tick (future arrivals)
    eng.metrics.end_time = clock["t"]
    done = eng._done
    short = [r.latency_s for r in done if not by_rid[r.rid].get("long")]
    long_ttft = [r.ttft_s for r in done if by_rid[r.rid].get("long")]
    s = eng.metrics.summary()
    return {
        "decode_tbt_p50_s": percentile(tbt_gaps, 50),
        "decode_tbt_p95_s": percentile(tbt_gaps, 95),
        "decode_tbt_max_s": max(tbt_gaps, default=0.0),
        "short_latency_p95_s": percentile(short, 95),
        "long_ttft_p95_s": percentile(long_ttft, 95),
        "ttft_p95_s": percentile([r.ttft_s for r in done], 95),
        "tokens_per_s": s["tokens_per_s"],
        "chunks": int(s["prefill_chunks"]),
        "chunk_steps": int(s["chunk_steps"]),
        "chunk_fill_frac": s["chunk_fill_frac"],
        "packed_segments": int(s["packed_segments"]),
        "decode_only_steps": int(s["decode_only_steps"]),
        "preemptions": int(s["preemptions"]),
        "chunk_tokens_committed": int(s["chunk_tokens_committed"]),
        "prefix_hit_tokens": int(s["prefix_hit_tokens"]),
        "cow_copies": int(s["cow_copies"]),
        "done": len(done),
    }


def interference_sweep(model, params, mesh, cfg, rcfg: RuntimeConfig,
                       requests: int = 24, seed: int = 0,
                       chunk_tokens: int = 16, rate_hz: float = 0.25,
                       verbose: bool = True) -> dict:
    """Decode p95 with vs without chunked prefill on a long/short Poisson
    mix (virtual clock — deterministic).  The unchunked engine carries a
    whole long prompt in ONE step, so every in-flight decoder's inter-token
    gap spikes by the full prompt's cost; the chunked engine bounds each
    step's prompt work at `chunk_tokens`, holding decode TBT p95 down at a
    bounded TTFT cost to the long prompts themselves (their prefill now
    spans several steps) — the reservation-free version of the trade the
    ROADMAP's "chunked prefill" open item asked for."""
    rng = np.random.default_rng(seed)
    long_len = min(64, rcfg.max_seq - 17)
    workload = interference_workload(rng, requests, cfg.vocab, rate_hz,
                                     long_len=long_len)
    results = {}
    for label, ct in (("chunked", chunk_tokens), ("unchunked", None)):
        r = _replay_virtual(model, params, mesh, rcfg, workload, ct)
        results[label] = r
        if verbose:
            print(f"{label:10s}: decode tbt p50 {r['decode_tbt_p50_s']:5.2f}  "
                  f"p95 {r['decode_tbt_p95_s']:5.2f}  "
                  f"max {r['decode_tbt_max_s']:5.2f} | "
                  f"long ttft p95 {r['long_ttft_p95_s']:6.2f} | "
                  f"short lat p95 {r['short_latency_p95_s']:6.2f} | "
                  f"chunks {r['chunks']:3d} | {r['done']} reqs (virtual s)")
    if verbose:
        ok = (results["chunked"]["decode_tbt_p95_s"]
              <= results["unchunked"]["decode_tbt_p95_s"])
        print("prefill-interference check (chunked decode TBT p95 <= "
              f"unchunked): {'PASS' if ok else 'MISS'}")
    return results


# ------------------------------------------------- sampled differential
def sampled_differential(model, params, mesh, cfg, rcfg: RuntimeConfig,
                         workload, n: int = 12,
                         verbose: bool = True) -> dict:
    """Mixed-sampling replay pinned against the B=1 fixed drain.

    A prefix of the Poisson workload gets per-request SamplingParams from
    the mixed cycle and runs through a FRESH continuous engine (chunked
    prefill, packing, the usual schedule) and through a fresh
    `FixedBatchEngine` at batch_size=1 with UNPADDED prompts (left-padding
    changes the logits; B=1 needs none).  Every token's draw is keyed by
    (seed, rid, token_index) — pure request identity and progress — so the
    rid sequences are aligned (fresh engines, same submission order) and
    the continuous streams must equal the drain's byte for byte (prefix
    compare: the static drain decodes the batch-wide worst-case budget).
    Any mismatch fails the bench."""
    sub = [dict(w) for w in workload[:n]]
    for i, w in enumerate(sub):
        w["sampling"] = mixed_sampling(i)
    prompt_hi = max(len(w["prompt"]) for w in sub)

    engine = ContinuousEngine(model, params, mesh, DEFAULT_RULES, rcfg)
    warm_engine(engine, cfg.vocab, prompt_hi)
    engine._rid = 0       # keys are rid-keyed: drop the warm-up rid so the
    #                       replay's rids align with the fresh baseline's
    t0 = time.perf_counter()
    engine.metrics.start_time = t0
    for w in sub:
        engine.submit(w["prompt"], max_new_tokens=w["max_new"],
                      arrival_time=t0 + w["arrival"], sampling=w["sampling"])
    finished = engine.run()
    done = {q.rid: q.output for q in finished}
    s = engine.metrics.summary()
    r = {"tokens_per_s": s["tokens_per_s"], "done": len(finished)}

    fixed = FixedBatchEngine(
        model, params, mesh, DEFAULT_RULES,
        ServeConfig(batch_size=1, max_seq=rcfg.max_seq,
                    max_new_tokens=max(w["max_new"] for w in sub)))
    for w in sub:
        fixed.submit(w["prompt"], sampling=w["sampling"])
    ref = {q.rid: q.output for q in fixed.run()}

    mismatches = sum(1 for rid, out in done.items()
                     if out != ref[rid][: len(out)])
    sampled_n = sum(1 for w in sub if not w["sampling"].greedy)
    out = {"tokens_per_s": r["tokens_per_s"], "mismatches": mismatches,
           "requests": len(sub), "sampled_requests": sampled_n,
           "done": r["done"]}
    if verbose:
        ok = mismatches == 0 and r["done"] == len(sub)
        print(f"sampled    : {r['tokens_per_s']:8.1f} tok/s | "
              f"{sampled_n}/{len(sub)} sampled | "
              f"mismatches vs B=1 drain: {mismatches} "
              f"({'PASS' if ok else 'FAIL'}: keyed streams replay "
              "byte-identically across schedules)")
    return out


# --------------------------------------------------- segment-packing sweep
def packing_sweep(model, params, mesh, cfg, rcfg: RuntimeConfig,
                  requests: int = 24, seed: int = 0, chunk_tokens: int = 32,
                  rate_hz: float = 1.5, verbose: bool = True) -> dict:
    """Useful tokens/s with vs without segment packing on a short-prompt-
    heavy Poisson workload (virtual clock — deterministic).  Every prompt
    is far smaller than the chunk budget, so the single-segment engine
    (PR 4 behaviour: one request's slice per step) pays the full compiled
    lane width for a mostly idle chunk each prefill step; the packed
    engine carries several prompts' segments per step, buying more useful
    prompt tokens for the same lane charge — higher chunk fill fraction,
    fewer chunk steps, better tokens/s AND better TTFT p95 (short prompts
    stop queueing behind one-per-step chunk scheduling)."""
    rng = np.random.default_rng(seed)
    workload = make_workload(rng, requests, cfg.vocab, rate_hz,
                             prompt_lo=4, prompt_hi=12, new_lo=4, new_hi=12)
    results = {}
    for label, segs in (("packed", max(2, rcfg.chunk_segments)),
                        ("single-seg", 1)):
        r = _replay_virtual(model, params, mesh, rcfg, workload,
                            chunk_tokens, chunk_segments=segs)
        results[label] = r
        if verbose:
            print(f"{label:10s}: {r['tokens_per_s']:7.2f} tok/s | "
                  f"ttft p95 {r['ttft_p95_s']:6.2f} | "
                  f"chunk fill {r['chunk_fill_frac']:4.0%} "
                  f"({r['chunks']:3d} chunks / {r['chunk_steps']:3d} steps) | "
                  f"packed segs {r['packed_segments']:3d} | "
                  f"decode-only {r['decode_only_steps']:3d} | "
                  f"{r['done']} reqs (virtual s)")
    if verbose:
        ok = (results["packed"]["tokens_per_s"]
              > results["single-seg"]["tokens_per_s"]
              and results["packed"]["packed_segments"] > 0)
        print("segment-packing check (packed tokens/s > single-segment, "
              f"packing observed): {'PASS' if ok else 'MISS'}")
    return results


# --------------------------------------------------- prefix-sharing sweep
def prefix_workload(rng: np.random.Generator, n: int, vocab: int,
                    rate_hz: float, system_len: int = 32,
                    share_frac: float = 0.75, tail_lo: int = 2,
                    tail_hi: int = 16, new_lo: int = 4, new_hi: int = 12):
    """Poisson arrivals where a `share_frac` share of requests begin with
    ONE hot `system_len`-token system prompt (an exact multiple of the
    headline block size, so its blocks are index-eligible) followed by a
    short per-request tail; the rest carry unrelated prompts.  The shape
    every multi-tenant chat serving deployment exhibits — and the one
    prefix sharing exists for: the system prompt's KV should be prefilled
    once, ever."""
    system = rng.integers(0, vocab, size=system_len).astype(np.int32)
    out = make_workload(rng, n, vocab, rate_hz, prompt_lo=4,
                        prompt_hi=system_len // 2, new_lo=new_lo,
                        new_hi=new_hi)
    for w in out:
        w["shared"] = bool(rng.random() < share_frac)
        if w["shared"]:
            tail = rng.integers(0, vocab,
                                size=int(rng.integers(tail_lo, tail_hi + 1)))
            w["prompt"] = np.concatenate([system, tail.astype(np.int32)])
    return out


def prefix_sweep(model, params, mesh, cfg, rcfg: RuntimeConfig,
                 requests: int = 24, seed: int = 0, chunk_tokens: int = 32,
                 rate_hz: float = 1.0, verbose: bool = True) -> dict:
    """Useful tokens/s with vs without prefix sharing on a shared-system-
    prompt Poisson workload (virtual clock — deterministic).  The sharing
    engine admits each hot-prefix request with its system prompt's blocks
    ADOPTED from the prefix index (refcounted, copy-on-write on divergence)
    and starts prefill at the first unshared token, so the chunk lane
    commits only the tails — fewer chunk-carrying steps, each a full
    lane-width charge saved, which the cost model converts into tokens/s
    and TTFT wins.  The sharing-off engine prefills every copy of the
    system prompt from scratch.  Streams are byte-identical either way
    (pinned by tests/test_prefix_sharing.py); this sweep measures the
    work, not the answers."""
    rng = np.random.default_rng(seed)
    workload = prefix_workload(rng, requests, cfg.vocab, rate_hz)
    results = {}
    for label, share in (("on", True), ("off", False)):
        r = _replay_virtual(model, params, mesh, rcfg, workload,
                            chunk_tokens, prefix_sharing=share)
        results[label] = r
        if verbose:
            print(f"sharing-{label:3s}: {r['tokens_per_s']:7.2f} tok/s | "
                  f"ttft p95 {r['ttft_p95_s']:6.2f} | "
                  f"chunk tokens {r['chunk_tokens_committed']:4d} "
                  f"({r['chunk_steps']:3d} steps) | "
                  f"prefix hits {r['prefix_hit_tokens']:4d} | "
                  f"cow {r['cow_copies']:2d} | "
                  f"{r['done']} reqs (virtual s)")
    if verbose:
        on, off = results["on"], results["off"]
        ok = (on["prefix_hit_tokens"] > 0
              and on["chunk_tokens_committed"]
              <= 0.6 * off["chunk_tokens_committed"]
              and on["tokens_per_s"] > off["tokens_per_s"])
        print("prefix-sharing check (>=40% fewer chunk tokens, tokens/s "
              f"improves, hits observed): {'PASS' if ok else 'MISS'}")
    return results


# -------------------------------------------------------------------- harness
def bench(requests: int = 32, slots: int = 4, seed: int = 0,
          rate_hz: float = 0.0, verbose: bool = True,
          lanes: bool = True, lane_requests: int = 12,
          pressure: bool = True, interference: bool = True,
          interference_requests: int = 24, packing: bool = True,
          packing_requests: int = 24, prefix: bool = True,
          prefix_requests: int = 24, sampling: str = "greedy",
          sampled: bool = True, sampled_requests: int = 12,
          tp: bool = True, tp_requests: int = 12,
          trace_path: str = None) -> dict:
    cfg = get_config("qwen3-1.7b").reduced(n_layers=2, d_model=128, d_ff=256,
                                           vocab=211)
    model = build_model(cfg)
    mesh = single_device_mesh()
    with mesh:
        params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)

    prompt_hi, new_hi = 48, 32
    rcfg = RuntimeConfig(max_slots=slots, block_size=16,
                         max_blocks_per_seq=-(-(prompt_hi + new_hi) // 16),
                         max_new_tokens=new_hi)
    recorder = TraceRecorder() if trace_path else None
    engine = ContinuousEngine(model, params, mesh, DEFAULT_RULES, rcfg,
                              trace=recorder)

    # Warm-up: compile THE unified step program (mixed lengths only warm
    # the host paths — chunk geometry is data, nothing else ever compiles).
    warm = [rng.integers(0, cfg.vocab, size=s).astype(np.int32)
            for s in (8, prompt_hi // 2, prompt_hi)] * 2
    for p in warm:
        engine.submit(p, max_new_tokens=4)
    engine.run()
    # Measure sustained (post-compile) capacity with a saturated burst.
    t0 = time.perf_counter()
    burst = 3 * slots
    for _ in range(burst):
        engine.submit(rng.integers(0, cfg.vocab, size=prompt_hi // 2)
                      .astype(np.int32), max_new_tokens=16)
    engine.run()
    cap_tok_s = (burst * 16) / (time.perf_counter() - t0)
    engine.reset_metrics()

    avg_new = (2 + new_hi) / 2
    if rate_hz <= 0:
        # offer ~1.3x sustained capacity: both engines run saturated, so
        # tokens/s measures ENGINE capacity rather than the arrival rate.
        rate_hz = max(0.1, 1.3 * cap_tok_s / avg_new)
    if verbose:
        print(f"sustained decode capacity ~{cap_tok_s:,.0f} tok/s -> "
              f"Poisson rate {rate_hz:.2f} req/s")

    workload = make_workload(rng, requests, cfg.vocab, rate_hz,
                             prompt_hi=prompt_hi, new_hi=new_hi)
    if sampling == "mixed":
        # per-request knobs on the HEADLINE workload too: same two step
        # programs, the knob/key arrays are just traced data
        for i, w in enumerate(workload):
            w["sampling"] = mixed_sampling(i)

    if recorder is not None:
        recorder.clear()      # drop warm-up/capacity events: the trace (and
        #                       its audit) covers exactly the headline replay
    cont = drive_continuous(engine, workload)
    fixed = drive_fixed(
        model, params, mesh,
        ServeConfig(batch_size=slots, max_seq=prompt_hi + new_hi,
                    max_new_tokens=new_hi),
        prompt_pad=prompt_hi, workload=workload)

    speedup = cont["tokens_per_s"] / max(1e-9, fixed["tokens_per_s"])
    if verbose:
        print(f"fixed      : {fixed['tokens_per_s']:8.1f} tok/s | "
              f"p50 {fixed['latency_p50_s']:6.2f}s  p95 {fixed['latency_p95_s']:6.2f}s | "
              f"{fixed['done']} reqs")
        print(f"continuous : {cont['tokens_per_s']:8.1f} tok/s | "
              f"p50 {cont['latency_p50_s']:6.2f}s  p95 {cont['latency_p95_s']:6.2f}s | "
              f"ttft p50 {cont['ttft_p50_s']:.2f}s | slot occ "
              f"{cont['slot_occupancy']:.0%} | cache occ {cont['cache_occupancy']:.0%}")
        print(f"chunk lane : fill {cont['chunk_fill_frac']:.0%} | packed segs "
              f"{cont['packed_segments']} | decode-only steps "
              f"{cont['decode_only_steps']}")
        print(f"continuous-batching speedup: {speedup:.2f}x tokens/s "
              f"(target >= 1.3x at equal-or-better p95: "
              f"{'PASS' if speedup >= 1.3 and cont['latency_p95_s'] <= fixed['latency_p95_s'] else 'MISS'})")
    out = {"fixed": fixed, "continuous": cont, "speedup": speedup}
    if recorder is not None:
        metadata = {
            "usable_blocks": engine.kv_cfg.num_blocks - 1,
            "block_size": engine.kv_cfg.block_size,
            "max_slots": rcfg.max_slots,
            "chunk_width": engine._chunk_width,
            "chunk_segments": engine._chunk_segments,
            "mesh": engine.mesh_tag,
            "layouts": _layout_summary(engine.router),
            "requests": requests, "seed": seed,
        }
        write_trace(trace_path, recorder.events, metrics=engine.metrics,
                    metadata=metadata)
        report = traceview.audit(recorder.events, metrics=engine.metrics,
                                 metadata=metadata)
        out["trace_audit_ok"] = report.ok
        if verbose:
            print(f"--- trace: {len(recorder.events)} events -> {trace_path} "
                  "(Chrome trace-event JSON; open in ui.perfetto.dev) ---")
            print("per-request time attribution (from trace events):")
            print(traceview.format_attribution(report.lifecycles))
            print(report.summary())
    if sampled:
        if verbose:
            print("--- sampled differential (mixed-sampling prefix vs the "
                  "B=1 fixed drain; keyed streams must match bytewise) ---")
        out["sampled"] = sampled_differential(
            model, params, mesh, cfg, rcfg,
            workload, n=min(sampled_requests, requests), verbose=verbose)
    if packing:
        if verbose:
            print("--- segment-packing sweep (short-prompt-heavy Poisson "
                  "mix; packed vs single-segment chunking; virtual clock) ---")
        out["packing"] = packing_sweep(model, params, mesh, cfg, rcfg,
                                       requests=packing_requests, seed=seed,
                                       verbose=verbose)
    if prefix:
        if verbose:
            print("--- prefix-sharing sweep (hot shared system prompt; "
                  "sharing on vs off; virtual clock) ---")
        out["prefix"] = prefix_sweep(model, params, mesh, cfg, rcfg,
                                     requests=prefix_requests, seed=seed,
                                     verbose=verbose)
    if interference:
        if verbose:
            print("--- prefill-interference sweep (long/short Poisson mix; "
                  "chunked vs unchunked prefill; virtual clock) ---")
        out["interference"] = interference_sweep(
            model, params, mesh, cfg, rcfg,
            requests=interference_requests, seed=seed, verbose=verbose)
    if pressure:
        if verbose:
            print("--- pool-pressure sweep (same Poisson workload; pool "
                  "shrunk vs worst-case demand; preemption + swap) ---")
        out["pressure"] = pressure_sweep(model, params, mesh, cfg, rcfg,
                                         workload, verbose=verbose)
    if lanes:
        if verbose:
            print("--- stage-matmul lane breakdown (same Poisson workload; "
                  "Pallas lanes run in interpret mode on CPU) ---")
        out["lanes"] = lane_breakdown(model, params, mesh, cfg, rcfg,
                                      workload[:lane_requests], verbose=verbose)
    if tp:
        if verbose:
            print("--- tensor-parallel mesh sweep (same workload at mesh "
                  "1x1/1x2/1x4; tuned layouts vs replicated) ---")
        out["tp"] = tp_sweep(model, params, cfg, rcfg,
                             workload[:tp_requests], verbose=verbose)
    return out


# ------------------------------------------------------ ssm family scenario
def bench_ssm(requests: int = 16, slots: int = 3, seed: int = 0,
              rate_hz: float = 0.0, verbose: bool = True,
              sampling: str = "greedy", trace_path: str = None) -> dict:
    """Mamba2 through the SAME continuous scheduler (`--family ssm`).

    The `SSMFamilyAdapter` swaps the paged KV pool for the fixed-size
    `SlotStateCache` (one conv+SSM state row per in-flight request) while
    the orchestration loop, scheduler, metrics and trace taxonomy stay
    exactly the decoder's.  The state pool is provisioned one row SHORT
    of the slot count (`state_slots = slots`, usable = slots - 1), so the
    replay exercises slot preemption + host swap on state rows the way
    the decoder's pool-pressure sweep does on KV blocks.  Reference: the
    same Poisson workload drained arrival-aware through the
    `FixedBatchEngine` (whole-prompt prefill, full worst-case budget)."""
    cfg = get_config("mamba2-2.7b").reduced(n_layers=2)
    model = build_model(cfg)
    mesh = single_device_mesh()
    with mesh:
        params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)

    q = cfg.ssm_chunk
    prompt_pad, new_hi = 3 * q, 12
    rcfg = RuntimeConfig(max_slots=slots, chunk_tokens=q,
                         max_new_tokens=new_hi, state_slots=slots)
    recorder = TraceRecorder() if trace_path else None
    engine = ContinuousEngine(model, params, mesh, DEFAULT_RULES, rcfg,
                              trace=recorder)
    assert engine.family == "ssm", engine.family
    warm_engine(engine, cfg.vocab, q)

    # Sustained (post-compile) capacity -> arrival rate, as the decoder does.
    t0 = time.perf_counter()
    burst = 3 * slots
    for _ in range(burst):
        engine.submit(rng.integers(0, cfg.vocab, size=q).astype(np.int32),
                      max_new_tokens=8)
    engine.run()
    cap_tok_s = (burst * 8) / (time.perf_counter() - t0)
    engine.reset_metrics()
    if rate_hz <= 0:
        rate_hz = max(0.1, 1.3 * cap_tok_s / ((2 + new_hi) / 2))
    if verbose:
        print(f"[ssm] sustained decode capacity ~{cap_tok_s:,.0f} tok/s -> "
              f"Poisson rate {rate_hz:.2f} req/s")

    # Arbitrary prompt lengths: the chunk lane pads ragged tails with
    # zero-dt rows; the fixed drain left-pads to `prompt_pad` (a multiple
    # of the SSD scan chunk, which whole-prompt prefill requires).
    workload = make_workload(rng, requests, cfg.vocab, rate_hz,
                             prompt_lo=4, prompt_hi=prompt_pad,
                             new_lo=2, new_hi=new_hi)
    if sampling == "mixed":
        for i, w in enumerate(workload):
            w["sampling"] = mixed_sampling(i)
    if recorder is not None:
        recorder.clear()      # the trace covers exactly the headline replay
    cont = drive_continuous(engine, workload)
    s = engine.metrics.summary()
    fixed = drive_fixed(
        model, params, mesh,
        ServeConfig(batch_size=slots, max_seq=prompt_pad + new_hi,
                    max_new_tokens=new_hi),
        prompt_pad=prompt_pad, workload=workload)
    speedup = cont["tokens_per_s"] / max(1e-9, fixed["tokens_per_s"])
    errors = requests - cont["done"]
    out = {"fixed": fixed, "continuous": cont, "speedup": speedup,
           "preemptions": int(s["preemptions"]),
           "ttft_p95_s": s["ttft_p95_s"], "errors": errors}
    if verbose:
        print(f"[ssm] fixed      : {fixed['tokens_per_s']:8.1f} tok/s | "
              f"p95 {fixed['latency_p95_s']:6.2f}s | {fixed['done']} reqs")
        print(f"[ssm] continuous : {cont['tokens_per_s']:8.1f} tok/s | "
              f"p95 {cont['latency_p95_s']:6.2f}s | "
              f"ttft p95 {s['ttft_p95_s']:.2f}s | "
              f"preemptions {out['preemptions']} | slot occ "
              f"{cont['slot_occupancy']:.0%} | state occ "
              f"{cont['cache_occupancy']:.0%}")
        print(f"[ssm] continuous-batching speedup: {speedup:.2f}x tokens/s | "
              f"errors {errors} "
              f"({'PASS' if errors == 0 else 'FAIL'}: continuous completes "
              "the full workload)")
    if recorder is not None:
        metadata = {
            # one state row per request: the pool audit replays slot
            # alloc/free as 1-block events against the usable row count
            "usable_blocks": engine.cache.cfg.usable,
            "block_size": 1,
            "max_slots": rcfg.max_slots,
            "chunk_width": engine._chunk_width,
            "chunk_segments": engine._chunk_segments,
            "family": "ssm",
            "mesh": engine.mesh_tag,
            "requests": requests, "seed": seed,
        }
        write_trace(trace_path, recorder.events, metrics=engine.metrics,
                    metadata=metadata)
        report = traceview.audit(recorder.events, metrics=engine.metrics,
                                 metadata=metadata)
        out["trace_audit_ok"] = report.ok
        if verbose:
            print(f"--- trace: {len(recorder.events)} events -> {trace_path} "
                  "(Chrome trace-event JSON; open in ui.perfetto.dev) ---")
            print("per-request time attribution (from trace events):")
            print(traceview.format_attribution(report.lifecycles))
            print(report.summary())
    return out


# -------------------------------------------------------------- CSV schema
# The harness CSV contract (benchmarks/run.py prints `name,us_per_call,
# derived`).  Rows used to be ad-hoc tuples appended in run(); the schema —
# column count/types AND the exact row names run() emits — is now pinned so
# dashboard/trajectory parsers can't silently break when rows are added.
# Extending the bench means extending `expected_csv_names()` AND its
# snapshot test (tests/test_trace.py) in the same change.
CSV_COLUMNS = ("name", "value", "derived")

PACKING_LABELS = ("packed", "single-seg")
PREFIX_LABELS = ("on", "off")
INTERFERENCE_LABELS = ("chunked", "unchunked")
PRESSURE_FACTORS = (1.0, 0.5, 0.25)
LANE_LABELS = ("xla-only", "tuned plan", "forced pallas")


def csv_row(name: str, value, derived: str = "") -> tuple:
    """Build one schema-conforming CSV row: (str name, float value,
    str derived).  Loud on drift — a non-numeric value or empty name is a
    bug in the bench, not a formatting detail for the parser to absorb."""
    if not isinstance(name, str) or not name:
        raise ValueError(f"CSV row name must be a non-empty str: {name!r}")
    return (name, float(value), str(derived))


def expected_csv_names(sampled: bool = True, packing: bool = True,
                       prefix: bool = True, interference: bool = True,
                       pressure: bool = True, lanes: bool = True,
                       ssm: bool = True, tp: bool = True) -> list:
    """The exact, ordered row names run() appends — the pinned schema."""
    names = ["serve_fixed_tok_s", "serve_continuous_tok_s",
             "serve_speedup_x", "serve_chunk_fill_frac"]
    if sampled:
        names += ["serve_sampled_tok_s", "serve_sampled_mismatches"]
    if packing:
        names += [f"serve_packing_{l.replace('-', '_')}_tok_s"
                  for l in PACKING_LABELS]
    if prefix:
        names += [f"serve_prefix_{l}_tok_s" for l in PREFIX_LABELS]
    if interference:
        names += [f"serve_interference_{l}_decode_tbt_p95_s"
                  for l in INTERFERENCE_LABELS]
    if pressure:
        names += [f"serve_pool_{f:.2f}x_tok_s" for f in PRESSURE_FACTORS]
    if lanes:
        names += [f"serve_lane_{l.replace(' ', '_')}_tok_s"
                  for l in LANE_LABELS]
    if ssm:
        names += ["serve_ssm_fixed_tok_s", "serve_ssm_continuous_tok_s",
                  "serve_ssm_speedup_x", "serve_ssm_preemptions"]
    if tp:
        names += [f"serve_tp_mesh{w}_tok_s" for w in TP_WIDTHS]
        names += ["serve_tp_tuned_tok_s", "serve_tp_replicated_tok_s"]
    return names


def run(csv_rows):
    """benchmarks.run harness entry."""
    r = bench(requests=24, slots=4, verbose=False, lane_requests=8)
    start = len(csv_rows)
    csv_rows.append(csv_row("serve_fixed_tok_s",
                            r["fixed"]["tokens_per_s"]))
    csv_rows.append(csv_row("serve_continuous_tok_s",
                            r["continuous"]["tokens_per_s"],
                            f"p95={r['continuous']['latency_p95_s']:.2f}s"))
    csv_rows.append(csv_row("serve_speedup_x", r["speedup"],
                            "continuous vs fixed, same Poisson workload"))
    csv_rows.append(csv_row("serve_chunk_fill_frac",
                            r["continuous"]["chunk_fill_frac"],
                            f"packed_segments="
                            f"{r['continuous']['packed_segments']} "
                            f"decode_only_steps="
                            f"{r['continuous']['decode_only_steps']}"))
    sd = r.get("sampled", {})
    csv_rows.append(csv_row("serve_sampled_tok_s", sd["tokens_per_s"],
                            f"sampled={sd['sampled_requests']}/"
                            f"{sd['requests']} mixed cycle"))
    csv_rows.append(csv_row("serve_sampled_mismatches", sd["mismatches"],
                            "keyed streams vs B=1 drain (must be 0)"))
    for label, pr in r.get("packing", {}).items():
        csv_rows.append(csv_row(
            f"serve_packing_{label.replace('-', '_')}_tok_s",
            pr["tokens_per_s"],
            f"ttft_p95={pr['ttft_p95_s']:.2f} "
            f"fill={pr['chunk_fill_frac']:.2f} "
            f"packed_segments={pr['packed_segments']} "
            f"decode_only={pr['decode_only_steps']} virtual-clock"))
    for label, xr in r.get("prefix", {}).items():
        csv_rows.append(csv_row(
            f"serve_prefix_{label}_tok_s", xr["tokens_per_s"],
            f"ttft_p95={xr['ttft_p95_s']:.2f} "
            f"chunk_tokens={xr['chunk_tokens_committed']} "
            f"prefix_hits={xr['prefix_hit_tokens']} "
            f"cow={xr['cow_copies']} virtual-clock"))
    for label, ir in r.get("interference", {}).items():
        csv_rows.append(csv_row(
            f"serve_interference_{label}_decode_tbt_p95_s",
            ir["decode_tbt_p95_s"],
            f"tbt_max={ir['decode_tbt_max_s']:.2f} "
            f"long_ttft_p95={ir['long_ttft_p95_s']:.2f} "
            f"chunks={ir['chunks']} virtual-clock"))
    for f, pr in r.get("pressure", {}).items():
        csv_rows.append(csv_row(
            f"serve_pool_{f:.2f}x_tok_s", pr["tokens_per_s"],
            f"preemptions={pr['preemptions']} swap_mb={pr['swap_mb']:.2f} "
            f"swap_in_s={pr['swap_in_time_s']:.3f} errors={pr['errors']}"))
    for label, lr in r.get("lanes", {}).items():
        lanes = ",".join(f"{k}:{v}" for k, v in sorted(lr["lanes"].items()))
        csv_rows.append(csv_row(
            f"serve_lane_{label.replace(' ', '_')}_tok_s",
            lr["tokens_per_s"], lanes or "no plan (all xla)"))
    sr = bench_ssm(requests=8, slots=3, verbose=False)
    csv_rows.append(csv_row("serve_ssm_fixed_tok_s",
                            sr["fixed"]["tokens_per_s"]))
    csv_rows.append(csv_row("serve_ssm_continuous_tok_s",
                            sr["continuous"]["tokens_per_s"],
                            f"ttft_p95={sr['ttft_p95_s']:.2f}s "
                            f"errors={sr['errors']}"))
    csv_rows.append(csv_row("serve_ssm_speedup_x", sr["speedup"],
                            "mamba2 continuous vs fixed, same Poisson "
                            "workload"))
    csv_rows.append(csv_row("serve_ssm_preemptions", sr["preemptions"],
                            "state pool one row short of slots"))
    # TP sweep rows: a fixed schema whatever the host's device count — a
    # single-device harness emits 0.0 with a "skipped" derived note, the
    # CI mesh-smoke job (4 virtual devices) emits real numbers
    tpr = r.get("tp", {})
    skipped = (f"skipped: {tpr.get('devices', 1)} host device(s)"
               if tpr.get("skipped", True) else "")
    for w in TP_WIDTHS:
        tr = tpr.get(w)
        csv_rows.append(csv_row(
            f"serve_tp_mesh{w}_tok_s",
            0.0 if tr is None else tr["tokens_per_s"],
            skipped if tr is None else
            f"ttft_p95={tr['ttft_p95_s']:.2f} layouts={tr['layouts']}"))
    for leg in ("tuned", "replicated"):
        tr = tpr.get(leg)
        csv_rows.append(csv_row(
            f"serve_tp_{leg}_tok_s",
            0.0 if tr is None else tr["tokens_per_s"],
            skipped if tr is None else
            f"mesh={tr['mesh']} ttft_p95={tr['ttft_p95_s']:.2f} "
            f"layouts={tr['layouts']}"))
    got = [row[0] for row in csv_rows[start:]]
    if got != expected_csv_names():
        raise AssertionError(
            "bench_serving CSV schema drifted from expected_csv_names(): "
            f"{got}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--family", choices=("decoder", "ssm"), default="decoder",
                    help="model family behind the continuous scheduler: "
                         "decoder (paged KV blocks) or ssm (Mamba2, "
                         "slot-pooled state rows; implies a zero-errors "
                         "guard and skips the decoder-only sweeps)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate (req/s); 0 = auto from capacity")
    ap.add_argument("--no-lanes", action="store_true",
                    help="skip the stage-matmul per-lane plan breakdown")
    ap.add_argument("--lane-requests", type=int, default=12,
                    help="workload prefix replayed per lane in the breakdown")
    ap.add_argument("--no-pressure", action="store_true",
                    help="skip the pool-pressure (preemption) sweep")
    ap.add_argument("--no-interference", action="store_true",
                    help="skip the prefill-interference (chunking) sweep")
    ap.add_argument("--interference-requests", type=int, default=24,
                    help="requests in the long/short interference mix")
    ap.add_argument("--no-packing", action="store_true",
                    help="skip the segment-packing sweep")
    ap.add_argument("--packing-requests", type=int, default=24,
                    help="requests in the short-prompt packing mix")
    ap.add_argument("--no-prefix", action="store_true",
                    help="skip the prefix-sharing sweep")
    ap.add_argument("--prefix-requests", type=int, default=24,
                    help="requests in the shared-system-prompt mix")
    ap.add_argument("--require-prefix-hits", action="store_true",
                    help="exit non-zero unless the sharing-on replay "
                         "adopted prompt tokens from the prefix index "
                         "(CI guard)")
    ap.add_argument("--sampling", choices=("greedy", "mixed"),
                    default="greedy",
                    help="per-request sampling on the headline workload: "
                         "greedy (default, temperature 0 everywhere) or "
                         "mixed (a fixed cycle of greedy / temperature / "
                         "top-k / top-p with per-request seeds; same two "
                         "compiled step programs — knobs are traced data)")
    ap.add_argument("--no-sampled", action="store_true",
                    help="skip the sampled differential (mixed-sampling "
                         "replay pinned byte-identical against the B=1 "
                         "fixed drain; mismatches exit non-zero)")
    ap.add_argument("--sampled-requests", type=int, default=12,
                    help="workload prefix replayed in the sampled "
                         "differential")
    ap.add_argument("--devices", type=int, default=None,
                    help="virtual host devices for the CPU backend "
                         "(applied by repro.platform BEFORE the jax import "
                         "at the top of this file; the TP sweep needs 4)")
    ap.add_argument("--no-tp", action="store_true",
                    help="skip the tensor-parallel mesh sweep")
    ap.add_argument("--tp-requests", type=int, default=12,
                    help="workload prefix replayed per mesh width in the "
                         "TP sweep")
    ap.add_argument("--require-decode-only", action="store_true",
                    help="exit non-zero unless the headline continuous run "
                         "dispatched the decode-only fast path (CI guard)")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="record the headline continuous run's event trace "
                         "to PATH (Chrome-trace JSON, opens in "
                         "ui.perfetto.dev; audited against ServeMetrics)")
    args = ap.parse_args()
    if args.family == "ssm":
        result = bench_ssm(args.requests, args.slots, args.seed, args.rate,
                           sampling=args.sampling, trace_path=args.trace)
        if args.trace and not result.get("trace_audit_ok", False):
            print("trace audit: FAIL — event trace disagrees with "
                  "ServeMetrics")
            raise SystemExit(1)
        if result["errors"]:
            print(f"zero-errors guard: FAIL — {result['errors']} requests "
                  "never completed")
            raise SystemExit(1)
        raise SystemExit(0)
    result = bench(args.requests, args.slots, args.seed, args.rate,
                   lanes=not args.no_lanes, lane_requests=args.lane_requests,
                   pressure=not args.no_pressure,
                   interference=not args.no_interference,
                   interference_requests=args.interference_requests,
                   packing=not args.no_packing,
                   packing_requests=args.packing_requests,
                   prefix=not args.no_prefix,
                   prefix_requests=args.prefix_requests,
                   sampling=args.sampling, sampled=not args.no_sampled,
                   sampled_requests=args.sampled_requests,
                   tp=not args.no_tp, tp_requests=args.tp_requests,
                   trace_path=args.trace)
    if args.trace and not result.get("trace_audit_ok", False):
        print("trace audit: FAIL — event trace disagrees with ServeMetrics")
        raise SystemExit(1)
    sd = result.get("sampled")
    if sd is not None and (sd["mismatches"] or sd["done"] < sd["requests"]):
        print(f"sampled differential: FAIL — {sd['mismatches']} stream "
              f"mismatches, {sd['done']}/{sd['requests']} completed")
        raise SystemExit(1)
    if args.require_prefix_hits:
        px = result.get("prefix", {}).get("on", {})
        hits = px.get("prefix_hit_tokens", 0)
        if hits == 0:
            print("prefix-sharing guard: FAIL — the sharing-on replay "
                  "never adopted a prompt token from the prefix index")
            raise SystemExit(1)
        print(f"prefix-sharing guard: PASS ({hits} prefix-hit tokens, "
              f"{px.get('cow_copies', 0)} CoW copies)")
    if args.require_decode_only:
        n = result["continuous"]["decode_only_steps"]
        if n == 0:
            print("decode-only guard: FAIL — the headline continuous run "
                  "never dispatched the decode-only fast path")
            raise SystemExit(1)
        print(f"decode-only guard: PASS ({n} decode-only steps)")
