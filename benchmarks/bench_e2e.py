"""§3.4 reproduction: end-to-end ResNet-18 inference plans.

Paper: WPK (integrated with TensorRT, free to pick third-party operator
implementations per op) runs 1.18x faster than TensorRT end-to-end, and
excluding the third-party operators costs only ~2%.

Ours races four plans over the optimized ResNet-18 graph:
  naive      — unoptimized graph, vendor (XLA) backend everywhere
  graph_only — graph optimization (§2.1) alone, vendor backend
  wpk_only   — graph optimization + tuned WPK codegen, NO third-party lane
  wpk_full   — the paper's full system-level exploration (§2.5)

Modeled TPU time is the primary metric; a real CPU wall-clock run of the
naive-vs-optimized engine (small image) demonstrates the graph passes win
on an actual machine too.
"""

import time

import jax.numpy as jnp
import numpy as np

from repro.core import Engine, Tuner, optimize_graph, select, xla_time
from repro.core.costmodel import xla_elementwise_time
from repro.core.graph import ELEMENTWISE_BINARY, ELEMENTWISE_UNARY
from repro.core.selection import TUNABLE_OPS, op_desc_of
from repro.models.resnet import resnet18_graph

_EW = ELEMENTWISE_UNARY + ELEMENTWISE_BINARY + (
    "bias_add", "batch_norm", "fused_elementwise")


def _plan_time_xla_only(graph, dtype_bytes: int = 2) -> float:
    total = 0.0
    for node in graph.toposort():
        if node.op in TUNABLE_OPS:
            op = op_desc_of(graph, node)
            if op is not None:
                total += xla_time(op)
        elif node.op in _EW:
            import numpy as np
            size = int(np.prod(graph.tensors[node.outputs[0]].shape))
            total += xla_elementwise_time(size * dtype_bytes)
    return total


def run(csv_rows):
    g = resnet18_graph(batch=1, image=224)
    gopt = optimize_graph(g)
    tuner = Tuner(methods=("genetic",))

    t_naive = _plan_time_xla_only(g)
    t_graph = _plan_time_xla_only(gopt)
    plan_full = select(gopt, tuner=tuner, third_party=True)
    plan_wpk = select(gopt, tuner=tuner, third_party=False)
    # un-fused leftovers (residual adds etc.) cost the same in every plan
    t_ew = t_graph - sum(
        xla_time(op_desc_of(gopt, n)) for n in gopt.toposort()
        if n.op in TUNABLE_OPS and op_desc_of(gopt, n) is not None)
    t_full = plan_full.total_modeled_time_s() + t_ew
    t_wpk = plan_wpk.total_modeled_time_s() + t_ew

    csv_rows.append(("e2e_naive_xla", t_naive * 1e6, "unoptimized graph, vendor ops"))
    csv_rows.append(("e2e_graph_only", t_graph * 1e6,
                     f"graph-opt speedup={t_naive / t_graph:.2f}x"))
    csv_rows.append(("e2e_wpk_no_third_party", t_wpk * 1e6,
                     f"vs_full={t_full / t_wpk:.3f} (paper: ~0.98, -2%)"))
    csv_rows.append(("e2e_wpk_full", t_full * 1e6,
                     f"speedup_vs_naive={t_naive / t_full:.2f}x "
                     f"vendor_ops_kept={plan_full.backend_histogram().get('xla', 0)} "
                     f"(paper: 1.18x vs TensorRT)"))

    # real CPU wall-clock: naive vs optimized graph through the engine
    g_small = resnet18_graph(batch=1, image=64)
    gopt_small = optimize_graph(g_small)
    x = jnp.asarray(np.random.default_rng(0)
                    .standard_normal((1, 3, 64, 64)).astype(np.float32))
    for tag, graph in (("naive", g_small), ("optimized", gopt_small)):
        eng = Engine(graph, None, None)
        eng(x)  # compile
        t0 = time.perf_counter()
        for _ in range(10):
            out = eng(x)
        out[0].block_until_ready()
        csv_rows.append((f"e2e_cpu_wallclock_{tag}",
                         (time.perf_counter() - t0) / 10 * 1e6,
                         "interpret-free XLA-CPU execution, image=64"))
    return csv_rows
