"""Figure 3a + Table 1 reproduction: random vs genetic vs RL-search.

Paper setup: the five production-model convolutions of Table 1 (where
RL-search beat genetic by 1.09-1.66x); random search is the floor — "both
RL-search and genetic search consistently outperform random search".

The paper also reports (§3.2) that on *ResNet-18* convs RL-search did NOT
beat genetic and showed higher variance — we report both tables so the
reproduction is faithful in both directions.
"""

import numpy as np

from repro.core import SearchTask, TEMPLATES, genetic_search, random_search, rl_search
from repro.core.schedules import OpDesc

# Table 1 of the paper (H, W, Cin, Cout, K, stride), batch 1.
TABLE1 = [
    ("conv1a", OpDesc.conv2d(1, 112, 96, 3, 64, 3, 3, stride=1)),
    ("conv1b", OpDesc.conv2d(1, 110, 94, 64, 96, 3, 3, stride=2)),
    ("conv2", OpDesc.conv2d(1, 54, 46, 96, 128, 3, 3, stride=2)),
    ("conv3", OpDesc.conv2d(1, 26, 22, 128, 256, 3, 3, stride=2)),
    ("conv4", OpDesc.conv2d(1, 12, 10, 256, 512, 3, 3, stride=1)),
]


def run(csv_rows, rl_episodes=3, rl_steps=16):
    tmpl = TEMPLATES["pallas_conv2d"]
    ratios = []
    for name, op in TABLE1:
        t_r = SearchTask(op, tmpl, seed=0)
        r_rand = random_search(t_r, budget=200)
        t_g = SearchTask(op, tmpl, seed=0)
        r_gen = genetic_search(t_g)
        t_rl = SearchTask(op, tmpl, seed=0)
        r_rl = rl_search(t_rl, episodes=rl_episodes, steps_per_episode=rl_steps)

        best = min(r_rand.runtime_s, r_gen.runtime_s, r_rl.runtime_s)
        ratios.append((r_rand.runtime_s / best, r_gen.runtime_s / best,
                       r_rl.runtime_s / best))
        csv_rows.append((f"search_fig3a_{name}", best * 1e6,
                         f"random_us={r_rand.runtime_s * 1e6:.2f} "
                         f"genetic_us={r_gen.runtime_s * 1e6:.2f} "
                         f"rl_us={r_rl.runtime_s * 1e6:.2f} "
                         f"rl_evals={r_rl.evals} genetic_evals={r_gen.evals}"))
    arr = np.array(ratios)
    csv_rows.append(("search_fig3a_summary", 0.0,
                     f"mean_slowdown random={arr[:, 0].mean():.3f} "
                     f"genetic={arr[:, 1].mean():.3f} rl={arr[:, 2].mean():.3f} "
                     f"(1.0 = best-of-three; paper: RL wins these 5 by 1.09-1.66x)"))
    return csv_rows
