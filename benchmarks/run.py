"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Select suites with
``python -m benchmarks.run [conv search_methods search_speed e2e roofline]``.
"""

import sys

from benchmarks import (
    bench_conv_operators,
    bench_e2e,
    bench_roofline,
    bench_search_methods,
    bench_search_speed,
    bench_serving,
)

SUITES = {
    "conv": bench_conv_operators.run,          # Fig 2b
    "search_methods": bench_search_methods.run,  # Fig 3a + Table 1
    "search_speed": bench_search_speed.run,    # Fig 3b
    "e2e": bench_e2e.run,                      # §3.4
    "roofline": bench_roofline.run,            # deliverable (g)
    "serving": bench_serving.run,              # §3.4 e2e serving speed
}


def main() -> None:
    wanted = sys.argv[1:] or list(SUITES)
    rows = []
    for name in wanted:
        SUITES[name](rows)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    main()
