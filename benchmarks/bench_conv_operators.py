"""Figure 2b reproduction: per-convolution speedups on ResNet-18.

Paper setup: individual convolution operators extracted from ResNet-18
(N=1, NCHW, 224x224), deduplicated by computational identity; speedup of
WPK (auto-tuned codegen) vs the vendor library (cuDNN there, the XLA
lowering model here).  Paper numbers: WPK 2.54x mean / 5.40x max over cuDNN;
"neither WPK nor TVM is always superior to cuDNN".

Ours reports, per conv group: modeled vendor time, modeled WPK-tuned time
(genetic search winner), speedup, and the roofline bound.  A second column
set gives *measured* CPU wall time of the tuned Pallas kernel in interpret
mode vs the XLA conv for the three smallest groups (laptop-scale sanity that
the tuned configs actually execute).
"""

import time

import numpy as np

from repro.core import Tuner, xla_time, roofline_bound
from repro.models.resnet import conv_groups


def run(csv_rows):
    tuner = Tuner(methods=("genetic",))
    speedups = []
    t0 = time.perf_counter()
    for name, op in conv_groups(batch=1, image=224):
        res = tuner.tune(op)
        t_xla = xla_time(op)
        sp = t_xla / res.runtime_s
        speedups.append(sp)
        csv_rows.append((f"conv_fig2b_{name}", res.runtime_s * 1e6,
                         f"speedup_vs_vendor={sp:.2f} "
                         f"vendor_us={t_xla * 1e6:.2f} "
                         f"roofline_us={roofline_bound(op) * 1e6:.2f} "
                         f"cfg={res.config}"))
    csv_rows.append(("conv_fig2b_mean", (time.perf_counter() - t0) * 1e6,
                     f"mean_speedup={np.mean(speedups):.2f} "
                     f"max_speedup={np.max(speedups):.2f} "
                     f"min_speedup={np.min(speedups):.2f} "
                     f"paper_mean=2.54 paper_max=5.40"))
    return csv_rows
