"""Batched serving engine (the paper is an *inference* system — this is the
end-to-end driver deliverable).

Request lifecycle: submit(prompt) -> queued -> batched prefill -> greedy
decode loop -> done.  The engine runs fixed-size batches (padding the last
batch) with two jit'd programs: `prefill_step` and `serve_step` — the same
functions the multi-pod dry-run lowers, so what is served here is exactly
what was compile-validated on the production mesh.

WPK integration: when the model's matmul/attention backends were tuned by
the WPK plan, the serve path inherits them; the e2e benchmark
(`benchmarks/bench_e2e.py`) compares plans the way the paper's §3.4 compares
WPK vs TensorRT.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import ShardingRules
from repro.launch.steps import jit_prefill_step, jit_serve_step


@dataclasses.dataclass
class ServeConfig:
    batch_size: int = 4
    max_seq: int = 256
    max_new_tokens: int = 32
    eos_id: int = -1          # -1: never stop early (synthetic vocab)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                      # (S,) int32
    output: List[int] = dataclasses.field(default_factory=list)
    latency_s: float = 0.0


class ServeEngine:
    def __init__(self, model, params, mesh, rules: ShardingRules,
                 cfg: ServeConfig, extras: Optional[Dict[str, Any]] = None):
        self.model = model
        self.params = params
        self.mesh = mesh
        self.rules = rules
        self.cfg = cfg
        self.extras = extras or {}
        self.queue: List[Request] = []
        self._rid = 0
        self._prefill = None
        self._decode = None
        self.stats = {"requests": 0, "tokens_out": 0, "decode_s": 0.0,
                      "prefill_s": 0.0}

    def submit(self, prompt: np.ndarray) -> int:
        self._rid += 1
        self.queue.append(Request(self._rid, np.asarray(prompt, np.int32)))
        return self._rid

    def _build(self, prompt_len: int):
        b = self.cfg.batch_size
        batch_specs = {"tokens": jax.ShapeDtypeStruct((b, prompt_len), jnp.int32)}
        for k, v in self.extras.items():
            batch_specs[k] = jax.ShapeDtypeStruct((b,) + v.shape, v.dtype)
        self._prefill = jit_prefill_step(self.model, self.mesh, self.rules,
                                         batch_specs, self.cfg.max_seq, b)
        self._decode = jit_serve_step(self.model, self.mesh, self.rules,
                                      b, self.cfg.max_seq)

    def run(self) -> List[Request]:
        """Drain the queue in fixed-size batches; returns completed requests."""
        done: List[Request] = []
        cfg = self.cfg
        with self.mesh:
            while self.queue:
                batch_reqs = self.queue[: cfg.batch_size]
                self.queue = self.queue[cfg.batch_size:]
                n = len(batch_reqs)
                plen = max(len(r.prompt) for r in batch_reqs)
                toks = np.zeros((cfg.batch_size, plen), np.int32)
                for i, r in enumerate(batch_reqs):
                    toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
                if self._prefill is None:
                    self._build(plen)

                t0 = time.perf_counter()
                batch = {"tokens": jnp.asarray(toks)}
                for k, v in self.extras.items():
                    batch[k] = jnp.broadcast_to(
                        jnp.asarray(v)[None], (cfg.batch_size,) + v.shape)
                logits, cache = self._prefill(self.params, batch)
                nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
                self.stats["prefill_s"] += time.perf_counter() - t0

                t0 = time.perf_counter()
                outs = [nxt]
                for _ in range(cfg.max_new_tokens - 1):
                    logits, cache = self._decode(self.params, cache, nxt[:, None])
                    nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
                    outs.append(nxt)
                out_tokens = np.stack([np.asarray(o) for o in outs], 1)
                dt = time.perf_counter() - t0
                self.stats["decode_s"] += dt

                for i, r in enumerate(batch_reqs):
                    seq = out_tokens[i].tolist()
                    if cfg.eos_id >= 0 and cfg.eos_id in seq:
                        seq = seq[: seq.index(cfg.eos_id) + 1]
                    r.output = seq
                    r.latency_s = dt
                    done.append(r)
                self.stats["requests"] += n
                self.stats["tokens_out"] += n * cfg.max_new_tokens
        return done

    def throughput(self) -> float:
        return self.stats["tokens_out"] / max(1e-9, self.stats["decode_s"])
