"""Serving engines (the pre-unified-step baselines).

`FixedBatchEngine` is the original synchronous drain loop: fixed-size
batches, left-padded prompts, a dedicated whole-prompt prefill program per
prompt-length bucket, every request in a batch decoding the full
`max_new_tokens`.  It remains as (a) the serving path for model families
the continuous runtime has no `FamilyAdapter` for (hybrid / encdec state
caches — see `repro.serve.family`), and (b) the differential baseline the
unified token-budget step is pinned against: `ContinuousEngine` must
produce byte-identical greedy streams to this drain loop for BOTH adapter
families (DecoderLM via the paged KV-cache, MambaLM via the slot-pooled
state cache), which `benchmarks/bench_serving.py` and the serving tests
exercise per family.

`ServeEngine` keeps the historical API (`submit` / `run` / `stats` /
`throughput`) as a thin compatibility wrapper: when the model exposes the
paged decode path (DecoderLM families) and no modality extras are in play
it delegates to `repro.serve.runtime.ContinuousEngine` — a family-agnostic
orchestrator that resolves its per-family state handling (paged KV blocks
vs fixed-size state slots) through the `FamilyAdapter` seam; otherwise it
falls back to the fixed-batch loop.  Mamba2 continuous serving is opted
into explicitly by constructing `ContinuousEngine` directly (or passing
`--engine continuous --family ssm` to the bench), keeping this wrapper's
historical routing stable.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import ShardingRules
from repro.launch.steps import jit_prefill_step, jit_serve_step
from repro.serve.sampling import (SamplingParams, batch_sampling_arrays,
                                  sample_host, truncate_at_eos)


@dataclasses.dataclass
class ServeConfig:
    batch_size: int = 4
    max_seq: int = 256
    max_new_tokens: int = 32
    eos_id: int = -1          # -1: never stop early (synthetic vocab)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                      # (S,) int32
    output: List[int] = dataclasses.field(default_factory=list)
    latency_s: float = 0.0
    sampling: SamplingParams = dataclasses.field(
        default_factory=SamplingParams)


class FixedBatchEngine:
    """The original fixed-batch drain loop (baseline engine).

    Retraces a prefill program per prompt-length bucket and stalls every
    slot for the batch's full `max_new_tokens` — exactly the costs the
    unified token-budget step removes.  Kept as the byte-identical greedy
    reference: at batch_size=1 its drain is the per-request ground truth
    the continuous engine's streams are differentially pinned against for
    both `FamilyAdapter` families."""

    def __init__(self, model, params, mesh, rules: ShardingRules,
                 cfg: ServeConfig, extras: Optional[Dict[str, Any]] = None):
        self.model = model
        self.params = params
        self.mesh = mesh
        self.rules = rules
        self.cfg = cfg
        self.extras = extras or {}
        self.queue: List[Request] = []
        self._rid = 0
        self._prefill = None
        self._decode = None
        self.stats = {"requests": 0, "tokens_out": 0, "decode_s": 0.0,
                      "prefill_s": 0.0}

    def submit(self, prompt: np.ndarray,
               sampling: Optional[SamplingParams] = None) -> int:
        sampling = sampling if sampling is not None else SamplingParams()
        bad = sampling.invalid_reason()
        if bad is not None:
            raise ValueError(f"invalid sampling params: {bad}")
        self._rid += 1
        self.queue.append(Request(self._rid, np.asarray(prompt, np.int32),
                                  sampling=sampling))
        return self._rid

    def _build(self, prompt_len: int):
        b = self.cfg.batch_size
        batch_specs = {"tokens": jax.ShapeDtypeStruct((b, prompt_len), jnp.int32)}
        for k, v in self.extras.items():
            batch_specs[k] = jax.ShapeDtypeStruct((b,) + v.shape, v.dtype)
        self._prefill = jit_prefill_step(self.model, self.mesh, self.rules,
                                         batch_specs, self.cfg.max_seq, b)
        self._decode = jit_serve_step(self.model, self.mesh, self.rules,
                                      b, self.cfg.max_seq)

    def run(self) -> List[Request]:
        """Drain the queue in fixed-size batches; returns completed requests."""
        done: List[Request] = []
        cfg = self.cfg
        with self.mesh:
            while self.queue:
                batch_reqs = self.queue[: cfg.batch_size]
                self.queue = self.queue[cfg.batch_size:]
                n = len(batch_reqs)
                plen = max(len(r.prompt) for r in batch_reqs)
                toks = np.zeros((cfg.batch_size, plen), np.int32)
                for i, r in enumerate(batch_reqs):
                    toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
                if self._prefill is None:
                    self._build(plen)

                # keyed sampling arrays at token index 0 (the prefill
                # sample); greedy requests stay on the bitwise argmax path
                sp, ks = batch_sampling_arrays(batch_reqs, cfg.batch_size)

                t_batch0 = time.perf_counter()
                batch = {"tokens": jnp.asarray(toks)}
                for k, v in self.extras.items():
                    batch[k] = jnp.broadcast_to(
                        jnp.asarray(v)[None], (cfg.batch_size,) + v.shape)
                logits, cache = self._prefill(self.params, batch)
                nxt = sample_host(logits[:, -1], sp, ks)
                outs = [np.asarray(nxt)]           # forces device sync
                tok_t = [time.perf_counter()]
                self.stats["prefill_s"] += tok_t[0] - t_batch0

                t0 = time.perf_counter()
                for j in range(1, cfg.max_new_tokens):
                    logits, cache = self._decode(self.params, cache, nxt[:, None])
                    ks[:, 2] = j                   # token index advances
                    nxt = sample_host(logits[:, -1], sp, ks)
                    outs.append(np.asarray(nxt))
                    tok_t.append(time.perf_counter())
                out_tokens = np.stack(outs, 1)
                self.stats["decode_s"] += time.perf_counter() - t0

                for i, r in enumerate(batch_reqs):
                    r.output = truncate_at_eos(out_tokens[i].tolist(),
                                               cfg.eos_id)
                    # latency is THIS request's: batch start to the step
                    # that emitted its last surviving token (eos-truncated
                    # requests stop accruing at their eos step, even though
                    # the fixed batch keeps draining)
                    r.latency_s = tok_t[len(r.output) - 1] - t_batch0
                    done.append(r)
                    # count tokens actually emitted, not the drain budget
                    self.stats["tokens_out"] += len(r.output)
                self.stats["requests"] += n
        return done

    def throughput(self) -> float:
        return self.stats["tokens_out"] / max(1e-9, self.stats["decode_s"])


class ServeEngine:
    """Compatibility wrapper: historical API over the continuous runtime.

    Models with a paged decode path are served by `ContinuousEngine`
    (continuous batching behind the `repro.serve.family` adapter seam);
    other families fall back to the fixed-batch loop transparently.  The
    routing predicate is deliberately unchanged by the family seam: ssm
    continuous serving is an explicit `ContinuousEngine` construction, not
    a silent rerouting of existing `ServeEngine` users."""

    def __init__(self, model, params, mesh, rules: ShardingRules,
                 cfg: ServeConfig, extras: Optional[Dict[str, Any]] = None):
        self.cfg = cfg
        self._continuous = (hasattr(model, "decode_step_paged")
                            and not extras)
        if self._continuous:
            from repro.serve.runtime import ContinuousEngine, RuntimeConfig
            block = 16
            rcfg = RuntimeConfig(
                max_slots=cfg.batch_size,
                block_size=block,
                max_blocks_per_seq=max(1, -(-cfg.max_seq // block)),
                max_new_tokens=cfg.max_new_tokens,
                eos_id=cfg.eos_id,
            )
            self._engine = ContinuousEngine(model, params, mesh, rules, rcfg)
        else:
            self._engine = FixedBatchEngine(model, params, mesh, rules, cfg,
                                            extras)
        self.stats = {"requests": 0, "tokens_out": 0, "decode_s": 0.0,
                      "prefill_s": 0.0}

    def submit(self, prompt: np.ndarray,
               sampling: Optional[SamplingParams] = None) -> int:
        return self._engine.submit(prompt, sampling=sampling)

    def run(self) -> List[Request]:
        if not self._continuous:
            done = self._engine.run()
            self.stats = self._engine.stats
            return done
        reqs = self._engine.run()
        m = self._engine.metrics
        self.stats["requests"] += m.requests_done
        self.stats["tokens_out"] += m.tokens_out
        # device-compute split, same semantics as FixedBatchEngine's stats
        # (wall time incl. arrival idle lives in the runtime's own metrics)
        self.stats["decode_s"] += m.decode_time_s
        self.stats["prefill_s"] += m.prefill_time_s
        self._engine.reset_metrics()  # next run() accumulates a fresh delta
        done = [Request(r.rid, r.prompt, list(r.output), r.latency_s)
                for r in sorted(reqs, key=lambda r: r.rid)]
        return done

    def throughput(self) -> float:
        return self.stats["tokens_out"] / max(1e-9, self.stats["decode_s"])
