"""Slot-pooled state cache for continuous batching of state-cache families.

The paged KV-cache (`kvcache.py`) exists because attention state GROWS with
the sequence; Mamba2's per-request state does not — one depthwise-conv
window (W-1, conv_dim) plus one SSM state (nh, hd, n) per layer, the same
size at token 1 and token 10k.  So the pool idea survives with the growth
machinery deleted: the cache is a fixed grid of *state slots*, a request
owns exactly ONE row of it for its whole residency, and "allocation" is a
free-list pop.  Everything else mirrors `BlockAllocator` deliberately:

  * row 0 is the reserved NULL slot — idle decode rows and padding point at
    it so device-side gathers/scatters never need a mask branch (colliding
    writes land in garbage nobody reads);
  * a preempted request's state is copied to a host buffer and its row
    returns to the free list (`swap_out`); resume claims a fresh row —
    possibly a different physical id, the index array is the only
    indirection — and scatters the host state back;
  * the same invariant-checking discipline (`check_invariants` after every
    mutation in the property suite), and the same trace taxonomy: slot
    claims/releases emit `block_alloc` / `block_free` with n=1, so the
    traceview pool-conservation replay audits a slot pool with zero new
    code.

`SlotCapacity` is this family's admission/footprint model for the
`ContinuousScheduler` capacity seam (see scheduler.py): fresh admission
reserves NOTHING — the slot is claimed lazily when the request's first
prompt chunk dispatches — so a state pool smaller than the slot count
organically drives the engine's preemption path instead of blocking
admission.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.serve.trace import NULL_RECORDER

NULL_SLOT = 0  # reserved sink row — never allocated to a request


@dataclasses.dataclass(frozen=True)
class StateCacheConfig:
    num_slots: int = 8  # physical pool rows (incl. the null row)

    @property
    def usable(self) -> int:
        return self.num_slots - 1


class SlotAllocator:
    """Free-list allocation of state-slot rows, one per resident request.

    The degenerate (block_size = whole request, no growth) rendering of
    `BlockAllocator`: same free-list, ownership, swap bookkeeping and
    invariants, specialised to exactly one row per request."""

    def __init__(self, cfg: StateCacheConfig):
        if cfg.num_slots < 2:
            raise ValueError("need at least 2 slots (one is the null row)")
        self.cfg = cfg
        # row 0 reserved as the null sink
        self._free: List[int] = list(range(cfg.num_slots - 1, NULL_SLOT, -1))
        self.owners: Dict[int, int] = {}
        # rid -> row count held at swap-out (always 1; kept as a COUNT so
        # the scheduler's resume gate reads it exactly like the paged
        # allocator's `swapped`)
        self.swapped: Dict[int, int] = {}
        self.trace = NULL_RECORDER

    # ------------------------------------------------------------ queries
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return self.cfg.usable - len(self._free)

    def occupancy(self) -> float:
        return self.num_used / self.cfg.usable if self.cfg.usable else 0.0

    def can_allocate(self, n_slots: int = 1) -> bool:
        return n_slots <= len(self._free)

    def holds(self, rid: int) -> bool:
        return rid in self.owners

    def slot_of(self, rid: int) -> int:
        return self.owners[rid]

    # -------------------------------------------------------- alloc / free
    def allocate(self, rid: int) -> int:
        """Claim one state row for request `rid`; returns the row id."""
        if rid in self.owners:
            raise ValueError(f"request {rid} already holds a state slot")
        if rid in self.swapped:
            raise ValueError(f"request {rid} is swapped out; use swap_in")
        if not self._free:
            raise MemoryError(
                f"state pool exhausted: want 1, free {len(self._free)}")
        row = self._free.pop()
        self.owners[rid] = row
        self.trace.emit("block_alloc", rid=rid, n=1,
                        free_after=len(self._free))
        return row

    def free(self, rid: int) -> int:
        """Return rid's state row to the free list."""
        row = self.owners.pop(rid)
        self._free.append(row)
        self.trace.emit("block_free", rid=rid, n=1,
                        free_after=len(self._free))
        return 1

    # ------------------------------------------------------------- swapping
    def swap_out(self, rid: int) -> int:
        """Release rid's row while remembering it held one; the caller saves
        the row *contents* first (see `SlotStateCache.swap_out`)."""
        if rid in self.swapped:
            raise ValueError(f"request {rid} already swapped out")
        n = self.free(rid)
        self.swapped[rid] = n
        return n

    def swap_in(self, rid: int) -> int:
        """Re-claim a row for a swapped-out request (fresh physical id);
        raises MemoryError if the pool is dry."""
        if not self.can_allocate(self.swapped[rid]):
            raise MemoryError(
                f"state pool exhausted on swap-in: want "
                f"{self.swapped[rid]}, free {len(self._free)}")
        del self.swapped[rid]
        return self.allocate(rid)

    def check_invariants(self) -> None:
        """Every usable row is either free or owned by exactly one request."""
        owned = list(self.owners.values())
        assert NULL_SLOT not in owned, "null slot leaked into ownership"
        assert NULL_SLOT not in self._free, "null slot leaked into free list"
        combined = sorted(owned + self._free)
        assert combined == list(range(1, self.cfg.num_slots)), (
            f"slot accounting broken: {combined}")
        assert len(set(owned)) == len(owned), "slot double-owned"
        assert not (set(self.swapped) & set(self.owners)), (
            "request both active and swapped out")
        assert all(n == 1 for n in self.swapped.values())


class SlotStateCache:
    """Device-side state pools plus the allocator.

    `conv` is (n_layers, num_slots, conv_width-1, conv_dim) and `ssm`
    (n_layers, num_slots, nheads, head_dim, d_state), both f32 — the same
    dtype the fixed-batch decode carries, which is what makes continuous
    serving bitwise comparable to its drain."""

    def __init__(self, cfg: StateCacheConfig, n_layers: int, conv_width: int,
                 conv_dim: int, nheads: int, head_dim: int, d_state: int,
                 shardings=None):
        self.cfg = cfg
        self.alloc = SlotAllocator(cfg)
        conv_shape = (n_layers, cfg.num_slots, conv_width - 1, conv_dim)
        ssm_shape = (n_layers, cfg.num_slots, nheads, head_dim, d_state)
        # `shardings` — a (conv NamedSharding, ssm NamedSharding) pair —
        # creates the pools DIRECTLY in their serving layout (rows
        # replicated, feature dims over the model axis), so the donated
        # pool arguments never layout-shift between the first step and the
        # rest: exactly one executable per program.
        if shardings is not None:
            conv_shard, ssm_shard = shardings
            self.conv = jnp.zeros(conv_shape, jnp.float32, device=conv_shard)
            self.ssm = jnp.zeros(ssm_shape, jnp.float32, device=ssm_shard)
        else:
            self.conv = jnp.zeros(conv_shape, jnp.float32)
            self.ssm = jnp.zeros(ssm_shape, jnp.float32)
        # rid -> (conv_host, ssm_host): preempted requests' state lives
        # here, off-device, until swap-in
        self._swapped: Dict[int, tuple] = {}

    @classmethod
    def for_model(cls, cfg: StateCacheConfig, model_cfg,
                  shardings=None) -> "SlotStateCache":
        from repro.models.mamba import _dims
        d_in, nh, conv_dim = _dims(model_cfg)
        return cls(cfg, model_cfg.n_layers, model_cfg.conv_width, conv_dim,
                   nh, model_cfg.ssm_head_dim, model_cfg.ssm_state,
                   shardings=shardings)

    # ------------------------------------------------------------- swapping
    def is_swapped(self, rid: int) -> bool:
        return rid in self._swapped

    def swap_out(self, rid: int) -> int:
        """Copy rid's state row to a host buffer and release the row;
        returns the bytes moved."""
        row = self.alloc.owners[rid]
        conv_host = np.asarray(self.conv[:, row])
        ssm_host = np.asarray(self.ssm[:, row])
        self._swapped[rid] = (conv_host, ssm_host)
        nbytes = conv_host.nbytes + ssm_host.nbytes
        self.alloc.trace.emit("swap_out", rid=rid, nbytes=nbytes, n_blocks=1)
        self.alloc.swap_out(rid)
        return nbytes

    def take_swapped(self, rid: int):
        """Pop rid's host-side (conv, ssm) buffers for swap-in; the caller
        scatters them at the freshly claimed row."""
        return self._swapped.pop(rid)

    def index_array(self, slot_rids: List[Optional[int]]) -> np.ndarray:
        """Dense (max_slots,) int32 state-row array for the jitted decode
        step; slots without a resident state-holding request point at the
        null row."""
        out = np.full((len(slot_rids),), NULL_SLOT, np.int32)
        for s, rid in enumerate(slot_rids):
            if rid is not None and rid in self.alloc.owners:
                out[s] = self.alloc.owners[rid]
        return out


class SlotCapacity:
    """The state-cache family's admission/footprint model for the
    `ContinuousScheduler` capacity seam.

    Fresh admission reserves NOTHING: the state row is claimed lazily by
    the engine when the request's first prompt chunk dispatches, through
    the same grow-or-preempt path that handles paged-KV growth — which is
    how a state pool smaller than the slot count forces preemption instead
    of deadlocking admission.  Resume must re-claim a row up front (the
    host state has to be scattered back before the request can run), so it
    gates on the free list exactly like the paged resume gates on blocks."""

    def __init__(self, alloc: SlotAllocator):
        self.alloc = alloc

    def submit_reason(self, req) -> Optional[str]:
        # any single request fits: one row, and the pool has >= 1 usable row
        return None

    def can_admit_fresh(self, req) -> bool:
        return True

    def admit_fresh(self, req) -> None:
        pass

    def can_admit_resume(self, req) -> bool:
        return self.alloc.can_allocate(self.alloc.swapped[req.rid])

    def admit_resume(self, req) -> None:
        self.alloc.swap_in(req.rid)

    def release(self, req) -> None:
        self.alloc.free(req.rid)

    def occupancy(self) -> float:
        return self.alloc.occupancy()
