"""Plan-aware backend routing for the serving runtime.

The WPK `InferencePlan` is per-operator *and per-shape*: the matmuls and
attention of a prefill (long query, batch 1) live at a very different point
of the roofline than the decode step (query length 1, batch = slot count),
and the unified step's chunked prefill (a fixed `chunk_tokens`-wide query
against a growing paged cache) at a third.  The old engine "inherited" one
plan for everything; here the serve graph is built with each shape family
as distinct named nodes —

    prefill.attention   decode.attention   prefill_chunk.attention
    prefill.qkv_proj    decode.qkv_proj    prefill_chunk.qkv_proj
    prefill.mlp_up      decode.mlp_up      prefill_chunk.mlp_up
    prefill.mlp_down    decode.mlp_down    prefill_chunk.mlp_down
    prefill.lm_head     decode.lm_head     prefill_chunk.lm_head

— and `selection.select` races the XLA lane against every applicable tuned
Pallas template for each of them separately.  (`prefill` describes the
whole-prompt shape family; the unified runtime executes prompts through
`prefill_chunk`, whose attention config tunes the chunked-prefill kernel's
`block_q` and whose matmuls are tuned at the chunk's (1, chunk_tokens, d)
shape.)  `PlanRouter` then answers the runtime's dispatch questions
("which attention backend for decode?", "which matmul config for the
chunk?", "how many segments may pack into one chunk?") by stage-qualified
lookup into that plan, with `prefill_chunk` falling back to the `prefill`
stage's choice on plans tuned before the stage existed — and to
single-segment packing on plans tuned before the segmented kernel existed
(`chunk_segments`).  `matmul_table(stage)` bundles every stage matmul's
(backend, config) into the dispatch table
`kernels.dispatch.matmul_dispatch` installs around the jitted serve
programs.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

from repro import hw
from repro.configs.base import ModelConfig
from repro.core.graph import Graph
from repro.core.plan import InferencePlan, OpChoice
from repro.core.selection import select
from repro.core.search.tuner import Tuner

# Stage-qualified serve stages, per model family.  The decoder family's
# three stages are the original serve graph; the ssm (state-cache) family
# has no attention op and a different matmul role set (in_proj / out_proj /
# lm_head — see repro.models.mamba), so its stages are distinct nodes and a
# plan may tune both families side by side.
FAMILY_STAGES = {
    "decoder": ("prefill", "decode", "prefill_chunk"),
    "ssm": ("ssm_prefill_chunk", "ssm_decode"),
}
STAGES = FAMILY_STAGES["decoder"] + FAMILY_STAGES["ssm"]

# The model's routable matmul roles per family (the decoder's canonical
# four live in kernels.dispatch.MATMUL_ROLES).
SSM_MATMUL_ROLES = ("in_proj", "out_proj", "lm_head")


def serve_stages(family: str):
    """The serve-plan stages a family's engine dispatches through."""
    return FAMILY_STAGES.get(family, FAMILY_STAGES["decoder"])

# The unified step's default per-step prompt-token budget.  This is THE
# canonical constant: `RuntimeConfig.chunk_tokens` defaults to it and
# `build_serve_graph` falls back to it when no explicit width is passed, so
# an untuned plan and a default engine can never drift onto different chunk
# shapes.
DEFAULT_CHUNK_TOKENS = 32


def _build_ssm_serve_graph(cfg: ModelConfig, *, slots: int,
                           chunk_tokens: Optional[int],
                           dtype: str) -> Graph:
    """The ssm family's serve-time operator set: no attention op — the SSD
    scan is not a raced template (yet) — but the projections dominate the
    matmul time and are raced per stage at the shapes the slot-pooled step
    programs actually run (a chunk-wide prefill segment vs a slots-wide
    single-token decode).  The chunk width is rounded UP to a multiple of
    `cfg.ssm_chunk`, mirroring `SSMFamilyAdapter`'s resolved lane width."""
    g = Graph("serve_ssm")
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    nh = d_in // cfg.ssm_head_dim
    proj = 2 * d_in + 2 * cfg.ssm_state + nh
    q = max(1, cfg.ssm_chunk)
    ct = min(chunk_tokens or DEFAULT_CHUNK_TOKENS, 4096)
    ct = -(-ct // q) * q

    xc = g.add_input("x_ssm_chunk", (1, ct, d), dtype)
    wi = g.add_input("w_in_proj", (d, proj), dtype)
    in_c = g.add_node("matmul", [xc, wi], (1, ct, proj), out_dtype=dtype,
                      name="ssm_prefill_chunk.in_proj")
    yc = g.add_input("y_ssm_chunk", (1, ct, d_in), dtype)
    wo = g.add_input("w_out_proj", (d_in, d), dtype)
    out_c = g.add_node("matmul", [yc, wo], (1, ct, d), out_dtype=dtype,
                       name="ssm_prefill_chunk.out_proj")
    wl = g.add_input("w_lm_ssm", (d, cfg.vocab), dtype)
    xl = g.add_input("x_ssm_last", (1, 1, d), dtype)
    lm_c = g.add_node("matmul", [xl, wl], (1, 1, cfg.vocab), out_dtype=dtype,
                      name="ssm_prefill_chunk.lm_head")

    xd = g.add_input("x_ssm_decode", (slots, 1, d), dtype)
    in_d = g.add_node("matmul", [xd, wi], (slots, 1, proj), out_dtype=dtype,
                      name="ssm_decode.in_proj")
    yd = g.add_input("y_ssm_decode", (slots, 1, d_in), dtype)
    out_d = g.add_node("matmul", [yd, wo], (slots, 1, d), out_dtype=dtype,
                       name="ssm_decode.out_proj")
    lm_d = g.add_node("matmul", [xd, wl], (slots, 1, cfg.vocab),
                      out_dtype=dtype, name="ssm_decode.lm_head")

    g.set_outputs([in_c, out_c, lm_c, in_d, out_d, lm_d])
    return g


def build_serve_graph(cfg: ModelConfig, *, prefill_len: int, slots: int,
                      max_seq: int, chunk_tokens: Optional[int] = None,
                      dtype: str = "float32",
                      family: str = "decoder") -> Graph:
    """The serve-time operator set as a Graph with stage-qualified names.

    `chunk_tokens` is the unified step's per-step prompt-token budget (the
    width of the prefill_chunk stage's query).  Pass the engine's RESOLVED
    width — `RuntimeConfig.chunk_width` — so the chunk lane is tuned at
    the shape it actually runs (in particular, an unchunked baseline
    engine, RuntimeConfig.chunk_tokens=None, runs a max_seq-wide lane).
    None here falls back to the RuntimeConfig field's default budget
    (32), matching an engine built with a default RuntimeConfig.

    `family="ssm"` builds the state-cache family's stage set instead
    (`ssm_prefill_chunk` / `ssm_decode`; see `FAMILY_STAGES`)."""
    if family == "ssm":
        return _build_ssm_serve_graph(cfg, slots=slots,
                                      chunk_tokens=chunk_tokens, dtype=dtype)
    g = Graph("serve")
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    # RuntimeConfig.chunk_tokens defaults to the same shared constant, so
    # an untuned call still tunes the chunk lane at the default engine's
    # shape — the two can't drift.
    ct = min(chunk_tokens or DEFAULT_CHUNK_TOKENS, max_seq)

    # ---- prefill stage: one request, `prefill_len` query tokens
    xp = g.add_input("x_prefill", (1, prefill_len, d), dtype)
    wq = g.add_input("w_qkv", (d, (h + 2 * hkv) * hd), dtype)
    qkv_p = g.add_node("matmul", [xp, wq], (1, prefill_len, (h + 2 * hkv) * hd),
                       out_dtype=dtype, name="prefill.qkv_proj")
    qp = g.add_input("q_prefill", (1, prefill_len, h, hd), dtype)
    kp = g.add_input("k_prefill", (1, prefill_len, hkv, hd), dtype)
    att_p = g.add_node("attention", [qp, kp, kp], (1, prefill_len, h, hd),
                       out_dtype=dtype, name="prefill.attention")
    wu = g.add_input("w_up", (d, cfg.d_ff), dtype)
    mlp_p = g.add_node("matmul", [xp, wu], (1, prefill_len, cfg.d_ff),
                       out_dtype=dtype, name="prefill.mlp_up")
    wd = g.add_input("w_down", (cfg.d_ff, d), dtype)
    hp = g.add_input("h_prefill", (1, prefill_len, cfg.d_ff), dtype)
    mlpd_p = g.add_node("matmul", [hp, wd], (1, prefill_len, d),
                        out_dtype=dtype, name="prefill.mlp_down")
    wl = g.add_input("w_lm", (d, cfg.vocab), dtype)
    lm_p = g.add_node("matmul", [xp, wl], (1, prefill_len, cfg.vocab),
                      out_dtype=dtype, name="prefill.lm_head")

    # ---- decode stage: `slots` requests, one query token each, long cache
    xd = g.add_input("x_decode", (slots, 1, d), dtype)
    qkv_d = g.add_node("matmul", [xd, wq], (slots, 1, (h + 2 * hkv) * hd),
                       out_dtype=dtype, name="decode.qkv_proj")
    qd = g.add_input("q_decode", (slots, 1, h, hd), dtype)
    kd = g.add_input("k_decode", (slots, max_seq, hkv, hd), dtype)
    att_d = g.add_node("attention", [qd, kd, kd], (slots, 1, h, hd),
                       out_dtype=dtype, name="decode.attention")
    mlp_d = g.add_node("matmul", [xd, wu], (slots, 1, cfg.d_ff),
                       out_dtype=dtype, name="decode.mlp_up")
    hd_ = g.add_input("h_decode", (slots, 1, cfg.d_ff), dtype)
    mlpd_d = g.add_node("matmul", [hd_, wd], (slots, 1, d),
                        out_dtype=dtype, name="decode.mlp_down")
    lm_d = g.add_node("matmul", [xd, wl], (slots, 1, cfg.vocab),
                      out_dtype=dtype, name="decode.lm_head")

    # ---- prefill_chunk stage: one request, a `chunk_tokens`-wide slice of
    # its prompt attending to the (up to max_seq) committed paged cache —
    # the shape family the unified step actually runs prompts through
    xc = g.add_input("x_chunk", (1, ct, d), dtype)
    qkv_c = g.add_node("matmul", [xc, wq], (1, ct, (h + 2 * hkv) * hd),
                       out_dtype=dtype, name="prefill_chunk.qkv_proj")
    qc = g.add_input("q_chunk", (1, ct, h, hd), dtype)
    kc = g.add_input("k_chunk", (1, max_seq, hkv, hd), dtype)
    att_c = g.add_node("attention", [qc, kc, kc], (1, ct, h, hd),
                       out_dtype=dtype, name="prefill_chunk.attention")
    mlp_c = g.add_node("matmul", [xc, wu], (1, ct, cfg.d_ff),
                       out_dtype=dtype, name="prefill_chunk.mlp_up")
    hc = g.add_input("h_chunk", (1, ct, cfg.d_ff), dtype)
    mlpd_c = g.add_node("matmul", [hc, wd], (1, ct, d),
                        out_dtype=dtype, name="prefill_chunk.mlp_down")
    lm_c = g.add_node("matmul", [xc, wl], (1, ct, cfg.vocab),
                      out_dtype=dtype, name="prefill_chunk.lm_head")

    g.set_outputs([qkv_p, att_p, mlp_p, mlpd_p, lm_p,
                   qkv_d, att_d, mlp_d, mlpd_d, lm_d,
                   qkv_c, att_c, mlp_c, mlpd_c, lm_c])
    return g


def build_serve_plan(cfg: ModelConfig, *, prefill_len: int, slots: int,
                     max_seq: int, chunk_tokens: Optional[int] = None,
                     chip: hw.Chip = hw.TPU_V5E,
                     tuner: Optional[Tuner] = None,
                     dtype: str = "bfloat16",
                     family: str = "decoder",
                     model_parallel: int = 1) -> InferencePlan:
    """Tune the serve graph and return its stage-qualified InferencePlan.

    `model_parallel` > 1 additionally races each stage matmul's LAYOUT
    (replicated vs model-parallel over that many devices, collectives
    priced by `core.costmodel`) — the tuned plan then carries a per-stage
    layout table `PlanRouter.serve_rules` folds into the `ShardingRules`
    the step builders compile under."""
    # dtype forwarded so the graph's tensors carry the width the plan is
    # tuned for (dtype-sensitive validation/cost modelling sees bf16, not a
    # float32 default that never matches the plan).
    g = build_serve_graph(cfg, prefill_len=prefill_len, slots=slots,
                          max_seq=max_seq, chunk_tokens=chunk_tokens,
                          dtype=dtype, family=family)
    return select(g, tuner=tuner, chip=chip, dtype=dtype,
                  model_parallel=model_parallel)


class PlanRouter:
    """Answers serve-time dispatch questions from a stage-qualified plan.

    With no plan (or no matching choice) every query falls back to the XLA
    lane — the runtime stays correct, just untuned."""

    def __init__(self, plan: Optional[InferencePlan] = None):
        self.plan = plan

    def _lookup(self, stage: str, op: str) -> Optional[OpChoice]:
        if self.plan is None:
            return None
        # exact stage-qualified name first, then any stage-prefixed op match
        choice = self.plan.choice(f"{stage}.{op}")
        if choice is not None:
            return choice
        for name, c in self.plan.choices.items():
            if name.startswith(f"{stage}.") and name.split(".", 1)[1].startswith(op):
                return c
        if stage == "prefill_chunk":
            # plans tuned before the chunk stage existed: the whole-prompt
            # prefill choice is the closest shape family — better than
            # silently dropping to untuned XLA
            return self._lookup("prefill", op)
        return None

    def attention_backend(self, stage: str) -> Tuple[str, Dict[str, Any]]:
        """-> ('xla' | 'pallas_attention', tuned config)."""
        assert stage in STAGES, stage
        c = self._lookup(stage, "attention")
        if c is None or c.backend == "xla":
            return "xla", {}
        return "pallas_attention", dict(c.config)

    def chunk_segments(self, default: int = 1) -> int:
        """Packing width of the prefill lane: how many requests' prompt
        segments one step's chunk may carry.

        The segmented kernel's grid is block_q x max-segments, and
        `max_segments` is a tunable of the `prefill_chunk` stage's
        attention template — a plan that raced it returns the tuned value
        here.  PALLAS configs tuned BEFORE the segmented kernel existed
        (no `max_segments` key) fall back to single-segment behaviour:
        their block_q was only ever raced on the one-request grid.  An XLA
        choice — whatever the plan's age — and the no-plan case both defer
        to the engine's own default: the XLA gather lane computes an
        identical per-row program at every packing width, so there is no
        tuned grid to protect and nothing to cap."""
        if self.plan is None:
            return max(1, default)
        c = self._lookup("prefill_chunk", "attention")
        if c is None or c.backend == "xla":
            return max(1, default)
        if "max_segments" in c.config:
            return max(1, int(c.config["max_segments"]))
        return 1

    def matmul_config(self, stage: str,
                      which: str = "qkv_proj") -> Tuple[str, Dict[str, Any]]:
        """-> ('xla' | 'pallas_matmul', tuned config) for a stage matmul."""
        assert stage in STAGES, stage
        c = self._lookup(stage, which)
        if c is None or c.backend == "xla":
            return "xla", {}
        return "pallas_matmul", dict(c.config)

    def matmul_table(self, stage: str) -> Dict[str, Tuple[str, Dict[str, Any]]]:
        """Every stage matmul's (backend, config) keyed by role — the
        dispatch table `kernels.dispatch.matmul_dispatch` installs around
        the stage's jitted program.  ssm stages use the ssm family's role
        set (there is no qkv/mlp in a Mamba block)."""
        from repro.kernels.dispatch import MATMUL_ROLES
        roles = SSM_MATMUL_ROLES if stage.startswith("ssm_") else MATMUL_ROLES
        return {role: self.matmul_config(stage, role) for role in roles}

    # -------------------------------------------------------------- layouts
    def layout(self, stage: str, which: str) -> str:
        """The plan's layout verdict for one stage op: 'replicated' |
        'model_parallel'.  No plan / no choice / pre-layout plans answer
        'replicated' — the single-device semantics they were tuned under."""
        c = self._lookup(stage, which)
        if c is None:
            return "replicated"
        return getattr(c, "layout", "replicated")

    def layout_table(self, stage: str) -> Dict[str, str]:
        """Every stage op's layout verdict keyed by role (matmul roles plus
        'attention' for decoder stages) — stamped into trace metadata and
        folded into `serve_rules`."""
        from repro.kernels.dispatch import MATMUL_ROLES
        if stage.startswith("ssm_"):
            roles = SSM_MATMUL_ROLES
        else:
            roles = tuple(MATMUL_ROLES) + ("attention",)
        return {role: self.layout(stage, role) for role in roles}

    def _raced_replicated(self, stages, roles) -> bool:
        """True when any raced stage choice EXPLICITLY chose the replicated
        layout — the demotion trigger for the roles' logical axes.  Choices
        that never raced layouts (old plans, indivisible shard dims) don't
        demote: the base rules' divisibility guards already govern them."""
        for s in stages:
            for r in roles:
                c = self._lookup(s, r)
                if c is None:
                    continue
                if (getattr(c, "layout_candidates", {})
                        and getattr(c, "layout", "replicated")
                        == "replicated"):
                    return True
        return False

    def serve_rules(self, base_rules, mesh, cfg: ModelConfig,
                    family: str = "decoder"):
        """Fold the plan's per-stage layout verdicts into the
        `ShardingRules` the step builders compile under.

        Monotone by construction — this only ever NARROWS `base_rules`
        (demotes logical axes to replicated), never promotes, so the base
        table is the maximal layout and token streams stay byte-identical
        across every mesh size.  Three tiers:

          * model axis size <= 1: `base_rules` returned untouched — the
            single-device path is exactly the pre-mesh engine;
          * no plan: `base_rules` with the divisibility guards of
            `launch.steps.rules_for_shape` applied (full model-parallel
            wherever legal);
          * tuned plan: guards plus demotion of every role group whose
            serving-stage choices explicitly raced layouts and chose
            replicated — coupled axes (mlp_up/mlp_down share 'ffn';
            qkv/attention share the head axes; in_proj/out_proj share the
            conv/state dims) demote together, so one `ShardingRules`
            always exists that honours every verdict."""
        m = mesh.shape.get("model", 1)
        if m <= 1:
            return base_rules
        rules = base_rules
        # divisibility guards: each sharded dim must divide the model axis
        if family == "ssm":
            d_in = cfg.ssm_expand * cfg.d_model
            nh = d_in // cfg.ssm_head_dim
            conv_dim = d_in + 2 * cfg.ssm_state
            if nh % m:
                rules = rules.replace(ssm_heads=None)
            if conv_dim % m:
                rules = rules.replace(conv_dim=None)
        else:
            if cfg.n_heads and cfg.n_heads % m:
                rules = rules.replace(heads=None)
            if cfg.n_kv_heads and cfg.n_kv_heads % m:
                rules = rules.replace(kv_heads=None)
            if cfg.d_ff and cfg.d_ff % m:
                rules = rules.replace(ffn=None)
        if cfg.vocab % m:
            rules = rules.replace(vocab=None)
            if cfg.d_model % m == 0:
                rules = rules.replace(embed_vec="model")
        if self.plan is None:
            return rules
        # the stages the engine actually dispatches through ('prefill' is
        # the whole-prompt shape family benches tune, not a serve stage)
        stages = tuple(s for s in serve_stages(family) if s != "prefill")
        if family == "ssm":
            if self._raced_replicated(stages, ("in_proj", "out_proj")):
                rules = rules.replace(conv_dim=None, ssm_heads=None)
        else:
            if self._raced_replicated(stages, ("qkv_proj", "attention")):
                rules = rules.replace(heads=None, kv_heads=None)
            if self._raced_replicated(stages, ("mlp_up", "mlp_down")):
                rules = rules.replace(ffn=None)
        if self._raced_replicated(stages, ("lm_head",)):
            rules = rules.replace(vocab=None, embed_vec=None)
        return rules

    def describe(self) -> Dict[str, str]:
        """Stage-qualified op -> chosen backend (for logs and benches)."""
        if self.plan is None:
            return {}
        return {name: c.backend for name, c in sorted(self.plan.choices.items())}
