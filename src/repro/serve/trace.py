"""Structured event tracing for the continuous-batching serving runtime.

`ServeMetrics` answers "how did the run go" with end-of-run aggregates; this
module answers "where did request 17's nine seconds go" with a per-event
record of everything the scheduler, allocator and step dispatcher decided,
stamped against the ENGINE clock (wall time for real serving, virtual time
for deterministic replays — the same injectable `now_fn` the runtime already
uses, so a traced virtual replay is reproducible event-for-event).

Three pieces:

  * `TraceRecorder` — an append-only list of typed `TraceEvent`s.  The
    event taxonomy (`EVENT_TYPES`) covers the full request lifecycle
    (`submit` / `reject` / `admit` / `chunk_scheduled` / `chunk_committed` /
    `first_token` / `decode_token` / `finish`), preemption
    (`preempt` / `swap_out` / `swap_in` / `resume`), pool accounting
    (`block_alloc` / `block_extend` / `block_free` / `block_share` /
    `cow_copy`), and per-step dispatch
    (`step_begin` / `step_end` with step kind, lane width, segment count,
    fill and device time, plus `compile` when a step program traces).
    Unknown event names are rejected loudly — the audit layer
    (`repro.serve.traceview`) depends on the taxonomy being closed.
  * `NullTraceRecorder` / `NULL_RECORDER` — the disabled path.  Emission
    sites hold a recorder attribute and either call its no-op `emit` or
    guard per-token hot loops on the recorder's `enabled` flag, so serving
    with tracing off costs one attribute lookup per site and allocates
    nothing.
  * the Chrome-trace-event exporter (`to_chrome_trace` / `write_trace`) —
    a whole Poisson replay opens in `ui.perfetto.dev`: one track per
    request (queued / prefill / stall / decode phase spans plus lifecycle
    instants), a scheduler track of step spans (unified vs decode-only,
    lane fill in the args), and a KV-pool counter track of free blocks.
    `write_trace` also embeds the raw event stream and a `ServeMetrics`
    snapshot under the `reproServe` key — unknown top-level keys are
    ignored by Perfetto, and the audit CLI
    (`python -m repro.serve.traceview trace.json`) reads them back to
    cross-validate the trace against the recorded aggregates.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Dict, List, Optional, Tuple

# The closed event taxonomy.  Grouped by the subsystem that emits them.
EVENT_TYPES = frozenset({
    # request lifecycle (scheduler.py / runtime.py)
    "submit",          # rid, arrival, prompt_len, max_new
                       #   [+ temperature, top_k, top_p, seed when sampled]
    "reject",          # rid, reason
    "admit",           # rid, slot, kind ("fresh"|"resume"[, stall_s])
    "chunk_scheduled",  # rid, start, n        (one per packed segment)
    "chunk_committed",  # rid, start, n, prefilled
    "first_token",     # rid, token
    "decode_token",    # rid, token
    "finish",          # rid, n_output, digest  (the terminal event; digest
                       #   = stream_digest of the full output stream)
    # preemption / swap (runtime.py / kvcache.py)
    "preempt",         # rid, slot
    "swap_out",        # rid, nbytes, n_blocks
    "swap_in",         # rid, nbytes
    "resume",          # rid, stall_s, swap_in_s
    # pool accounting (kvcache.py BlockAllocator).  `free_after` on every
    # event lets the audit replay pool conservation step by step; under
    # refcounting a free only `released` the blocks whose last owner let
    # go (absent on pre-sharing traces — then released == n).
    "block_alloc",     # rid, n, free_after
    "block_extend",    # rid, n, free_after
    "block_free",      # rid, n, released, free_after
    "block_share",     # rid, n, revived, free_after  (prefix adoption:
                       #   only the `revived` blocks left the free list)
    "cow_copy",        # rid, n, free_after  (copy-on-write: n fresh blocks
                       #   claimed; the old blocks keep their other owners)
    # step dispatch (runtime.py)
    "step_begin",      # step, kind ("unified"|"decode_only"), lane_width,
                       #   segments, chunk_tokens, decode_rows
    "step_end",        # step, kind, ... as begin, plus device_s
    "compile",         # program ("unified"|"decode_only"|"commit"), device_s
})


def stream_digest(tokens) -> str:
    """Order-sensitive 64-bit FNV-1a digest of a token stream, hex-encoded.

    Stamped on every `finish` event so a trace pins the exact bytes of each
    request's output, not just its length; the audit layer recomputes it
    from the per-token events (`first_token` + `decode_token` in stream
    order) and flags any divergence.  With keyed sampling this is what
    makes a recorded sampled run *checkably* replayable."""
    h = 0xCBF29CE484222325
    for t in tokens:
        for b in int(t).to_bytes(4, "little", signed=True):
            h ^= b
            h = (h * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return f"{h:016x}"


@dataclasses.dataclass
class TraceEvent:
    """One recorded event: taxonomy name, engine-clock timestamp, the
    request it concerns (None for scheduler/pool-scoped events), and the
    event type's extra fields."""
    name: str
    t: float
    rid: Optional[int] = None
    fields: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        out = {"name": self.name, "t": self.t}
        if self.rid is not None:
            out["rid"] = self.rid
        out.update(self.fields)
        return out

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "TraceEvent":
        d = dict(d)
        name = d.pop("name")
        t = d.pop("t")
        rid = d.pop("rid", None)
        return cls(name, t, rid, d)


class TraceRecorder:
    """Append-only structured event recorder on the engine clock.

    `now_fn` defaults to None; the engine binds its own clock at
    construction (`ContinuousEngine(..., trace=rec)`), so events recorded
    under a virtual-clock replay carry virtual timestamps.  Pass `t=`
    explicitly to stamp an event at a known instant instead."""

    enabled = True

    def __init__(self, now_fn=None):
        self.now_fn = now_fn
        self.events: List[TraceEvent] = []

    def emit(self, name: str, t: Optional[float] = None,
             rid: Optional[int] = None, **fields) -> None:
        if name not in EVENT_TYPES:
            raise ValueError(f"unknown trace event type {name!r}; the "
                             f"taxonomy is closed (see trace.EVENT_TYPES)")
        if t is None:
            t = self.now_fn() if self.now_fn is not None else time.perf_counter()
        self.events.append(TraceEvent(name, t, rid, fields))

    def clear(self) -> None:
        """Drop recorded events (e.g. after an engine warm-up pass)."""
        self.events = []

    def __len__(self) -> int:
        return len(self.events)


class NullTraceRecorder:
    """The disabled recorder: every emission site's `self.trace.emit(...)`
    is a no-op call, and hot per-token loops skip even that by checking
    the `enabled` flag — one attribute lookup on the disabled path."""

    enabled = False
    events: Tuple = ()

    def emit(self, name: str, t: Optional[float] = None,
             rid: Optional[int] = None, **fields) -> None:
        pass

    def clear(self) -> None:
        pass

    def __len__(self) -> int:
        return 0


NULL_RECORDER = NullTraceRecorder()


# --------------------------------------------------------------- metrics I/O
def metrics_snapshot(metrics) -> Dict[str, Any]:
    """A JSON-serializable `ServeMetrics` snapshot for embedding next to a
    trace: the scalar summary plus the raw per-request sample lists the
    audit recomputes from events (TTFT, latency)."""
    snap = dict(metrics.summary())
    snap["family"] = getattr(metrics, "family", "decoder")
    snap["ttfts_s"] = list(metrics.ttfts_s)
    snap["latencies_s"] = list(metrics.latencies_s)
    return snap


# ------------------------------------------------------- Chrome trace export
# Perfetto/chrome://tracing process ids: one "process" per track family.
PID_REQUESTS = 1
PID_SCHEDULER = 2
PID_POOL = 3


def _us(t: float, t0: float) -> float:
    return (t - t0) * 1e6


def _request_track_events(events: List[TraceEvent], t0: float) -> List[dict]:
    """Per-request phase spans + lifecycle instants, one thread per rid."""
    out: List[dict] = []
    # minimal per-rid lifecycle for span building (the audit layer owns the
    # rigorous reconstruction; here we only need phase boundaries)
    arr: Dict[int, float] = {}
    first_admit: Dict[int, float] = {}
    first_token: Dict[int, float] = {}
    finish: Dict[int, float] = {}
    stalls: Dict[int, List[List[float]]] = {}
    for e in events:
        r = e.rid
        if e.name == "submit":
            arr[r] = e.fields.get("arrival", e.t)
        elif e.name == "admit":
            first_admit.setdefault(r, e.t)
            open_stalls = stalls.get(r, [])
            if open_stalls and len(open_stalls[-1]) == 1:
                open_stalls[-1].append(e.t)
        elif e.name == "preempt":
            stalls.setdefault(r, []).append([e.t])
        elif e.name == "first_token":
            first_token.setdefault(r, e.t)
        elif e.name == "finish":
            finish[r] = e.t

    def span(rid, name, a, b):
        if a is None or b is None or b < a:
            return
        out.append({"name": name, "ph": "X", "pid": PID_REQUESTS, "tid": rid,
                    "ts": _us(a, t0), "dur": max(0.0, (b - a) * 1e6)})

    for rid in sorted(arr):
        span(rid, "queued", arr.get(rid), first_admit.get(rid))
        span(rid, "prefill", first_admit.get(rid), first_token.get(rid))
        span(rid, "decode", first_token.get(rid), finish.get(rid))
        for iv in stalls.get(rid, []):
            if len(iv) == 2:
                span(rid, "stall", iv[0], iv[1])

    instant = {"submit", "admit", "first_token", "preempt", "swap_out",
               "swap_in", "resume", "chunk_committed", "finish", "reject"}
    for e in events:
        if e.rid is None or e.name not in instant:
            continue
        out.append({"name": e.name, "ph": "i", "s": "t",
                    "pid": PID_REQUESTS, "tid": e.rid,
                    "ts": _us(e.t, t0), "args": dict(e.fields)})
    return out


def _scheduler_track_events(events: List[TraceEvent], t0: float) -> List[dict]:
    """Step spans (unified / decode-only) + compile instants."""
    out: List[dict] = []
    begins: Dict[int, TraceEvent] = {}
    for e in events:
        if e.name == "step_begin":
            begins[e.fields["step"]] = e
        elif e.name == "step_end":
            b = begins.pop(e.fields["step"], None)
            ts = _us((b or e).t, t0)
            dur = (e.t - b.t) * 1e6 if b is not None else 0.0
            if dur <= 0.0:
                # virtual-clock replays advance the clock BETWEEN steps, so
                # begin/end coincide; fall back to measured device time
                dur = e.fields.get("device_s", 0.0) * 1e6
            out.append({"name": f"step:{e.fields.get('kind', '?')}",
                        "ph": "X", "pid": PID_SCHEDULER, "tid": 0,
                        "ts": ts, "dur": dur, "args": dict(e.fields)})
        elif e.name == "compile":
            out.append({"name": f"compile:{e.fields.get('program', '?')}",
                        "ph": "i", "s": "p", "pid": PID_SCHEDULER, "tid": 1,
                        "ts": _us(e.t, t0), "args": dict(e.fields)})
    return out


def _pool_track_events(events: List[TraceEvent], t0: float) -> List[dict]:
    """Free-block counter track from the allocator's accounting events."""
    out: List[dict] = []
    for e in events:
        if e.name in ("block_alloc", "block_extend", "block_free",
                      "block_share", "cow_copy"):
            out.append({"name": "free_blocks", "ph": "C",
                        "pid": PID_POOL, "tid": 0, "ts": _us(e.t, t0),
                        "args": {"free": e.fields.get("free_after", 0)}})
    return out


def to_chrome_trace(events: List[TraceEvent]) -> List[dict]:
    """Chrome-trace-event list (the `traceEvents` array): request tracks,
    scheduler step track, KV-pool counter track.  Timestamps are rebased to
    the earliest event so wall-clock and virtual-clock traces both open at
    t=0 in Perfetto."""
    if not events:
        return []
    t0 = min(e.t for e in events)
    for e in events:
        if e.name == "submit":
            t0 = min(t0, e.fields.get("arrival", e.t))
    out: List[dict] = []
    for pid, name in ((PID_REQUESTS, "requests"),
                      (PID_SCHEDULER, "scheduler"),
                      (PID_POOL, "kv pool")):
        out.append({"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
                    "args": {"name": name}})
    for rid in sorted({e.rid for e in events if e.rid is not None}):
        out.append({"name": "thread_name", "ph": "M", "pid": PID_REQUESTS,
                    "tid": rid, "args": {"name": f"req {rid}"}})
    out.append({"name": "thread_name", "ph": "M", "pid": PID_SCHEDULER,
                "tid": 0, "args": {"name": "steps"}})
    out.append({"name": "thread_name", "ph": "M", "pid": PID_SCHEDULER,
                "tid": 1, "args": {"name": "compiles"}})
    out.extend(_request_track_events(events, t0))
    out.extend(_scheduler_track_events(events, t0))
    out.extend(_pool_track_events(events, t0))
    return out


def write_trace(path: str, events: List[TraceEvent], metrics=None,
                metadata: Optional[Dict[str, Any]] = None) -> None:
    """Write a Chrome-trace-event JSON file that also carries the raw event
    stream, a `ServeMetrics` snapshot, and run metadata under the
    `reproServe` key (ignored by Perfetto, consumed by the audit CLI)."""
    if metrics is not None and not isinstance(metrics, dict):
        metrics = metrics_snapshot(metrics)
    payload = {
        "traceEvents": to_chrome_trace(events),
        "displayTimeUnit": "ms",
        "reproServe": {
            "events": [e.to_dict() for e in events],
            "metrics": metrics,
            "metadata": metadata or {},
        },
    }
    with open(path, "w") as f:
        json.dump(payload, f)


def load_trace(path: str):
    """Read back a `write_trace` file: (events, metrics dict or None,
    metadata dict)."""
    with open(path) as f:
        payload = json.load(f)
    raw = payload.get("reproServe", {})
    events = [TraceEvent.from_dict(d) for d in raw.get("events", [])]
    return events, raw.get("metrics"), raw.get("metadata", {})
