"""Serving metrics: latency percentiles, TTFT, throughput, cache occupancy.

Everything is recorded against the engine's own clock (wall time for real
serving, virtual time for simulated workloads) so the same metrics object
backs both the runtime and the benchmark harness.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional


def percentile(values: List[float], p: float) -> float:
    """Nearest-rank percentile (p in [0, 100]); 0.0 on empty input.

    True nearest-rank: the value at rank ceil(p/100 * n) (1-based), i.e. the
    smallest sample >= p percent of the distribution.  (The previous
    round(p/100 * (n-1)) was a rounded linear-interpolation index, which
    biased p95 toward the max on small samples.)"""
    if not values:
        return 0.0
    xs = sorted(values)
    rank = max(1, math.ceil(p / 100.0 * len(xs)))   # 1-based nearest rank
    return xs[min(len(xs), rank) - 1]


@dataclasses.dataclass
class ServeMetrics:
    # which model family served these requests ("decoder" | "ssm"): set by
    # the engine from its FamilyAdapter, embedded in trace snapshots so an
    # audit knows which step taxonomy to expect.  Kept out of `summary()`,
    # which is a flat float dict feeding CSV benches.
    family: str = "decoder"
    latencies_s: List[float] = dataclasses.field(default_factory=list)
    ttfts_s: List[float] = dataclasses.field(default_factory=list)
    tokens_out: int = 0
    requests_done: int = 0
    decode_steps: int = 0
    prefills: int = 0          # prompts whose prefill completed
    # chunked-prefill accounting under the PACKED lifecycle: one *chunk* is
    # one request's contiguous prompt slice (a segment) committed in one
    # step, and one step may carry chunks from SEVERAL requests — so a
    # single step can retire several prefills at once (`prefills` advances
    # per request, when its final segment commits; TTFT spans all of that
    # request's chunks).  prefill_chunks > prefills means at least one
    # prompt was split across steps; prefill_chunks > chunk_steps means
    # segments were packed.  Lane utilization is tracked separately:
    # `chunk_steps` counts steps that carried prompt work,
    # `chunk_lane_tokens` the lane capacity those steps paid for
    # (steps x compiled chunk width — the lane always executes at full
    # width), `packed_segments` the chunks that shared their step with at
    # least one other request's, and `decode_only_steps` the steps that
    # skipped the chunk lane entirely via the compiled decode-only fast
    # path.
    prefill_chunks: int = 0
    chunk_tokens_committed: int = 0
    chunk_steps: int = 0
    chunk_lane_tokens: int = 0
    packed_segments: int = 0
    decode_only_steps: int = 0
    # device-compute time (always wall-clock, even under a virtual engine
    # clock) — comparable with FixedBatchEngine's prefill_s/decode_s split.
    # The unified program carries both lanes in one invocation, so a mixed
    # step's time goes to decode_time_s; prefill_time_s collects the steps
    # that carried ONLY chunk work (no decode rows), and decode-only fast-
    # path steps are pure decode_time_s.
    prefill_time_s: float = 0.0
    decode_time_s: float = 0.0
    # swap-in scatter time used to hide inside prefill_time_s; preemption
    # cost is its own line now
    swap_in_time_s: float = 0.0
    # per-decode-step samples
    slot_occupancy: List[float] = dataclasses.field(default_factory=list)
    cache_occupancy: List[float] = dataclasses.field(default_factory=list)
    # None = not started/ended yet.  (A 0.0 sentinel misfires for virtual
    # clock replays that legitimately start at t=0.0.)
    start_time: Optional[float] = None
    end_time: Optional[float] = None
    # preemption / swap accounting (on-demand KV growth under pool pressure)
    preemptions: int = 0
    swap_out_bytes: int = 0
    swap_in_bytes: int = 0
    stall_s: float = 0.0       # total off-slot time of preempted requests
    # prefix sharing: prompt tokens whose KV admission adopted from the
    # prefix index (chunk-lane work never done) and copy-on-write block
    # copies made when a write landed in a shared block
    prefix_hit_tokens: int = 0
    cow_copies: int = 0
    # packed resume commits: `resume_commits` counts commit-program
    # invocations (a burst of K swap-ins costs ceil(K / resume_segments)),
    # `packed_resumes` the swap-ins that shared their invocation with at
    # least one other (the resume-path mirror of `packed_segments`)
    resume_commits: int = 0
    packed_resumes: int = 0

    # ----------------------------------------------------------- recording
    def record_step(self, active_slots: int, max_slots: int,
                    cache_occ: float) -> None:
        self.decode_steps += 1
        self.slot_occupancy.append(active_slots / max(1, max_slots))
        self.cache_occupancy.append(cache_occ)

    def record_first_token(self, ttft_s: float) -> None:
        if math.isnan(ttft_s):
            raise ValueError(
                "TTFT of a request with no first token (NaN) cannot be "
                "aggregated")
        self.ttfts_s.append(ttft_s)

    def record_completion(self, latency_s: float, n_tokens: int) -> None:
        if math.isnan(latency_s):
            raise ValueError(
                "latency of an unfinished request (NaN) cannot be aggregated")
        self.requests_done += 1
        self.tokens_out += n_tokens
        self.latencies_s.append(latency_s)

    def record_chunk_step(self, seg_tokens: List[int], lane_width: int) -> None:
        """One unified step carried a packed chunk of `len(seg_tokens)`
        prompt segments (their token counts; committed to the paged pool
        in-program) through a `lane_width`-token compiled lane."""
        self.chunk_steps += 1
        self.chunk_lane_tokens += lane_width
        self.prefill_chunks += len(seg_tokens)
        self.chunk_tokens_committed += sum(seg_tokens)
        if len(seg_tokens) > 1:
            self.packed_segments += len(seg_tokens)

    def record_decode_only_step(self) -> None:
        """One engine step ran the compiled decode-only fast path (no
        prompt work pending — the chunk lane's cost was skipped, not
        masked)."""
        self.decode_only_steps += 1

    def chunk_fill_frac(self) -> float:
        """Mean utilization of the chunk lane over the steps that ran it:
        committed prompt tokens / lane capacity paid for.  1.0 means every
        token of every chunk step's budget did useful prompt work."""
        if self.chunk_lane_tokens <= 0:
            return 0.0
        return self.chunk_tokens_committed / self.chunk_lane_tokens

    def record_preemption(self, nbytes: int) -> None:
        self.preemptions += 1
        self.swap_out_bytes += nbytes

    def record_resume(self, nbytes: int, stall_s: float,
                      swap_in_s: float = 0.0) -> None:
        self.swap_in_bytes += nbytes
        self.stall_s += stall_s
        self.swap_in_time_s += swap_in_s

    def record_resume_commit(self, n_requests: int) -> None:
        """One commit-program invocation carried `n_requests` swap-ins."""
        self.resume_commits += 1
        if n_requests > 1:
            self.packed_resumes += n_requests

    def record_prefix_hit(self, n_tokens: int) -> None:
        """Admission adopted `n_tokens` prompt tokens' KV from the prefix
        index — chunk-lane work that will never run."""
        self.prefix_hit_tokens += n_tokens

    def record_cow(self, n_copies: int) -> None:
        self.cow_copies += n_copies

    # ------------------------------------------------------------- summary
    @property
    def wall_s(self) -> float:
        """Elapsed engine-clock time; 0.0 while `start_time`/`end_time` are
        unset.  (The old 1e-9 sentinel made `tokens_per_s()` absurdly huge
        — billions of tok/s — on an engine that never ran.)"""
        if self.start_time is None or self.end_time is None:
            return 0.0
        return max(1e-9, self.end_time - self.start_time)

    def tokens_per_s(self) -> float:
        w = self.wall_s
        return self.tokens_out / w if w > 0.0 else 0.0

    def summary(self) -> Dict[str, float]:
        return {
            "requests": float(self.requests_done),
            "tokens_out": float(self.tokens_out),
            "wall_s": self.wall_s,
            "tokens_per_s": self.tokens_per_s(),
            "latency_p50_s": percentile(self.latencies_s, 50),
            "latency_p95_s": percentile(self.latencies_s, 95),
            "ttft_p50_s": percentile(self.ttfts_s, 50),
            "ttft_p95_s": percentile(self.ttfts_s, 95),
            "decode_steps": float(self.decode_steps),
            "prefills": float(self.prefills),
            "prefill_chunks": float(self.prefill_chunks),
            "chunk_tokens_committed": float(self.chunk_tokens_committed),
            "chunk_steps": float(self.chunk_steps),
            "chunk_fill_frac": self.chunk_fill_frac(),
            "packed_segments": float(self.packed_segments),
            "decode_only_steps": float(self.decode_only_steps),
            "prefill_time_s": self.prefill_time_s,
            "decode_time_s": self.decode_time_s,
            "swap_in_time_s": self.swap_in_time_s,
            "slot_occupancy_mean": (sum(self.slot_occupancy)
                                    / max(1, len(self.slot_occupancy))),
            "cache_occupancy_mean": (sum(self.cache_occupancy)
                                     / max(1, len(self.cache_occupancy))),
            "cache_occupancy_max": max(self.cache_occupancy, default=0.0),
            "preemptions": float(self.preemptions),
            "swap_out_bytes": float(self.swap_out_bytes),
            "swap_in_bytes": float(self.swap_in_bytes),
            "stall_s": self.stall_s,
            "prefix_hit_tokens": float(self.prefix_hit_tokens),
            "cow_copies": float(self.cow_copies),
            "resume_commits": float(self.resume_commits),
            "packed_resumes": float(self.packed_resumes),
        }
