from repro.serve.engine import FixedBatchEngine, Request, ServeConfig, ServeEngine
from repro.serve.kvcache import BlockAllocator, KVCacheConfig, PagedKVCache
from repro.serve.metrics import ServeMetrics, percentile
from repro.serve.router import (
    DEFAULT_CHUNK_TOKENS,
    PlanRouter,
    build_serve_graph,
    build_serve_plan,
)
from repro.serve.runtime import ContinuousEngine, RuntimeConfig
from repro.serve.scheduler import ContinuousScheduler, ServeRequest

__all__ = [
    "BlockAllocator",
    "ContinuousEngine",
    "DEFAULT_CHUNK_TOKENS",
    "ContinuousScheduler",
    "FixedBatchEngine",
    "KVCacheConfig",
    "PagedKVCache",
    "PlanRouter",
    "Request",
    "RuntimeConfig",
    "ServeConfig",
    "ServeEngine",
    "ServeMetrics",
    "ServeRequest",
    "build_serve_graph",
    "build_serve_plan",
    "percentile",
]
