from repro.serve.engine import FixedBatchEngine, Request, ServeConfig, ServeEngine
from repro.serve.kvcache import BlockAllocator, KVCacheConfig, PagedKVCache
from repro.serve.metrics import ServeMetrics, percentile
from repro.serve.router import (
    DEFAULT_CHUNK_TOKENS,
    PlanRouter,
    build_serve_graph,
    build_serve_plan,
)
from repro.serve.runtime import ContinuousEngine, RuntimeConfig
from repro.serve.scheduler import ContinuousScheduler, ServeRequest
from repro.serve.trace import (
    NULL_RECORDER,
    TraceRecorder,
    load_trace,
    write_trace,
)

__all__ = [
    "BlockAllocator",
    "ContinuousEngine",
    "DEFAULT_CHUNK_TOKENS",
    "ContinuousScheduler",
    "FixedBatchEngine",
    "KVCacheConfig",
    "NULL_RECORDER",
    "PagedKVCache",
    "PlanRouter",
    "Request",
    "RuntimeConfig",
    "ServeConfig",
    "ServeEngine",
    "ServeMetrics",
    "ServeRequest",
    "TraceRecorder",
    "build_serve_graph",
    "build_serve_plan",
    "load_trace",
    "percentile",
    "write_trace",
]
