from repro.serve.engine import FixedBatchEngine, Request, ServeConfig, ServeEngine
from repro.serve.family import (
    DecoderFamilyAdapter,
    SSMFamilyAdapter,
    resolve_family_adapter,
)
from repro.serve.kvcache import BlockAllocator, KVCacheConfig, PagedKVCache
from repro.serve.metrics import ServeMetrics, percentile
from repro.serve.router import (
    DEFAULT_CHUNK_TOKENS,
    FAMILY_STAGES,
    PlanRouter,
    build_serve_graph,
    build_serve_plan,
    serve_stages,
)
from repro.serve.runtime import ContinuousEngine, RuntimeConfig
from repro.serve.sampling import GREEDY, SamplingParams, truncate_at_eos
from repro.serve.scheduler import ContinuousScheduler, PagedCapacity, ServeRequest
from repro.serve.statecache import (
    SlotAllocator,
    SlotCapacity,
    SlotStateCache,
    StateCacheConfig,
)
from repro.serve.trace import (
    NULL_RECORDER,
    TraceRecorder,
    load_trace,
    write_trace,
)

__all__ = [
    "BlockAllocator",
    "ContinuousEngine",
    "DEFAULT_CHUNK_TOKENS",
    "ContinuousScheduler",
    "DecoderFamilyAdapter",
    "FAMILY_STAGES",
    "FixedBatchEngine",
    "GREEDY",
    "KVCacheConfig",
    "NULL_RECORDER",
    "PagedCapacity",
    "PagedKVCache",
    "PlanRouter",
    "Request",
    "RuntimeConfig",
    "SSMFamilyAdapter",
    "SamplingParams",
    "ServeConfig",
    "ServeEngine",
    "ServeMetrics",
    "ServeRequest",
    "SlotAllocator",
    "SlotCapacity",
    "SlotStateCache",
    "StateCacheConfig",
    "TraceRecorder",
    "build_serve_graph",
    "build_serve_plan",
    "load_trace",
    "percentile",
    "resolve_family_adapter",
    "serve_stages",
    "truncate_at_eos",
    "write_trace",
]
