"""Per-request sampling policy: `SamplingParams` and the host-side packing.

The device routine lives in `repro.kernels.sampling` (fused into the four
serving step programs as traced data — see that module for the array
conventions and the determinism contract).  This module is everything the
HOST does with it:

  * `SamplingParams` — the submit-time knobs a request carries through its
    whole life (scheduler queue, slot residency, preemption/resume, the
    fixed-batch drain).  temperature=0 (the default) is greedy and reduces
    bitwise to the pre-sampling argmax path.
  * array builders — pack per-slot / per-segment / per-batch (rows, 3)
    float32 sampling and (rows, 3) int32 [seed, rid, token_index] key
    arrays.  Rows without an active sampled request are greedy
    (temperature 0), so idle/prefilling slots keep producing the same
    discarded argmax garbage they always did.
  * `sample_host` — the SAME routine under a standalone jit, used by
    `FixedBatchEngine` so the differential baseline draws bitwise
    identical tokens to the fused step programs.
  * `truncate_at_eos` — the one stop-at-first-eos definition BOTH engines
    share (`FixedBatchEngine.run` truncation and `ContinuousEngine`
    retirement), so eos semantics cannot diverge between the continuous
    runtime and its baseline.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.sampling import sample_tokens

_INT32_MAX = 2**31 - 1


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Submit-time sampling knobs for one request.

    temperature: 0 (default) = greedy argmax, bitwise the pre-sampling
        path; > 0 scales the logits before the draw.
    top_k: keep only the k largest logits (0 = off).
    top_p: keep the minimal nucleus of tokens covering probability mass
        top_p (1.0 = off).
    seed: the request's stream seed.  Token i of the request is drawn
        under the key (seed, rid, i) — replay with the same triple is
        bitwise identical regardless of batching, chunking or preemption.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0

    def invalid_reason(self) -> Optional[str]:
        """Reject reason, or None when the params are servable (mirrors
        the scheduler's other submit guards)."""
        if not math.isfinite(self.temperature) or self.temperature < 0:
            return f"temperature must be finite and >= 0, got {self.temperature}"
        if self.top_k < 0:
            return f"top_k must be >= 0, got {self.top_k}"
        if not (0.0 < self.top_p <= 1.0):
            return f"top_p must be in (0, 1], got {self.top_p}"
        if not (0 <= self.seed <= _INT32_MAX):
            return f"seed must fit int32 (0 <= seed < 2**31), got {self.seed}"
        return None


GREEDY = SamplingParams()


# ------------------------------------------------------------ array packing
def _greedy_arrays(rows: int) -> Tuple[np.ndarray, np.ndarray]:
    sp = np.zeros((rows, 3), np.float32)
    sp[:, 2] = 1.0                        # top_p off
    ks = np.zeros((rows, 3), np.int32)
    return sp, ks


def _fill_row(sp: np.ndarray, ks: np.ndarray, i: int, s: SamplingParams,
              rid: int, token_index: int) -> None:
    sp[i, 0] = s.temperature
    sp[i, 1] = float(s.top_k)
    sp[i, 2] = s.top_p
    ks[i, 0] = s.seed
    ks[i, 1] = rid
    ks[i, 2] = token_index


def slot_sampling_arrays(slots) -> Tuple[np.ndarray, np.ndarray]:
    """Decode-lane arrays for the continuous engine: one row per slot.
    Empty and still-prefilling slots stay greedy with a zero key — their
    decode row is masked to the sink and its token discarded, exactly as
    before.  The token index is the request's CURRENT output length (the
    index the next decode token will land at), so the key stream is a pure
    function of request progress and survives preemption/resume for
    free."""
    sp, ks = _greedy_arrays(len(slots))
    for i, req in enumerate(slots):
        if req is None or req.prefilling:
            continue
        _fill_row(sp, ks, i, req.sampling, req.rid, len(req.output))
    return sp, ks


def segment_sampling_arrays(chunks: Sequence[tuple],
                            n_segments: int) -> Tuple[np.ndarray, np.ndarray]:
    """Chunk-lane arrays: one row per packed segment slot.  A segment's
    sample is only consumed when that chunk completes its prompt, i.e. it
    draws the request's FIRST token — token index 0.  Idle segment slots
    are greedy."""
    sp, ks = _greedy_arrays(n_segments)
    for i, (req, _start, _n) in enumerate(chunks):
        _fill_row(sp, ks, i, req.sampling, req.rid, 0)
    return sp, ks


def batch_sampling_arrays(reqs, width: int) -> Tuple[np.ndarray, np.ndarray]:
    """Fixed-batch arrays at token index 0 (the prefill sample); the drain
    loop advances column 2 in place per decode iteration.  Padding rows
    past len(reqs) are greedy."""
    sp, ks = _greedy_arrays(width)
    for i, r in enumerate(reqs):
        _fill_row(sp, ks, i, r.sampling, r.rid, 0)
    return sp, ks


# ------------------------------------------------------------- host sampler
@functools.lru_cache(maxsize=1)
def _jitted_sampler():
    # built lazily so importing this module never touches the backend
    return jax.jit(sample_tokens)


def sample_host(logits, sampling: np.ndarray, keys: np.ndarray):
    """The keyed sampler as a standalone jitted call for the fixed-batch
    baseline: same routine, same float program per row, so its tokens are
    bitwise identical to the fused step programs' on identical logits."""
    return _jitted_sampler()(logits, jnp.asarray(sampling), jnp.asarray(keys))


# ------------------------------------------------------------ eos semantics
def truncate_at_eos(seq: Sequence[int], eos_id: int) -> List[int]:
    """Stop-at-first-eos: the single definition of eos truncation both
    engines share.  Tokens past the first eos (and the eos itself stays)
    are dropped; eos_id < 0 disables early stopping."""
    seq = list(seq)
    if eos_id < 0 or eos_id not in seq:
        return seq
    return seq[: seq.index(eos_id) + 1]
