"""Trace audit: reconstruct per-request lifecycles from a serve event trace
and cross-validate them against `ServeMetrics` aggregates.

The differential tests pin that scheduling choices are invisible to the
TOKENS (byte-identical greedy streams); this module pins that they are
faithfully VISIBLE to the trace: every number `ServeMetrics` reports must be
recomputable from the event stream alone.  Checks:

  * **terminal** — every `admit`ed request reaches exactly one `finish`
    event, preceded by exactly one `first_token`;
  * **timing** — per-request TTFT / completion latency recomputed purely
    from events (`first_token.t - arrival`, `finish.t - arrival`) match the
    recorded `ServeMetrics` sample lists, and total stall time recomputed
    from `preempt` -> resume-`admit` intervals matches `stall_s`;
  * **tokens** — `first_token` + `decode_token` event counts reproduce
    `tokens_out`, per-request token events match each `finish` event's
    `n_output`, and committed `chunk_committed` tokens reproduce
    `chunk_tokens_committed` (each request's chunks covering exactly
    [0, prompt_len) in order);
  * **sampling** — each `finish` event's `digest` (FNV-1a over the token
    stream, stamped at retirement) matches the digest recomputed from the
    `first_token`/`decode_token` events' token values, pinning that the
    trace records the EXACT stream a replay must reproduce; and every
    sampled submit (temperature > 0) carries its `seed`, without which a
    recorded run is not replayable;
  * **pool** — replaying `block_alloc` / `block_extend` / `block_free` /
    `block_share` / `cow_copy` against a free-block counter reproduces
    every event's recorded `free_after`, no request's holding goes
    negative, and a completed run returns the pool to its initial free
    level.  The replay is REFCOUNT-aware: a share only removes its
    `revived` blocks from the free level (live matches just gain an
    owner), a free only returns its `released` blocks (co-owned blocks
    stay out), and a CoW claims one fresh block without releasing the old
    (its other owners keep it) — so a forged share (claiming more free
    blocks than it revived, or reviving blocks that were never free)
    breaks the `free_after` chain and fails the audit;
  * **dispatch** — `step_end` events with kind `decode_only` carried zero
    segments and zero chunk tokens, and their count matches
    `decode_only_steps` (same for `chunk_steps` / unified);
  * **family** — lifecycle and step events carry ONE consistent serving
    family tag ("decoder" | "ssm"; events from traces recorded before the
    family seam carry none and default to "decoder"), matching the
    snapshot's recorded family — the same audit holds for both engine
    families;
  * **export** — the Chrome-trace-event export is valid (JSON-serializable,
    required keys per event).

`attribution_rows` / `format_attribution` turn the lifecycles into the
per-request time-attribution table (queued / prefill / stalled / decode
fractions) `bench_serving.py --trace` prints.

CLI (used by CI on the bench smoke's captured trace):

    PYTHONPATH=src python -m repro.serve.traceview out.json
"""

from __future__ import annotations

import dataclasses
import json
import math
import sys
from typing import Any, Dict, List, Optional, Tuple

from repro.serve.trace import (TraceEvent, metrics_snapshot, stream_digest,
                               to_chrome_trace)

_TOL = 1e-6


@dataclasses.dataclass
class Lifecycle:
    """One request's reconstructed lifecycle, built purely from events."""
    rid: int
    arrival: Optional[float] = None
    submit_t: Optional[float] = None
    prompt_len: Optional[int] = None
    admits: List[Tuple[float, str]] = dataclasses.field(default_factory=list)
    preempts: List[float] = dataclasses.field(default_factory=list)
    stalls: List[Tuple[float, float]] = dataclasses.field(default_factory=list)
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None
    n_output: Optional[int] = None
    decode_tokens: int = 0
    first_tokens: int = 0
    chunks: List[Tuple[float, int, int]] = dataclasses.field(
        default_factory=list)   # (t, start, n) per chunk_committed
    # sampled-replay state: the token values in emission order, the finish
    # event's stream digest, and whether the submit carried sampling knobs
    tokens: List[int] = dataclasses.field(default_factory=list)
    digest: Optional[str] = None
    sampled: bool = False
    has_seed: bool = False
    shared_blocks: int = 0   # prefix blocks adopted via block_share events

    # ------------------------------------------------- event-derived timing
    @property
    def ttft_s(self) -> float:
        if self.first_token_t is None or self.arrival is None:
            return math.nan
        return self.first_token_t - self.arrival

    @property
    def latency_s(self) -> float:
        if self.finish_t is None or self.arrival is None:
            return math.nan
        return self.finish_t - self.arrival

    @property
    def stall_s(self) -> float:
        return sum(b - a for a, b in self.stalls)

    @property
    def queued_s(self) -> float:
        if not self.admits or self.arrival is None:
            return math.nan
        return self.admits[0][0] - self.arrival

    def _stall_split(self) -> Tuple[float, float]:
        """(stall during prefill, stall during decode): a preemption that
        began before the first token stalled the prompt, later ones stall
        decoding."""
        pre = dec = 0.0
        for a, b in self.stalls:
            if self.first_token_t is not None and a >= self.first_token_t:
                dec += b - a
            else:
                pre += b - a
        return pre, dec

    @property
    def prefill_s(self) -> float:
        if self.first_token_t is None or not self.admits:
            return math.nan
        return self.first_token_t - self.admits[0][0] - self._stall_split()[0]

    @property
    def decode_s(self) -> float:
        if self.finish_t is None or self.first_token_t is None:
            return math.nan
        return self.finish_t - self.first_token_t - self._stall_split()[1]


def build_lifecycles(events: List[TraceEvent]) -> Dict[int, Lifecycle]:
    """Fold the event stream into per-request lifecycles (pure function of
    the trace; `ServeMetrics` is never consulted)."""
    lcs: Dict[int, Lifecycle] = {}

    def lc(rid: int) -> Lifecycle:
        if rid not in lcs:
            lcs[rid] = Lifecycle(rid)
        return lcs[rid]

    for e in events:
        r = e.rid
        if e.name == "submit":
            x = lc(r)
            x.submit_t = e.t
            x.arrival = e.fields.get("arrival", e.t)
            x.prompt_len = e.fields.get("prompt_len")
            x.sampled = e.fields.get("temperature", 0.0) > 0.0
            x.has_seed = "seed" in e.fields
        elif e.name == "admit":
            x = lc(r)
            x.admits.append((e.t, e.fields.get("kind", "fresh")))
            if x.preempts and len(x.stalls) < len(x.preempts):
                x.stalls.append((x.preempts[len(x.stalls)], e.t))
        elif e.name == "preempt":
            lc(r).preempts.append(e.t)
        elif e.name == "first_token":
            x = lc(r)
            x.first_tokens += 1
            if x.first_token_t is None:
                x.first_token_t = e.t
            if "token" in e.fields:
                x.tokens.append(int(e.fields["token"]))
        elif e.name == "decode_token":
            x = lc(r)
            x.decode_tokens += 1
            if "token" in e.fields:
                x.tokens.append(int(e.fields["token"]))
        elif e.name == "chunk_committed":
            lc(r).chunks.append((e.t, e.fields.get("start", 0),
                                 e.fields.get("n", 0)))
        elif e.name == "block_share":
            lc(r).shared_blocks += e.fields.get("n", 0)
        elif e.name == "finish":
            x = lc(r)
            x.finish_t = e.t
            x.n_output = e.fields.get("n_output")
            x.digest = e.fields.get("digest")
    return lcs


@dataclasses.dataclass
class AuditReport:
    violations: List[str]
    lifecycles: Dict[int, Lifecycle]
    checks: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not self.violations

    def summary(self) -> str:
        head = (f"trace audit: {'PASS' if self.ok else 'FAIL'} — "
                f"{len(self.lifecycles)} requests, "
                + ", ".join(f"{k}={v}" for k, v in sorted(self.checks.items())))
        if self.violations:
            head += "\n" + "\n".join(f"  VIOLATION: {v}"
                                     for v in self.violations)
        return head


def _close(a: float, b: float, tol: float = _TOL) -> bool:
    return abs(a - b) <= tol * max(1.0, abs(a), abs(b))


def _match_samples(name: str, got: List[float], want: List[float],
                   violations: List[str]) -> None:
    got, want = sorted(got), sorted(want)
    if len(got) != len(want):
        violations.append(f"{name}: {len(got)} event-derived samples vs "
                          f"{len(want)} recorded")
        return
    for g, w in zip(got, want):
        if not _close(g, w):
            violations.append(f"{name}: event-derived {g!r} != recorded {w!r}")
            return


def _audit_lifecycles(lcs: Dict[int, Lifecycle], violations: List[str],
                      block_size: Optional[int] = None) -> None:
    for rid, x in sorted(lcs.items()):
        if x.submit_t is None:
            violations.append(f"req {rid}: events without a submit")
        if not x.admits:
            if x.finish_t is not None:
                violations.append(f"req {rid}: finished without an admit")
            continue
        if x.finish_t is None:
            violations.append(f"req {rid}: admitted but never reached a "
                              "terminal finish event")
            continue
        if x.first_tokens != 1:
            violations.append(f"req {rid}: {x.first_tokens} first_token "
                              "events (want exactly 1)")
        if x.n_output is not None \
                and x.first_tokens + x.decode_tokens != x.n_output:
            violations.append(
                f"req {rid}: {x.first_tokens}+{x.decode_tokens} token events "
                f"!= finish n_output {x.n_output}")
        # replay pin: the finish digest must match the digest of the token
        # VALUES the first_token/decode_token events recorded (only
        # checkable when every token event carried its value)
        if x.digest is not None \
                and len(x.tokens) == x.first_tokens + x.decode_tokens:
            got = stream_digest(x.tokens)
            if got != x.digest:
                violations.append(
                    f"req {rid}: token-event digest {got} != finish "
                    f"digest {x.digest} — trace does not pin the stream")
        if x.sampled and not x.has_seed:
            violations.append(
                f"req {rid}: sampled submit (temperature > 0) without a "
                "seed — run is not replayable from the trace")
        if len(x.stalls) != len(x.preempts):
            violations.append(f"req {rid}: {len(x.preempts)} preempts but "
                              f"{len(x.stalls)} resume intervals")
        resumes = sum(1 for _, kind in x.admits if kind == "resume")
        if resumes != len(x.preempts):
            violations.append(f"req {rid}: {len(x.preempts)} preempts but "
                              f"{resumes} resume admits")
        # chunk coverage: committed segments tile [adopted, prompt_len) in
        # order, where `adopted` is 0 unless the request shared prefix
        # blocks at admission (then its first chunk begins at the adoption
        # point — min(shared_blocks * block_size, prompt_len - 1) when the
        # trace metadata pins the block size, else wherever the first chunk
        # says, as long as a share justifies the skip)
        if x.chunks and x.prompt_len is not None:
            pos = 0
            if x.shared_blocks:
                if block_size is not None:
                    pos = min(x.shared_blocks * block_size,
                              x.prompt_len - 1)
                else:
                    first = x.chunks[0][1]
                    if 0 < first < x.prompt_len:
                        pos = first
            for _, start, n in x.chunks:
                if start != pos:
                    violations.append(f"req {rid}: chunk committed at "
                                      f"{start}, expected {pos}")
                    break
                pos += n
            else:
                if pos != x.prompt_len:
                    violations.append(
                        f"req {rid}: chunks committed {pos} of "
                        f"{x.prompt_len} prompt tokens")


def _pool_free_delta(e: TraceEvent) -> int:
    """How the event moved the free-block level, refcount-aware: frees
    return only the blocks whose last owner let go (`released`; absent on
    pre-sharing traces, where every freed block released), shares remove
    only their `revived` blocks (live matches just gain an owner), CoW
    claims `n` fresh blocks and releases none (the old blocks keep their
    other owners)."""
    n = e.fields["n"]
    if e.name == "block_free":
        return e.fields.get("released", n)
    if e.name == "block_share":
        return -e.fields.get("revived", 0)
    return -n   # block_alloc / block_extend / cow_copy


def _audit_pool(events: List[TraceEvent], metadata: Dict[str, Any],
                violations: List[str], checks: Dict[str, Any]) -> None:
    block_events = [e for e in events if e.name in
                    ("block_alloc", "block_extend", "block_free",
                     "block_share", "cow_copy")]
    if not block_events:
        return
    free = metadata.get("usable_blocks")
    if free is None:
        # infer the initial level from the first event's recorded state
        e0 = block_events[0]
        free = e0.fields["free_after"] - _pool_free_delta(e0)
    initial = free
    held: Dict[int, int] = {}
    for e in block_events:
        n = e.fields["n"]
        if n < 0:
            violations.append(f"{e.name} rid {e.rid}: negative count {n}")
            continue
        if e.name == "block_free":
            released = e.fields.get("released", n)
            if released > n:
                violations.append(f"block_free rid {e.rid}: released "
                                  f"{released} > freed {n}")
            held[e.rid] = held.get(e.rid, 0) - n
            if held[e.rid] < 0:
                violations.append(f"req {e.rid}: freed {n} blocks beyond "
                                  "its holding")
        elif e.name == "block_share":
            revived = e.fields.get("revived", 0)
            if revived > n:
                violations.append(f"block_share rid {e.rid}: revived "
                                  f"{revived} > adopted {n}")
            held[e.rid] = held.get(e.rid, 0) + n
        elif e.name == "cow_copy":
            # one fresh block swaps in for each shared one: the holding
            # count is unchanged and nothing returns to the free list
            pass
        else:
            held[e.rid] = held.get(e.rid, 0) + n
        free += _pool_free_delta(e)
        if free < 0:
            violations.append(f"{e.name} rid {e.rid}: free count went "
                              f"negative ({free})")
        if free != e.fields["free_after"]:
            violations.append(
                f"{e.name} rid {e.rid}: modeled free {free} != recorded "
                f"free_after {e.fields['free_after']}")
            free = e.fields["free_after"]   # resync to localize reports
    leaked = {r: h for r, h in held.items() if h != 0}
    if leaked:
        violations.append(f"pool accounting leaked blocks at end of trace: "
                          f"{leaked}")
    if free != initial:
        violations.append(f"pool free count ended at {free}, started at "
                          f"{initial}")
    checks["block_events"] = len(block_events)


def _audit_steps(events: List[TraceEvent], violations: List[str],
                 checks: Dict[str, Any]) -> Dict[str, int]:
    begins: Dict[int, TraceEvent] = {}
    kinds = {"unified": 0, "decode_only": 0}
    for e in events:
        if e.name == "step_begin":
            if e.fields["step"] in begins:
                violations.append(f"step {e.fields['step']}: duplicate "
                                  "step_begin")
            begins[e.fields["step"]] = e
        elif e.name == "step_end":
            b = begins.pop(e.fields["step"], None)
            if b is None:
                violations.append(f"step {e.fields['step']}: step_end "
                                  "without step_begin")
            elif b.fields.get("kind") != e.fields.get("kind"):
                violations.append(f"step {e.fields['step']}: kind changed "
                                  "between begin and end")
            kind = e.fields.get("kind")
            if kind in kinds:
                kinds[kind] += 1
            if kind == "decode_only" and (
                    e.fields.get("segments", 0) != 0
                    or e.fields.get("chunk_tokens", 0) != 0):
                violations.append(
                    f"step {e.fields['step']}: decode_only step carried "
                    f"{e.fields.get('segments')} segments / "
                    f"{e.fields.get('chunk_tokens')} chunk tokens")
    if begins:
        violations.append(f"{len(begins)} step_begin events never ended: "
                          f"{sorted(begins)[:5]}")
    checks.update(unified_steps=kinds["unified"],
                  decode_only_steps=kinds["decode_only"])
    return kinds


def audit(events: List[TraceEvent], metrics=None,
          metadata: Optional[Dict[str, Any]] = None) -> AuditReport:
    """Audit a trace's internal invariants and (when `metrics` is given —
    a `ServeMetrics` or its `metrics_snapshot` dict) cross-validate the
    event-derived request timings and counters against the recorded
    aggregates.  Assumes a COMPLETED run: every admitted request must have
    terminated."""
    if metrics is not None and not isinstance(metrics, dict):
        metrics = metrics_snapshot(metrics)
    metadata = metadata or {}
    violations: List[str] = []
    checks: Dict[str, Any] = {}

    lcs = build_lifecycles(events)
    _audit_lifecycles(lcs, violations, metadata.get("block_size"))
    _audit_pool(events, metadata, violations, checks)
    kinds = _audit_steps(events, violations, checks)
    checks["requests"] = len(lcs)
    checks["sampled_requests"] = sum(1 for x in lcs.values() if x.sampled)

    # family consistency: one engine serves one family; absent tags are
    # pre-seam traces, i.e. the decoder family
    fams = {e.fields.get("family", "decoder") for e in events
            if e.name in ("submit", "admit", "preempt", "finish",
                          "step_begin", "step_end")}
    if len(fams) > 1:
        violations.append(
            f"mixed serving families in one trace: {sorted(fams)}")
    checks["family"] = sorted(fams)[0] if fams else "decoder"
    if metrics is not None and isinstance(metrics, dict):
        mfam = metrics.get("family", "decoder")
        if fams and mfam not in fams:
            violations.append(f"metrics family {mfam!r} not among event "
                              f"families {sorted(fams)}")

    # mesh consistency: one engine runs one mesh for its whole life; absent
    # tags are pre-mesh traces, i.e. a single device ("<data>x<model>")
    meshes = {e.fields.get("mesh", "1x1") for e in events
              if e.name in ("step_begin", "step_end")}
    if len(meshes) > 1:
        violations.append(f"mixed meshes in one trace: {sorted(meshes)}")
    checks["mesh"] = sorted(meshes)[0] if meshes else "1x1"
    md_mesh = metadata.get("mesh")
    if md_mesh is not None and meshes and md_mesh not in meshes:
        violations.append(f"metadata mesh {md_mesh!r} not among step-event "
                          f"meshes {sorted(meshes)}")

    finished = [x for x in lcs.values() if x.finish_t is not None]
    if metrics is not None:
        _match_samples("ttft", [x.ttft_s for x in finished
                                if x.first_token_t is not None],
                       metrics.get("ttfts_s", []), violations)
        _match_samples("latency", [x.latency_s for x in finished],
                       metrics.get("latencies_s", []), violations)
        stall = sum(x.stall_s for x in lcs.values())
        if not _close(stall, metrics.get("stall_s", 0.0)):
            violations.append(f"stall: event-derived {stall!r} != recorded "
                              f"{metrics.get('stall_s')!r}")
        tokens = sum(x.first_tokens + x.decode_tokens for x in lcs.values())
        if tokens != int(metrics.get("tokens_out", 0)):
            violations.append(f"tokens_out: {tokens} token events vs "
                              f"recorded {metrics.get('tokens_out')}")
        if len(finished) != int(metrics.get("requests", len(finished))):
            violations.append(f"requests: {len(finished)} finish events vs "
                              f"recorded {metrics.get('requests')}")
        preempts = sum(len(x.preempts) for x in lcs.values())
        if preempts != int(metrics.get("preemptions", 0)):
            violations.append(f"preemptions: {preempts} preempt events vs "
                              f"recorded {metrics.get('preemptions')}")
        if "cow_copies" in metrics:   # absent on pre-sharing snapshots
            cows = sum(e.fields.get("n", 1) for e in events
                       if e.name == "cow_copy")
            if cows != int(metrics["cow_copies"]):
                violations.append(f"cow_copies: {cows} cow_copy events vs "
                                  f"recorded {metrics.get('cow_copies')}")
        committed = sum(n for x in lcs.values() for _, _, n in x.chunks)
        if committed != int(metrics.get("chunk_tokens_committed", 0)):
            violations.append(
                f"chunk_tokens_committed: {committed} from events vs "
                f"recorded {metrics.get('chunk_tokens_committed')}")
        firsts = sum(x.first_tokens for x in lcs.values())
        if firsts != int(metrics.get("prefills", 0)):
            violations.append(f"prefills: {firsts} first_token events vs "
                              f"recorded {metrics.get('prefills')}")
        for key, kind in (("decode_only_steps", "decode_only"),
                          ("chunk_steps", "unified")):
            if kinds[kind] != int(metrics.get(key, 0)):
                violations.append(f"{key}: {kinds[kind]} {kind} step_end "
                                  f"events vs recorded {metrics.get(key)}")

    # Chrome-trace-event export validity
    try:
        chrome = to_chrome_trace(events)
        json.dumps(chrome)
        for ev in chrome:
            if "ph" not in ev or "pid" not in ev or "name" not in ev:
                violations.append(f"chrome event missing required keys: {ev}")
                break
            if ev["ph"] != "M" and "ts" not in ev:
                violations.append(f"chrome event missing ts: {ev}")
                break
        checks["chrome_events"] = len(chrome)
    except (TypeError, ValueError, KeyError) as exc:
        violations.append(f"chrome trace export failed: {exc!r}")

    return AuditReport(violations, lcs, checks)


# --------------------------------------------------------------- attribution
def attribution_rows(lcs: Dict[int, Lifecycle]) -> List[Dict[str, float]]:
    """Per-request time attribution: where each finished request's latency
    went (queued / prefill / stalled / decode seconds and fractions)."""
    rows = []
    for rid in sorted(lcs):
        x = lcs[rid]
        if x.finish_t is None or x.arrival is None or not x.admits:
            continue
        parts = {"queued_s": x.queued_s, "prefill_s": x.prefill_s,
                 "stall_s": x.stall_s, "decode_s": x.decode_s}
        total = x.latency_s
        row = {"rid": rid, "total_s": total, **parts}
        for k, v in parts.items():
            row[k.replace("_s", "_frac")] = \
                (v / total) if total > 0 else 0.0
        rows.append(row)
    return rows


def format_attribution(lcs: Dict[int, Lifecycle]) -> str:
    """The per-request time-attribution table `bench_serving.py --trace`
    prints: one line per request, latency split into phases."""
    rows = attribution_rows(lcs)
    if not rows:
        return "(no finished requests in trace)"
    lines = [f"{'rid':>5} {'total_s':>8} {'queued':>7} {'prefill':>8} "
             f"{'stall':>7} {'decode':>7}"]
    for r in rows:
        lines.append(
            f"{r['rid']:>5} {r['total_s']:>8.3f} {r['queued_frac']:>6.0%} "
            f"{r['prefill_frac']:>7.0%} {r['stall_frac']:>6.0%} "
            f"{r['decode_frac']:>6.0%}")
    return "\n".join(lines)


# ----------------------------------------------------------------------- CLI
def main(argv: Optional[List[str]] = None) -> int:
    """Audit a trace file captured by `bench_serving.py --trace` (CI runs
    this on the smoke trace; any invariant violation is a non-zero exit)."""
    import argparse

    from repro.serve.trace import load_trace

    ap = argparse.ArgumentParser(
        description="audit a serve trace (Chrome JSON with embedded events)")
    ap.add_argument("trace", help="path written by bench_serving.py --trace")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress the per-request attribution table")
    args = ap.parse_args(argv)
    events, metrics, metadata = load_trace(args.trace)
    if not events:
        print(f"{args.trace}: no embedded serve events (was it written by "
              "bench_serving.py --trace?)")
        return 1
    report = audit(events, metrics=metrics, metadata=metadata)
    if not args.quiet:
        print(format_attribution(report.lifecycles))
    print(report.summary())
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
