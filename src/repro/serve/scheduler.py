"""Continuous-batching scheduler: slot-based admission into in-flight batches.

The decode program is compiled once for a fixed slot count; a *slot* is one
row of that batch.  Each engine step the scheduler:

  1. retires finished requests (slots + KV blocks return to the pool),
  2. admits waiting requests into free slots — FIFO, gated on the paged
     KV-cache having enough free blocks for the request's *worst case*
     KV footprint (see `kv_rows`), so an admitted request can never die
     of cache exhaustion mid-decode and no preemption machinery is needed,
  3. hands the engine the set of newly admitted requests to prefill.

Requests that arrive while all slots are busy (or the pool is dry) simply
wait — overload degrades to queueing delay, never to an error.  Per-slot
position tracking is length-based (no left-padding anywhere): slot i's next
token lands at position `lengths[i]`, independent of every other slot.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional

import numpy as np

from repro.serve.kvcache import BlockAllocator, KVCacheConfig


@dataclasses.dataclass
class ServeRequest:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int
    arrival_time: float = 0.0
    # lifecycle timestamps (engine clock)
    admitted_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    # generation state
    output: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))

    @property
    def done(self) -> bool:
        return self.finish_time is not None

    @property
    def latency_s(self) -> float:
        return (self.finish_time or 0.0) - self.arrival_time

    @property
    def ttft_s(self) -> float:
        return (self.first_token_time or 0.0) - self.arrival_time


class ContinuousScheduler:
    """Admission control over `max_slots` decode slots + the block pool."""

    def __init__(self, max_slots: int, kv_cfg: KVCacheConfig,
                 alloc: BlockAllocator):
        self.max_slots = max_slots
        self.kv_cfg = kv_cfg
        self.alloc = alloc
        self.waiting: Deque[ServeRequest] = deque()
        self.slots: List[Optional[ServeRequest]] = [None] * max_slots

    # ------------------------------------------------------------- queries
    @property
    def num_active(self) -> int:
        return sum(r is not None for r in self.slots)

    @property
    def num_waiting(self) -> int:
        return len(self.waiting)

    @property
    def has_work(self) -> bool:
        return self.num_active > 0 or self.num_waiting > 0

    def slot_rids(self) -> List[Optional[int]]:
        return [r.rid if r is not None else None for r in self.slots]

    # ----------------------------------------------------------- lifecycle
    @staticmethod
    def kv_rows(req: ServeRequest) -> int:
        """KV rows a request can ever occupy: the prompt plus every
        generated token except the last (which is emitted but never fed
        back through a decode step, so its K/V row is never written)."""
        return req.prompt_len + req.max_new_tokens - 1

    def submit(self, req: ServeRequest) -> None:
        if req.max_new_tokens < 1:
            raise ValueError(
                f"request {req.rid}: max_new_tokens must be >= 1")
        if self.kv_rows(req) > self.kv_cfg.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt {req.prompt_len} + "
                f"max_new {req.max_new_tokens} exceeds max_seq "
                f"{self.kv_cfg.max_seq}")
        need = self.kv_cfg.blocks_for(self.kv_rows(req))
        usable = self.kv_cfg.num_blocks - 1
        if need > usable:
            # would never be admittable even with an empty pool — reject now
            # instead of letting the engine wait on it forever
            raise ValueError(
                f"request {req.rid}: needs {need} KV blocks but the pool "
                f"only has {usable}")
        self.waiting.append(req)

    def admit(self, now: float) -> List[ServeRequest]:
        """Move waiting requests into free slots; returns the newly admitted
        (to be prefilled by the engine).  FIFO with head-of-line blocking:
        a request too large for the current free pool also holds back the
        requests behind it, preserving arrival order fairness."""
        admitted: List[ServeRequest] = []
        for slot in range(self.max_slots):
            if self.slots[slot] is not None or not self.waiting:
                continue
            req = self.waiting[0]
            if req.arrival_time > now:
                break  # not yet arrived (simulated-arrival workloads)
            need = self.kv_cfg.blocks_for(self.kv_rows(req))
            if not self.alloc.can_allocate(need):
                break
            self.waiting.popleft()
            self.alloc.allocate(req.rid, need)
            req.slot = slot
            req.admitted_time = now
            self.slots[slot] = req
            admitted.append(req)
        return admitted

    def retire(self, req: ServeRequest, now: float) -> None:
        """Release the request's slot and KV blocks."""
        req.finish_time = now
        self.alloc.free(req.rid)
        assert req.slot is not None and self.slots[req.slot] is req
        self.slots[req.slot] = None
        req.slot = None
