"""Continuous-batching scheduler: slot-based admission into in-flight batches.

The decode program is compiled once for a fixed slot count; a *slot* is one
row of that batch.  Each engine step the scheduler:

  1. retires finished requests (slots + KV blocks return to the pool),
  2. admits waiting requests into free slots — resumes first (a preempted
     request re-enters before any new arrival), then FIFO arrivals, gated
     on the paged KV-cache having enough free blocks for the request's
     *prompt* (not prompt+budget): KV grows on demand during decode
     (`BlockAllocator.extend`, one block at a time), so admission reserves
     only what prefill will actually write,
  3. picks the step's prefill *chunk* (`next_chunks`): alongside the slot
     accounting sits chunk accounting — each admitted request remembers how
     much of its prompt is committed (`ServeRequest.prefilled`) and the
     engine's `chunk_tokens` budget is greedily PACKED, oldest admission
     first, with prompt segments from up to `max_segments` requests per
     step (short prompts no longer leave the tail of the budget idle).
     Admission itself is therefore free
     (no prefill program runs at admission; the prompt is streamed through
     the unified step), and a request only joins the decode batch once its
     prompt is fully committed.

When the pool runs dry mid-decode — a growing request cannot extend — the
scheduler picks a preemption *victim*: the most recently admitted active
request (LIFO), preferring the one with the most remaining budget among
same-step admissions.  The victim's KV blocks are swapped out to a host
buffer by the engine and the request joins the resume queue; the submit-time
guard (a single request's worst case must fit the pool alone) makes this
loop always terminate — preempting every other active request frees enough
blocks for any admitted request to finish.

Requests that arrive while all slots are busy (or the pool is dry) simply
wait — overload degrades to queueing delay (plus preemption under pool
pressure), never to an error.  Per-slot position tracking is length-based
(no left-padding anywhere): slot i's next token lands at position
`lengths[i]`, independent of every other slot.
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Deque, List, Optional

import numpy as np

from repro.serve.kvcache import BlockAllocator, KVCacheConfig
from repro.serve.sampling import SamplingParams
from repro.serve.trace import NULL_RECORDER, stream_digest


@dataclasses.dataclass
class ServeRequest:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int
    arrival_time: float = 0.0
    # submit-time sampling knobs; the default is greedy (temperature 0),
    # which is bitwise the pre-sampling argmax path.  The params ride on
    # the request through its whole life — slot residency, preemption,
    # resume — so per-token keys (seed, rid, token_index) never drift.
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    # lifecycle timestamps (engine clock)
    admitted_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    # generation state
    output: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    # chunked-prefill state: prompt tokens whose KV is committed to the pool.
    # A request joins the decode batch only once prefilled == prompt_len; the
    # unified step advances it by up to `chunk_tokens` per engine step.
    prefilled: int = 0
    # preemption state
    preemptions: int = 0
    preempted_time: Optional[float] = None  # set while off-slot awaiting resume
    stall_s: float = 0.0                    # total time spent preempted
    last_stall_s: float = 0.0               # stall of the most recent resume

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))

    @property
    def prefilling(self) -> bool:
        """True while some prompt KV is still uncommitted — the request
        holds a slot but is not yet part of the decode batch."""
        return self.prefilled < self.prompt_len

    @property
    def done(self) -> bool:
        return self.finish_time is not None

    @property
    def remaining_budget(self) -> int:
        return self.max_new_tokens - len(self.output)

    @property
    def latency_s(self) -> float:
        """Completion latency; NaN until the request finishes (a finite
        value here for an unfinished request would silently poison any
        aggregate it lands in)."""
        if self.finish_time is None:
            return math.nan
        return self.finish_time - self.arrival_time

    @property
    def ttft_s(self) -> float:
        """Time to first token; NaN until the first token exists."""
        if self.first_token_time is None:
            return math.nan
        return self.first_token_time - self.arrival_time


class PagedCapacity:
    """The paged-KV family's admission/footprint model: the capacity-seam
    object the scheduler consults instead of hard-wiring block arithmetic.

    Submit guards, fresh/resume admission gates and the retire-time release
    are verbatim relocations of the scheduler's pre-seam logic (same order,
    same reject strings, same trace events), so extracting the seam is a
    provable no-op for DecoderLM.  `SlotStateCache`'s `SlotCapacity`
    (serve/statecache.py) is the other implementation — fixed one-row
    footprint, claimed lazily at first-chunk dispatch."""

    def __init__(self, kv_cfg: KVCacheConfig, alloc: BlockAllocator):
        self.kv_cfg = kv_cfg
        self.alloc = alloc

    def submit_reason(self, req: "ServeRequest") -> Optional[str]:
        rows = ContinuousScheduler.kv_rows(req)
        if rows > self.kv_cfg.max_seq:
            return (f"prompt {req.prompt_len} + max_new "
                    f"{req.max_new_tokens} exceeds max_seq "
                    f"{self.kv_cfg.max_seq}")
        need = self.kv_cfg.blocks_for(rows)
        usable = self.kv_cfg.num_blocks - 1
        if need > usable:
            # could never finish even running alone on an empty pool —
            # reject now instead of preempting everyone and still dying.
            # (This guard is also what makes preemption terminate: with
            # every other request evicted, any admitted request can always
            # extend to its worst case.)
            return f"needs {need} KV blocks but the pool only has {usable}"
        return None

    def _prefix_plan(self, req: "ServeRequest"):
        """(matched blocks, revived count, fresh blocks) for admitting req
        with prefix sharing: `matched` comes from the allocator's prefix
        index, `revived` counts the matched blocks currently parked on the
        free list (refcount 0 — adopting them shrinks the free pool), and
        `fresh` is what the prompt still needs beyond the match."""
        if not getattr(self.kv_cfg, "prefix_sharing", False):
            return [], 0, self.kv_cfg.blocks_for(req.prompt_len)
        matched = self.alloc.match_prefix(req.prompt)
        revived = sum(1 for b in matched if b not in self.alloc.refcount)
        fresh = self.kv_cfg.blocks_for(req.prompt_len) - len(matched)
        return matched, revived, fresh

    def can_admit_fresh(self, req: "ServeRequest") -> bool:
        # live matched blocks are free capacity-wise (refcount bump only);
        # revived ones leave the free list, so they count like fresh blocks
        _, revived, fresh = self._prefix_plan(req)
        return self.alloc.can_allocate(fresh + revived)

    def admit_fresh(self, req: "ServeRequest") -> None:
        matched, _, fresh = self._prefix_plan(req)
        if not matched:
            self.alloc.allocate(req.rid,
                                self.kv_cfg.blocks_for(req.prompt_len))
            return
        # adopt the shared prefix FIRST (reviving any free-listed matches),
        # THEN grow the fresh tail — allocation must not evict a block the
        # match is about to revive
        self.alloc.share(req.rid, matched)
        if fresh:
            ok = self.alloc.extend(req.rid, req.prompt_len)
            assert ok, "admission gate passed but the fresh tail failed"
        # prefill starts at the first unshared token; at least one prompt
        # token always runs through the chunk lane so the first output
        # token is sampled from the segment's logits exactly as unshared
        req.prefilled = min(len(matched) * self.kv_cfg.block_size,
                            req.prompt_len - 1)

    def can_admit_resume(self, req: "ServeRequest") -> bool:
        return self.alloc.can_allocate(self.alloc.swapped[req.rid])

    def admit_resume(self, req: "ServeRequest") -> None:
        self.alloc.swap_in(req.rid)

    def release(self, req: "ServeRequest") -> None:
        self.alloc.free(req.rid)

    def occupancy(self) -> float:
        return self.alloc.occupancy()


class ContinuousScheduler:
    """Admission control over `max_slots` decode slots + a capacity model.

    The scheduler owns WHO is resident (slots, queues, admission order,
    preemption policy); the capacity object owns the family's memory
    arithmetic (what a request's footprint is, whether the pool covers it,
    how admission/retire move it).  `PagedCapacity` is the DecoderLM
    implementation; passing `capacity=` explicitly plugs in another family
    (`SlotCapacity` for the state-cache families).  The legacy
    `(max_slots, kv_cfg, alloc)` construction builds a `PagedCapacity`
    internally and stays bit-identical."""

    def __init__(self, max_slots: int, kv_cfg: Optional[KVCacheConfig] = None,
                 alloc: Optional[BlockAllocator] = None, trace=NULL_RECORDER,
                 capacity=None):
        self.max_slots = max_slots
        if capacity is None:
            capacity = PagedCapacity(kv_cfg, alloc)
        self.capacity = capacity
        self.kv_cfg = kv_cfg
        self.alloc = alloc if alloc is not None else getattr(
            capacity, "alloc", None)
        # structured event recorder (`repro.serve.trace`); the engine passes
        # its own, the default no-op costs one attribute lookup per site
        self.trace = trace
        # family tag stamped on lifecycle events; the engine overwrites it
        # from its FamilyAdapter.  Pre-seam traces carried no field, so the
        # audit treats an absent tag as "decoder".
        self.family = "decoder"
        self.waiting: Deque[ServeRequest] = deque()
        self.resumed: Deque[ServeRequest] = deque()   # preempted, to re-admit
        self.slots: List[Optional[ServeRequest]] = [None] * max_slots

    # ------------------------------------------------------------- queries
    @property
    def num_active(self) -> int:
        return sum(r is not None for r in self.slots)

    @property
    def num_waiting(self) -> int:
        return len(self.waiting)

    @property
    def num_preempted(self) -> int:
        return len(self.resumed)

    @property
    def has_work(self) -> bool:
        return (self.num_active > 0 or self.num_waiting > 0
                or self.num_preempted > 0)

    def slot_rids(self) -> List[Optional[int]]:
        return [r.rid if r is not None else None for r in self.slots]

    # ----------------------------------------------------------- lifecycle
    @staticmethod
    def kv_rows(req: ServeRequest) -> int:
        """KV rows a request can ever occupy: the prompt plus every
        generated token except the last (which is emitted but never fed
        back through a decode step, so its K/V row is never written)."""
        return req.prompt_len + req.max_new_tokens - 1

    def _reject(self, req: ServeRequest, reason: str) -> None:
        self.trace.emit("reject", rid=req.rid, reason=reason)
        raise ValueError(f"request {req.rid}: {reason}")

    def submit(self, req: ServeRequest) -> None:
        if req.max_new_tokens < 1:
            self._reject(req, "max_new_tokens must be >= 1")
        if req.prompt_len < 1:
            self._reject(req, "empty prompt")
        bad = req.sampling.invalid_reason()
        if bad is not None:
            self._reject(req, bad)
        reason = self.capacity.submit_reason(req)
        if reason is not None:
            self._reject(req, reason)
        self.waiting.append(req)
        # sampled submits carry their knobs (incl. the per-request seed) in
        # the trace, so a recorded run is exactly replayable; the audit
        # layer checks the seed is present whenever temperature > 0
        extra = {}
        if not req.sampling.greedy:
            s = req.sampling
            extra = dict(temperature=s.temperature, top_k=s.top_k,
                         top_p=s.top_p, seed=s.seed)
        self.trace.emit("submit", rid=req.rid, arrival=req.arrival_time,
                        prompt_len=req.prompt_len,
                        max_new=req.max_new_tokens, family=self.family,
                        **extra)

    def admit(self, now: float) -> List[ServeRequest]:
        """Move waiting/preempted requests into free slots; returns the
        newly admitted (resumes carry swapped-out KV the engine must commit
        before decoding; fresh admissions are prefilled).

        Resume-first with head-of-line blocking on BOTH queues: a preempted
        request re-enters before any newer arrival, and a request too large
        for the current free pool also holds back the requests behind it,
        preserving admission-order fairness.  Fresh admissions are gated on
        the *prompt* footprint only — decode KV grows on demand."""
        admitted: List[ServeRequest] = []
        for slot in range(self.max_slots):
            if self.slots[slot] is not None:
                continue
            if self.resumed:
                req = self.resumed[0]
                if not self.capacity.can_admit_resume(req):
                    break   # nobody jumps a preempted request's re-admission
                self.resumed.popleft()
                self.capacity.admit_resume(req)
                req.last_stall_s = now - req.preempted_time
                req.stall_s += req.last_stall_s
                req.preempted_time = None
                kind = "resume"
            elif self.waiting:
                req = self.waiting[0]
                if req.arrival_time > now:
                    break  # not yet arrived (simulated-arrival workloads)
                if not self.capacity.can_admit_fresh(req):
                    break
                self.waiting.popleft()
                self.capacity.admit_fresh(req)
                req.admitted_time = now
                kind = "fresh"
            else:
                break
            req.slot = slot
            self.slots[slot] = req
            admitted.append(req)
            self.trace.emit("admit", t=now, rid=req.rid, slot=slot, kind=kind,
                            family=self.family)
        return admitted

    def next_chunks(self, budget: int, max_segments: int = 1) -> List[tuple]:
        """Pick this step's prefill chunk as a PACKED list of segments:
        requests with uncommitted prompt tokens, oldest admission first
        (ties: lowest rid), greedily fill the budget — each takes
        min(remaining budget, remaining prompt), so the head request may
        split mid-prompt exactly as before and the tail segment may too
        (the split point just becomes that request's next chunk start).

        Returns up to `max_segments` tuples (request, start, n_tokens);
        empty when no prompt work is pending.  Head-of-line by admission
        time: an older prompt always receives budget before a younger one,
        so TTFT ordering follows admission ordering, while younger prompts
        may ride along in whatever budget the head leaves idle — that
        left-over budget is exactly what single-segment chunking wasted
        (the compiled chunk lane executes at full width regardless of
        fill).  The budget is the unified step's `chunk_tokens` — the
        token-budget counterpart of slot accounting: slots bound *who* is
        resident, the chunk budget bounds how much *prompt* work any
        single step may carry, which is what keeps prompt work from
        stalling the decode batch."""
        if budget < 1 or max_segments < 1:
            return []
        cands = sorted([r for r in self.slots
                        if r is not None and r.prefilling],
                       key=lambda r: (r.admitted_time, r.rid))
        out: List[tuple] = []
        for req in cands:
            if budget < 1 or len(out) >= max_segments:
                break
            n = min(budget, req.prompt_len - req.prefilled)
            out.append((req, req.prefilled, n))
            budget -= n
        return out

    def victim_for_preemption(
            self, exclude_rid: int,
            eligible=None) -> Optional[ServeRequest]:
        """Deterministic victim choice when the pool runs dry: the most
        recently admitted active request (LIFO — oldest work is never the
        one rolled back), preferring the largest remaining budget among
        requests admitted at the same instant (the long-tail request has
        the most KV growth still ahead of it), then the highest rid.

        `eligible` (optional predicate) narrows the candidates to requests
        whose eviction can actually free capacity — the state-cache family
        passes `holds-a-state-row`, since an admitted-but-unclaimed request
        owns nothing to reclaim.  The paged family leaves it unset (every
        resident holds blocks from admission), which preserves the pre-seam
        choice exactly."""
        cands = [r for r in self.slots
                 if r is not None and r.rid != exclude_rid
                 and (eligible is None or eligible(r))]
        if not cands:
            return None
        return max(cands, key=lambda r: (r.admitted_time,
                                         r.remaining_budget, r.rid))

    def preempt(self, req: ServeRequest, now: float) -> None:
        """Take `req` off its slot and queue it for resume.  The engine
        swaps the KV blocks out (see `PagedKVCache.swap_out`) BEFORE calling
        this; here is only the slot/queue bookkeeping.  Partially prefilled
        requests preempt exactly like decoding ones — `prefilled` rides on
        the request, so after the swap-in restores the committed KV the
        chunk accounting resumes the prompt mid-stream, recomputing
        nothing."""
        assert req.slot is not None and self.slots[req.slot] is req
        self.trace.emit("preempt", t=now, rid=req.rid, slot=req.slot,
                        family=self.family)
        self.slots[req.slot] = None
        req.slot = None
        req.preemptions += 1
        req.preempted_time = now
        self.resumed.append(req)

    def retire(self, req: ServeRequest, now: float) -> None:
        """Release the request's slot and its capacity holding (KV blocks /
        state row)."""
        req.finish_time = now
        self.capacity.release(req)
        assert req.slot is not None and self.slots[req.slot] is req
        self.slots[req.slot] = None
        req.slot = None
        # the finish event pins the whole token stream via a digest the
        # replay audit recomputes from first_token/decode_token events
        self.trace.emit("finish", t=now, rid=req.rid,
                        n_output=len(req.output),
                        digest=stream_digest(req.output), family=self.family)
