"""Continuous-batching serving runtime (tentpole of the serving subsystem).

Request lifecycle:

    submit() -> waiting -> [scheduler admits into a free slot if the
                PROMPT fits the free pool — not prompt+budget]
             -> bucketed prefill (B=1, right-padded, KV committed into the
                paged pool at the slot's block table; first token sampled)
             -> joins the in-flight decode batch within the SAME step()
                (admit -> prefill -> decode all run in one engine step, so
                an admitted request has emitted 2 tokens after one step)
             -> greedy decode, one token per engine step; KV blocks grow
                ON DEMAND (`BlockAllocator.extend`, one block as each
                boundary is crossed); retiring on eos/max_new -> blocks +
                slot freed, metrics recorded.

Under pool pressure the grow path preempts: when a request cannot extend,
the scheduler's victim (LIFO by admission, preferring the most remaining
budget) has its KV swapped out to a host buffer, its slot and blocks are
released, and it joins the resume queue.  Resume re-admits ahead of new
arrivals, swaps the saved KV back into freshly allocated blocks through
the SAME jitted commit program the bucketed prefill uses (padded to the
same power-of-two buckets), restores the slot's length/last-token state,
and decoding continues — no token is recomputed and the single decode
program never recompiles (its shapes are static in slots and pool blocks;
preemption only edits block-table *data*).  Commit programs stay bounded
by the same power-of-two bucket ladder prefill uses: a resume can at most
warm a ladder rung no prompt happened to reach, never an unbounded shape.

Key properties the fixed-batch `ServeEngine` lacks:

  * requests are admitted into *running* decode batches — a new arrival
    decodes alongside the in-flight batch in the very step that admits it,
    instead of waiting for the whole previous batch to drain;
  * no cross-request padding: per-slot lengths/block-tables mean a 12-token
    prompt next to a 200-token prompt costs 12 tokens of KV;
  * the decode program is compiled ONCE (static slot/pool shapes); prefill
    compiles per power-of-two bucket, bounded by log2(max_seq) programs;
  * the tuned `InferencePlan` drives dispatch: prefill and decode attention
    backends AND every stage matmul (qkv_proj / mlp_up / mlp_down /
    lm_head) are chosen separately by `PlanRouter` from a stage-qualified
    serve plan (see `repro.serve.router` and `repro.kernels.dispatch`).

The engine clock is injectable (`now_fn`) so benchmarks can replay Poisson
arrival traces in wall time or virtual time with identical scheduling.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import ShardingRules
from repro.launch.steps import (
    jit_commit_prefill,
    jit_paged_decode_step,
    jit_paged_prefill_step,
)
from repro.serve.kvcache import NULL_BLOCK, KVCacheConfig, PagedKVCache
from repro.serve.metrics import ServeMetrics
from repro.serve.router import PlanRouter
from repro.serve.scheduler import ContinuousScheduler, ServeRequest


@dataclasses.dataclass
class RuntimeConfig:
    max_slots: int = 4                # decode batch width (compiled once)
    block_size: int = 16              # KV block granularity (token rows)
    max_blocks_per_seq: int = 8       # per-request table width
    num_blocks: Optional[int] = None  # pool size; default: slots*table + null
    max_new_tokens: int = 32          # default generation budget
    eos_id: int = -1                  # -1: never stop early
    interpret: bool = True            # False: compile Pallas lanes on real TPU

    @property
    def max_seq(self) -> int:
        return self.block_size * self.max_blocks_per_seq

    def kv_config(self) -> KVCacheConfig:
        nb = self.num_blocks
        if nb is None:
            nb = self.max_slots * self.max_blocks_per_seq + 1
        return KVCacheConfig(num_blocks=nb, block_size=self.block_size,
                             max_blocks_per_seq=self.max_blocks_per_seq)


class ContinuousEngine:
    """Slot-based continuous-batching engine over the paged KV-cache."""

    def __init__(self, model, params, mesh, rules: ShardingRules,
                 cfg: RuntimeConfig, router: Optional[PlanRouter] = None,
                 now_fn: Optional[Callable[[], float]] = None):
        if not hasattr(model, "decode_step_paged"):
            raise TypeError(
                f"{type(model).__name__} has no paged decode path; use the "
                "fixed-batch ServeEngine for this family")
        self.model = model
        self.params = params
        self.mesh = mesh
        self.rules = rules
        self.cfg = cfg
        self.router = router or PlanRouter(None)
        self.now_fn = now_fn or time.perf_counter
        mcfg = model.cfg
        self.kv_cfg = cfg.kv_config()
        self.cache = PagedKVCache(self.kv_cfg, mcfg.n_layers, mcfg.n_kv_heads,
                                  mcfg.hd, jnp.dtype(mcfg.dtype))
        self.scheduler = ContinuousScheduler(cfg.max_slots, self.kv_cfg,
                                             self.cache.alloc)
        self.metrics = ServeMetrics()
        self._rid = 0
        self._done: List[ServeRequest] = []
        # per-slot host state
        self._lengths = np.zeros((cfg.max_slots,), np.int32)
        self._last_tok = np.zeros((cfg.max_slots,), np.int32)
        # compiled programs — attention backends AND the per-stage matmul
        # lane tables come from the plan's respective stage choices.  (The
        # paged decode kernel's block geometry is fixed by the pool, so its
        # stage choice contributes only the backend; the prefill flash
        # kernel also takes the tuned block_q/block_kv config.  The matmul
        # tables route qkv_proj/mlp_up/mlp_down/lm_head through the chosen
        # XLA-vs-Pallas lane; closed over at trace time, so dispatch never
        # recompiles mid-serve.)
        decode_backend, _ = self.router.attention_backend("decode")
        self._matmul_tables = {s: self.router.matmul_table(s)
                               for s in ("prefill", "decode")}
        self._decode = jit_paged_decode_step(
            model, mesh, rules, attn_backend=decode_backend,
            matmul_table=self._matmul_tables["decode"],
            interpret=cfg.interpret)
        self._prefill_choice = self.router.attention_backend("prefill")
        self._prefills: Dict[int, Any] = {}   # bucket len -> jitted prefill
        self._commit = jit_commit_prefill(model, mesh, rules)

    # ------------------------------------------------------------ interface
    def submit(self, prompt: np.ndarray, max_new_tokens: Optional[int] = None,
               arrival_time: Optional[float] = None) -> int:
        self._rid += 1
        if max_new_tokens is None:
            max_new_tokens = self.cfg.max_new_tokens
        req = ServeRequest(
            rid=self._rid, prompt=np.asarray(prompt, np.int32),
            max_new_tokens=max_new_tokens,
            arrival_time=(arrival_time if arrival_time is not None
                          else self.now_fn()))
        self.scheduler.submit(req)
        return self._rid

    def run(self) -> List[ServeRequest]:
        """Step until every submitted request completes; returns them in
        completion order.  Idle steps (all slots empty, next arrival still
        in the future) back off briefly instead of spinning."""
        if self.metrics.start_time == 0.0:
            self.metrics.start_time = self.now_fn()
        with self.mesh:
            while self.scheduler.has_work:
                if not self.step():
                    time.sleep(2e-4)
        self.metrics.end_time = self.now_fn()
        done, self._done = self._done, []
        return done

    def reset_metrics(self) -> None:
        """Fresh metrics (e.g. after a warm-up pass); compiled programs and
        cache state are kept."""
        self.metrics = ServeMetrics()

    # ----------------------------------------------------------- internals
    def _bucket(self, prompt_len: int) -> int:
        """Power-of-two block-count bucket (>= 1 block) covering the prompt:
        at most log2(max_blocks_per_seq)+1 prefill programs ever compile."""
        bs = self.kv_cfg.block_size
        nb = max(1, -(-prompt_len // bs))
        p = 1
        while p < nb:
            p *= 2
        return min(p, self.kv_cfg.max_blocks_per_seq) * bs

    def _prefill_fn(self, bucket: int):
        fn = self._prefills.get(bucket)
        if fn is None:
            specs = {"tokens": jax.ShapeDtypeStruct((1, bucket), jnp.int32)}
            backend, config = self._prefill_choice
            fn = jit_paged_prefill_step(self.model, self.mesh, self.rules,
                                        specs, attn_backend=backend,
                                        attn_config=config,
                                        matmul_table=self._matmul_tables["prefill"],
                                        interpret=self.cfg.interpret)
            self._prefills[bucket] = fn
        return fn

    # ------------------------------------------------- preemption / resume
    def _ensure_blocks(self, req: ServeRequest) -> None:
        """Grow req's block table to cover its next decode write (position
        `lengths[slot]`), preempting victims while the pool is dry.  The
        submit-time guard (single-request worst case fits the pool) makes
        the loop terminate: once every other active request is evicted,
        req owns every allocated block and extend cannot fail."""
        need_rows = int(self._lengths[req.slot]) + 1
        while not self.cache.alloc.extend(req.rid, need_rows):
            victim = self.scheduler.victim_for_preemption(exclude_rid=req.rid)
            if victim is None:
                raise MemoryError(
                    f"request {req.rid} cannot grow to {need_rows} rows with "
                    "no victims left — submit() guard violated")
            self._preempt(victim)

    def _preempt(self, victim: ServeRequest) -> None:
        """Swap the victim's KV out to host, free its blocks + slot, queue
        it for resume."""
        slot = victim.slot
        nbytes = self.cache.swap_out(victim.rid)
        self.scheduler.preempt(victim, self.now_fn())
        self._reset_slot(slot)
        self.metrics.record_preemption(nbytes)

    def _resume(self, req: ServeRequest) -> None:
        """Swap a re-admitted request's KV back in: scatter the host buffer
        into the freshly allocated blocks via the SAME jitted commit program
        the bucketed prefill uses (host blocks padded to the power-of-two
        bucket, padding ids pointing at the null sink), then restore the
        slot's host state.  No forward pass — no token is recomputed."""
        t0 = time.perf_counter()
        k_host, v_host = self.cache.take_swapped(req.rid)
        nbytes = k_host.nbytes + v_host.nbytes   # before bucket padding
        table = self.cache.alloc.tables[req.rid]
        nb = k_host.shape[1]
        assert nb == len(table)
        bs = self.kv_cfg.block_size
        nb_pad = self._bucket(nb * bs) // bs
        ids = np.full((nb_pad,), NULL_BLOCK, np.int32)
        ids[:nb] = table
        if nb_pad > nb:
            pad = np.zeros(k_host.shape[:1] + (nb_pad - nb,)
                           + k_host.shape[2:], k_host.dtype)
            k_host = np.concatenate([k_host, pad], axis=1)
            v_host = np.concatenate([v_host, pad], axis=1)
        L = k_host.shape[0]
        ks = jnp.asarray(k_host.reshape(L, 1, nb_pad * bs, *k_host.shape[3:]))
        vs = jnp.asarray(v_host.reshape(L, 1, nb_pad * bs, *v_host.shape[3:]))
        self.cache.k, self.cache.v = self._commit(
            self.cache.k, self.cache.v, ks, vs, jnp.asarray(ids))
        self.metrics.prefill_time_s += time.perf_counter() - t0
        self.metrics.record_resume(nbytes, req.last_stall_s)
        slot = req.slot
        self._lengths[slot] = req.prompt_len + len(req.output) - 1
        self._last_tok[slot] = req.output[-1]

    def _prefill(self, req: ServeRequest, now: float) -> None:
        plen = req.prompt_len
        bucket = self._bucket(plen)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :plen] = req.prompt                       # right-pad
        lengths = jnp.asarray([plen], jnp.int32)
        t0 = time.perf_counter()
        logits, ks, vs = self._prefill_fn(bucket)(
            self.params, {"tokens": jnp.asarray(toks)}, lengths)

        # commit the prompt KV into this request's blocks
        table = self.cache.alloc.tables[req.rid]
        nb = bucket // self.kv_cfg.block_size
        ids = np.full((nb,), NULL_BLOCK, np.int32)
        n_real = min(nb, len(table))
        ids[:n_real] = table[:n_real]
        self.cache.k, self.cache.v = self._commit(
            self.cache.k, self.cache.v, ks, vs, jnp.asarray(ids))
        self.metrics.prefill_time_s += time.perf_counter() - t0

        first = int(jnp.argmax(logits[0, -1], -1))
        req.output.append(first)
        req.first_token_time = self.now_fn()
        self.metrics.record_first_token(req.first_token_time - req.arrival_time)
        self.metrics.prefills += 1
        slot = req.slot
        self._lengths[slot] = plen
        self._last_tok[slot] = first
        if self._finished(req):
            self.scheduler.retire(req, self.now_fn())
            self._reset_slot(slot)
            self._complete(req)

    def _reset_slot(self, slot: int) -> None:
        # stale lengths on a freed slot would index past the (all-null)
        # block table; zeroed state keeps every inactive slot's writes
        # pinned to the sink block.
        self._lengths[slot] = 0
        self._last_tok[slot] = 0

    def _finished(self, req: ServeRequest) -> bool:
        if len(req.output) >= req.max_new_tokens:
            return True
        return self.cfg.eos_id >= 0 and req.output[-1] == self.cfg.eos_id

    def _complete(self, req: ServeRequest) -> None:
        self.metrics.record_completion(req.latency_s, len(req.output))
        self._done.append(req)

    def step(self) -> bool:
        """One engine step: admit (resumes swap back in, new arrivals
        prefill), grow every active request's block table to cover its next
        token (preempting victims if the pool is dry), then one decode step
        over every surviving slot.  Returns False when nothing ran."""
        now = self.now_fn()
        admitted = self.scheduler.admit(now)
        for req in admitted:
            if self.cache.is_swapped(req.rid):
                self._resume(req)
            else:
                self._prefill(req, now)

        # on-demand growth: every active request secures the block its next
        # decode write lands in.  A request preempted as some later grower's
        # victim drops out of this step's batch (slot is None by then).
        for req in [r for r in self.scheduler.slots if r is not None]:
            if req.slot is not None:
                self._ensure_blocks(req)

        active = [r for r in self.scheduler.slots if r is not None]
        if not active:
            return bool(admitted)
        bt = jnp.asarray(self.cache.table_array(self.scheduler.slot_rids()))
        lengths = jnp.asarray(self._lengths)
        tokens = jnp.asarray(self._last_tok[:, None])
        t0 = time.perf_counter()
        nxt_dev, self.cache.k, self.cache.v = self._decode(
            self.params, self.cache.k, self.cache.v, bt, lengths, tokens)
        nxt = np.asarray(nxt_dev, np.int32)
        self.metrics.decode_time_s += time.perf_counter() - t0

        now = self.now_fn()
        self.metrics.record_step(len(active), self.cfg.max_slots,
                                 self.cache.alloc.occupancy())
        for req in active:
            slot = req.slot
            req.output.append(int(nxt[slot]))
            self._lengths[slot] += 1
            self._last_tok[slot] = nxt[slot]
            if self._finished(req):
                self.scheduler.retire(req, now)
                self._reset_slot(slot)
                self._complete(req)
        return True
