"""Continuous-batching serving runtime (tentpole of the serving subsystem).

Request lifecycle under the UNIFIED TOKEN-BUDGET STEP:

    submit() -> waiting -> [scheduler admits into a free slot if the
                PROMPT fits the free pool — not prompt+budget; admission
                itself runs no program]
             -> chunked prefill: each engine step packs up to
                `chunk_tokens` of pending prompt work — prompt SEGMENTS
                from up to `chunk_segments` requests, oldest admission
                first, greedy fill — into the step's prefill lane,
                committing each segment's KV into its own request's paged
                blocks in-program, chunk by chunk, while the decode lane
                advances EVERY in-flight request in the same compiled
                program (a long prompt never stalls the decode batch, and
                short prompts no longer waste the budget's tail)
             -> the chunk that completes the prompt also samples the first
                token (TTFT spans all of the prompt's chunks)
             -> joins the decode batch the NEXT step; greedy decode, one
                token per engine step; KV blocks grow ON DEMAND
                (`BlockAllocator.extend`, one block as each boundary is
                crossed); retiring on eos/max_new -> blocks + slot freed,
                metrics recorded.

One engine step = ONE invocation of one of exactly TWO jitted programs:
`jit_unified_step` (packed prefill lane + decode lane) when prompt work is
pending, `jit_decode_only_step` (the decode lane alone) when none is — the
unified program's chunk lane executes at its compiled width even when
idle, so chunk-less steps skip it entirely instead of masking it.  Both
programs' shapes are static in (slots, pool blocks, table width, chunk
budget, segment slots): admission, chunk packing, retirement, preemption
and resume are all pure data updates.  Each program compiles exactly once
— the power-of-two prefill-bucket ladder of the old two-program runtime is
gone entirely, and with it every admission-time compile.

Under pool pressure the grow path preempts: when a request cannot extend,
the scheduler's victim (LIFO by admission, preferring the most remaining
budget) has its KV swapped out to a host buffer, its slot and blocks are
released, and it joins the resume queue.  Mid-prefill requests preempt the
same way — `ServeRequest.prefilled` rides along, so a resumed request
continues its prompt at the next uncommitted token.  Resume re-admits
ahead of new arrivals and scatters the saved KV back through the jitted
commit program, always padded to the full table width, so exactly one
commit shape ever traces.  No token is recomputed and the unified program
never recompiles (preemption only edits block-table *data*).

Key properties the fixed-batch `ServeEngine` lacks:

  * requests are admitted into *running* decode batches, and long prompts
    are time-sliced: a 200-token prompt crosses the device as
    ceil(200/chunk_tokens) budgeted chunks, each sharing its step with the
    whole decode batch, instead of a dedicated B=1 prefill program that
    stalls everyone (head-of-line interference);
  * short prompts are PACKED: one step's chunk carries segments from up to
    `chunk_segments` requests (greedy fill, oldest admission first), so a
    burst of small prompts fills the budget the head request leaves idle
    instead of spending one step each;
  * no cross-request padding: per-slot lengths/block-tables mean a 12-token
    prompt next to a 200-token prompt costs 12 tokens of KV;
  * exactly TWO compiled programs serve every step (static slot/pool/chunk
    shapes; the decode-only variant skips the idle chunk lane); admission
    compiles nothing, ever;
  * the tuned `InferencePlan` drives dispatch: the decode and chunked-
    prefill attention backends AND every stage matmul (qkv_proj / mlp_up /
    mlp_down / lm_head) are chosen separately by `PlanRouter` from a
    stage-qualified serve plan — the chunk lane has its own
    `prefill_chunk` stage whose attention config tunes the paged prefill
    kernel's `block_q` (see `repro.serve.router`, `repro.kernels.dispatch`).

The engine clock is injectable (`now_fn`) so benchmarks can replay Poisson
arrival traces in wall time or virtual time with identical scheduling.

Passing a `repro.serve.trace.TraceRecorder` as `trace=` records every
scheduler / allocator / step decision as a typed event on the engine clock
(admission, chunk packing, preemption and swap, block accounting, step
dispatch with lane fill and device time, program compiles).  The recorder
threads through the scheduler and the block allocator, exports to
Chrome-trace-event JSON for `ui.perfetto.dev`, and feeds the trace audit
(`repro.serve.traceview`).  Disabled — the default — every emission site
holds the no-op recorder, so serving costs one attribute lookup per site
and the per-token loops skip even that via the `enabled` flag.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import ShardingRules, prune_for_mesh
from repro.launch.steps import (
    jit_commit_prefill,
    jit_decode_only_step,
    jit_unified_step,
    paged_pool_sharding,
)
from repro.serve.kvcache import NULL_BLOCK, KVCacheConfig, PagedKVCache
from repro.serve.metrics import ServeMetrics
from repro.serve.router import DEFAULT_CHUNK_TOKENS, PlanRouter
from repro.serve.scheduler import ContinuousScheduler, ServeRequest
from repro.serve.trace import NULL_RECORDER, TraceRecorder


@dataclasses.dataclass
class RuntimeConfig:
    max_slots: int = 4                # decode batch width (compiled once)
    block_size: int = 16              # KV block granularity (token rows)
    max_blocks_per_seq: int = 8       # per-request table width
    num_blocks: Optional[int] = None  # pool size; default: slots*table + null
    max_new_tokens: int = 32          # default generation budget
    eos_id: int = -1                  # -1: never stop early
    # prompt tokens the unified step may carry per engine step (the prefill
    # lane's width).  None = max_seq: any admissible prompt prefills in one
    # chunk (the "unchunked" configuration — identical token streams, just
    # no slicing).  Smaller budgets slice long prompts across steps so the
    # decode batch keeps streaming.  The lane's width is baked into the
    # unified program, so every step that carries ANY prompt work executes
    # the full width — but chunk-less steps dispatch the compiled
    # decode-only program and skip the lane entirely, and segment packing
    # fills the width with several short prompts at once, so the budget is
    # only ever paid when (and as fully as) prompt work exists.  The
    # default is the shared `router.DEFAULT_CHUNK_TOKENS` so the engine
    # and an untuned serve plan can't drift onto different chunk shapes.
    chunk_tokens: Optional[int] = DEFAULT_CHUNK_TOKENS
    # prompt segments one step's chunk may pack.  Greedy fill means a step
    # carries min(chunk_segments, prefilling requests) segments; 1 restores
    # the single-request chunk lane.  A tuned plan may narrow this via its
    # prefill_chunk stage's `max_segments` choice (old Pallas plans, tuned
    # before the segmented kernel, narrow it to 1 — see
    # PlanRouter.chunk_segments); the narrowed value is the segmented
    # kernel's compiled descriptor height, so the tuned knob sizes the
    # block_q x max-segments grid itself.
    chunk_segments: int = 4
    interpret: bool = True            # False: compile Pallas lanes on real TPU

    @property
    def max_seq(self) -> int:
        return self.block_size * self.max_blocks_per_seq

    @property
    def chunk_width(self) -> int:
        """The prefill lane's RESOLVED width: chunk_tokens clamped to
        [1, max_seq], with None meaning max_seq.  Pass THIS to
        `build_serve_plan(chunk_tokens=...)` so the plan's prefill_chunk
        stage is tuned at the width the engine actually runs."""
        return max(1, min(self.chunk_tokens or self.max_seq, self.max_seq))

    def kv_config(self) -> KVCacheConfig:
        nb = self.num_blocks
        if nb is None:
            nb = self.max_slots * self.max_blocks_per_seq + 1
        return KVCacheConfig(num_blocks=nb, block_size=self.block_size,
                             max_blocks_per_seq=self.max_blocks_per_seq)


class ContinuousEngine:
    """Slot-based continuous-batching engine over the paged KV-cache."""

    def __init__(self, model, params, mesh, rules: ShardingRules,
                 cfg: RuntimeConfig, router: Optional[PlanRouter] = None,
                 now_fn: Optional[Callable[[], float]] = None,
                 trace: Optional[TraceRecorder] = None):
        if not hasattr(model, "decode_step_paged"):
            raise TypeError(
                f"{type(model).__name__} has no paged decode path; use the "
                "fixed-batch ServeEngine for this family")
        self.model = model
        self.params = params
        self.mesh = mesh
        self.rules = rules
        self.cfg = cfg
        self.router = router or PlanRouter(None)
        self.now_fn = now_fn or time.perf_counter
        # structured event tracing (`repro.serve.trace`): the recorder is
        # threaded through the scheduler and the block allocator so every
        # lifecycle / pool / step event lands in ONE stream on the ENGINE
        # clock.  Disabled (the default) it is the no-op recorder — one
        # attribute lookup per emission site, per-token hot loops guard on
        # `trace.enabled` and skip even that.
        self.trace = trace if trace is not None else NULL_RECORDER
        if self.trace.enabled and self.trace.now_fn is None:
            self.trace.now_fn = self.now_fn
        mcfg = model.cfg
        self.kv_cfg = cfg.kv_config()
        self.cache = PagedKVCache(self.kv_cfg, mcfg.n_layers, mcfg.n_kv_heads,
                                  mcfg.hd, jnp.dtype(mcfg.dtype))
        self.cache.alloc.trace = self.trace
        self.scheduler = ContinuousScheduler(cfg.max_slots, self.kv_cfg,
                                             self.cache.alloc,
                                             trace=self.trace)
        self.metrics = ServeMetrics()
        self._rid = 0
        self._step_idx = 0
        self._done: List[ServeRequest] = []
        # fixed prefill-lane geometry: the step's prompt-token budget and
        # the packed-segment descriptor height, both compiled in.  The
        # height is the EFFECTIVE packing width — cfg.chunk_segments
        # narrowed by the plan's tuned `max_segments` (old Pallas plans,
        # tuned before the segmented kernel existed, narrow it to 1) — so
        # the segmented kernel's grid is exactly as tall as the packing
        # the scheduler will actually do: the tuned knob sizes the grid,
        # it doesn't just throttle host-side packing under a wider one.
        self._chunk_width = cfg.chunk_width
        self._chunk_segments = max(1, min(
            cfg.chunk_segments,
            self.router.chunk_segments(default=cfg.chunk_segments)))
        # per-slot host state (decode lane; prefilling slots stay zeroed so
        # their dummy decode row writes to the null sink)
        self._lengths = np.zeros((cfg.max_slots,), np.int32)
        self._last_tok = np.zeros((cfg.max_slots,), np.int32)
        # THE two compiled step programs: the unified step carrying the
        # decode batch plus one packed prompt chunk, and the decode-only
        # fast path for steps with no prompt work (the unified program's
        # chunk lane executes at its compiled width even when idle, so
        # skipping it is a dispatch decision, not a mask).  Attention
        # backends and per-stage matmul lane tables come from the plan's
        # stage choices (decode + the prefill_chunk stage), closed over at
        # trace time — dispatch never recompiles mid-serve, and admission
        # compiles nothing at all.
        decode_backend, _ = self.router.attention_backend("decode")
        chunk_backend, chunk_config = self.router.attention_backend(
            "prefill_chunk")
        self._unified = jit_unified_step(
            model, mesh, rules,
            decode_attn_backend=decode_backend,
            chunk_attn_backend=chunk_backend,
            chunk_attn_config=chunk_config,
            decode_matmul_table=self.router.matmul_table("decode"),
            chunk_matmul_table=self.router.matmul_table("prefill_chunk"),
            interpret=cfg.interpret)
        self._decode_only = jit_decode_only_step(
            model, mesh, rules,
            decode_attn_backend=decode_backend,
            decode_matmul_table=self.router.matmul_table("decode"),
            interpret=cfg.interpret)
        # resume-only commit (swap-in scatter); single full-width shape
        self._commit = jit_commit_prefill(model, mesh, rules)
        # commit the fresh pools to their serving sharding up front: the
        # unified program's donated pool arguments then carry the SAME
        # sharding on the very first step as on every later one, so exactly
        # one executable ever builds (an uncommitted first call would
        # compile a second, layout-shifted copy of the program)
        pool_shard = paged_pool_sharding(model, mesh,
                                         prune_for_mesh(rules, mesh))
        self.cache.k = jax.device_put(self.cache.k, pool_shard)
        self.cache.v = jax.device_put(self.cache.v, pool_shard)

    # ------------------------------------------------------------ interface
    def submit(self, prompt: np.ndarray, max_new_tokens: Optional[int] = None,
               arrival_time: Optional[float] = None) -> int:
        self._rid += 1
        if max_new_tokens is None:
            max_new_tokens = self.cfg.max_new_tokens
        req = ServeRequest(
            rid=self._rid, prompt=np.asarray(prompt, np.int32),
            max_new_tokens=max_new_tokens,
            arrival_time=(arrival_time if arrival_time is not None
                          else self.now_fn()))
        self.scheduler.submit(req)
        return self._rid

    def run(self) -> List[ServeRequest]:
        """Step until every submitted request completes; returns them in
        completion order.  Idle steps (all slots empty, next arrival still
        in the future) back off briefly instead of spinning."""
        if self.metrics.start_time is None:
            self.metrics.start_time = self.now_fn()
        with self.mesh:
            while self.scheduler.has_work:
                if not self.step():
                    time.sleep(2e-4)
        self.metrics.end_time = self.now_fn()
        done, self._done = self._done, []
        return done

    def reset_metrics(self) -> None:
        """Fresh metrics (e.g. after a warm-up pass); compiled programs and
        cache state are kept."""
        self.metrics = ServeMetrics()

    # ------------------------------------------------- preemption / resume
    def _ensure_blocks(self, req: ServeRequest) -> None:
        """Grow req's block table to cover its next decode write (position
        `lengths[slot]`), preempting victims while the pool is dry.  The
        submit-time guard (single-request worst case fits the pool) makes
        the loop terminate: once every other active request is evicted,
        req owns every allocated block and extend cannot fail."""
        need_rows = int(self._lengths[req.slot]) + 1
        while not self.cache.alloc.extend(req.rid, need_rows):
            victim = self.scheduler.victim_for_preemption(exclude_rid=req.rid)
            if victim is None:
                raise MemoryError(
                    f"request {req.rid} cannot grow to {need_rows} rows with "
                    "no victims left — submit() guard violated")
            self._preempt(victim)

    def _preempt(self, victim: ServeRequest) -> None:
        """Swap the victim's KV out to host, free its blocks + slot, queue
        it for resume.  Works mid-prefill too: the committed chunks travel
        with the swap and `prefilled` marks where the prompt resumes."""
        slot = victim.slot
        nbytes = self.cache.swap_out(victim.rid)
        self.scheduler.preempt(victim, self.now_fn())
        self._reset_slot(slot)
        self.metrics.record_preemption(nbytes)

    def _resume(self, req: ServeRequest) -> None:
        """Swap a re-admitted request's KV back in: scatter the host buffer
        into the freshly allocated blocks via the jitted commit program,
        always padded to the FULL table width (padding ids point at the
        null sink) so exactly one commit shape ever traces, then restore
        the slot's host state.  No forward pass — no token is recomputed; a
        mid-prefill request continues chunking from `prefilled`."""
        t0 = time.perf_counter()
        k_host, v_host = self.cache.take_swapped(req.rid)
        nbytes = k_host.nbytes + v_host.nbytes   # before table padding
        table = self.cache.alloc.tables[req.rid]
        nb = k_host.shape[1]
        assert nb == len(table)
        bs = self.kv_cfg.block_size
        nb_pad = self.kv_cfg.max_blocks_per_seq
        ids = np.full((nb_pad,), NULL_BLOCK, np.int32)
        ids[:nb] = table
        if nb_pad > nb:
            pad = np.zeros(k_host.shape[:1] + (nb_pad - nb,)
                           + k_host.shape[2:], k_host.dtype)
            k_host = np.concatenate([k_host, pad], axis=1)
            v_host = np.concatenate([v_host, pad], axis=1)
        L = k_host.shape[0]
        ks = jnp.asarray(k_host.reshape(L, 1, nb_pad * bs, *k_host.shape[3:]))
        vs = jnp.asarray(v_host.reshape(L, 1, nb_pad * bs, *v_host.shape[3:]))
        if self.trace.enabled:
            n_commit = self._commit._cache_size()
        self.cache.k, self.cache.v = self._commit(
            self.cache.k, self.cache.v, ks, vs, jnp.asarray(ids))
        swap_in_s = time.perf_counter() - t0
        if self.trace.enabled:
            if self._commit._cache_size() > n_commit:
                self.trace.emit("compile", program="commit",
                                device_s=swap_in_s)
            self.trace.emit("swap_in", rid=req.rid, nbytes=nbytes)
            self.trace.emit("resume", rid=req.rid, stall_s=req.last_stall_s,
                            swap_in_s=swap_in_s)
        self.metrics.record_resume(nbytes, req.last_stall_s,
                                   swap_in_s=swap_in_s)
        slot = req.slot
        if req.prefilling:
            # not in the decode batch yet: stay masked (zeroed) until the
            # remaining chunks commit the rest of the prompt
            self._reset_slot(slot)
        else:
            self._lengths[slot] = req.prompt_len + len(req.output) - 1
            self._last_tok[slot] = req.output[-1]

    def _reset_slot(self, slot: int) -> None:
        # stale lengths on a freed slot would index past the (all-null)
        # block table; zeroed state keeps every inactive slot's writes
        # pinned to the sink block.
        self._lengths[slot] = 0
        self._last_tok[slot] = 0

    def _finished(self, req: ServeRequest) -> bool:
        if len(req.output) >= req.max_new_tokens:
            return True
        return self.cfg.eos_id >= 0 and req.output[-1] == self.cfg.eos_id

    def _complete(self, req: ServeRequest) -> None:
        self.metrics.record_completion(req.latency_s, len(req.output))
        self._done.append(req)

    # ----------------------------------------------------------- unified step
    def _chunk_inputs(self, chunks: List[Tuple[ServeRequest, int, int]]):
        """Host-side prefill-lane arrays for a packed chunk: the segments'
        prompt slices concatenated from row 0 (fixed `_chunk_width`,
        zero-padded), each segment's block table, and the (S, 3) descriptor
        array [row_offset, seg_len, kv_start].  Idle segment slots carry
        seg_len 0 with an all-null table (their row_offset sits at the fill
        level so offsets stay monotone; padding rows divert to the sink)."""
        c = self._chunk_width
        ns = self._chunk_segments
        toks = np.zeros((1, c), np.int32)
        tables = np.full((ns, self.kv_cfg.max_blocks_per_seq),
                         NULL_BLOCK, np.int32)
        info = np.zeros((ns, 3), np.int32)
        q0 = 0
        for i, (req, start, n) in enumerate(chunks):
            toks[0, q0:q0 + n] = req.prompt[start:start + n]
            held = self.cache.alloc.tables[req.rid]
            tables[i, :len(held)] = held
            info[i] = (q0, n, start)
            q0 += n
        info[len(chunks):, 0] = q0            # idle slots: empty span at fill
        return toks, tables, info

    def step(self) -> bool:
        """One engine step = one invocation of one of the TWO compiled step
        programs: admit (resumes swap back in; fresh arrivals just take a
        slot), pack the step's prefill chunk (token-budget accounting,
        greedy fill over up to `chunk_segments` requests), grow every
        *decoding* request's block table to cover its next token
        (preempting victims if the pool is dry), then run either the
        unified program (packed chunk lane + decode lane) or — when no
        prompt work is pending — the decode-only fast path, which skips
        the idle chunk-wide forward entirely.  Returns False when nothing
        ran."""
        now = self.now_fn()
        admitted = self.scheduler.admit(now)
        for req in admitted:
            if self.cache.is_swapped(req.rid):
                self._resume(req)
            # fresh admissions run nothing here: their prompts stream
            # through the unified step's chunk lane, starting this step

        chunks = self.scheduler.next_chunks(self._chunk_width,
                                            self._chunk_segments)

        # on-demand growth for the decode batch: every decoding request
        # secures the block its next write lands in.  A request preempted
        # as some later grower's victim drops out of this step (slot is
        # None by then) — including, possibly, any of the packed segments'
        # requests.
        for req in [r for r in self.scheduler.slots
                    if r is not None and not r.prefilling]:
            if req.slot is not None:
                self._ensure_blocks(req)
        chunks = [ch for ch in chunks if ch[0].slot is not None]

        decoding = [r for r in self.scheduler.slots
                    if r is not None and not r.prefilling]
        if not decoding and not chunks:
            return bool(admitted)

        # decode lane inputs: prefilling slots are masked exactly like empty
        # ones (null table, zero length) — their dummy row writes to the sink
        dec_rids = [r.rid if (r is not None and not r.prefilling) else None
                    for r in self.scheduler.slots]
        bt = jnp.asarray(self.cache.table_array(dec_rids))
        lengths = jnp.asarray(self._lengths)
        tokens = jnp.asarray(self._last_tok[:, None])

        trace = self.trace
        kind = "unified" if chunks else "decode_only"
        step_idx = self._step_idx
        self._step_idx += 1
        if trace.enabled:
            for req, start, n in chunks:
                trace.emit("chunk_scheduled", t=now, rid=req.rid,
                           start=start, n=n)
            trace.emit("step_begin", t=now, step=step_idx, kind=kind,
                       lane_width=self._chunk_width if chunks else 0,
                       segments=len(chunks),
                       chunk_tokens=sum(n for _, _, n in chunks),
                       decode_rows=len(decoding))
            prog = self._unified if chunks else self._decode_only
            n_compiled = prog._cache_size()

        t0 = time.perf_counter()
        if chunks:
            ch_toks, seg_tables, seg_info = self._chunk_inputs(chunks)
            nxt_dev, seg_next_dev, self.cache.k, self.cache.v = self._unified(
                self.params, self.cache.k, self.cache.v, bt, lengths, tokens,
                jnp.asarray(ch_toks), jnp.asarray(seg_tables),
                jnp.asarray(seg_info))
        else:
            # decode-only fast path: no prompt work pending, so the step
            # skips the chunk-wide forward instead of masking it
            nxt_dev, self.cache.k, self.cache.v = self._decode_only(
                self.params, self.cache.k, self.cache.v, bt, lengths, tokens)
        nxt = np.asarray(nxt_dev, np.int32)
        step_s = time.perf_counter() - t0
        if trace.enabled and prog._cache_size() > n_compiled:
            trace.emit("compile", program=kind, device_s=step_s)
        # attribute chunk-only steps to prefill time, everything else to
        # decode time
        if decoding:
            self.metrics.decode_time_s += step_s
        else:
            self.metrics.prefill_time_s += step_s

        now = self.now_fn()
        if trace.enabled:
            trace.emit("step_end", t=now, step=step_idx, kind=kind,
                       lane_width=self._chunk_width if chunks else 0,
                       segments=len(chunks),
                       chunk_tokens=sum(n for _, _, n in chunks),
                       decode_rows=len(decoding), device_s=step_s)
        if chunks:
            self.metrics.record_chunk_step([n for _, _, n in chunks],
                                           self._chunk_width)
            seg_next = np.asarray(seg_next_dev, np.int32)
            for i, (req, start, n) in enumerate(chunks):
                req.prefilled = start + n
                if trace.enabled:
                    trace.emit("chunk_committed", t=now, rid=req.rid,
                               start=start, n=n, prefilled=req.prefilled)
                if not req.prefilling:        # this chunk finished the prompt
                    first = int(seg_next[i])
                    req.output.append(first)
                    req.first_token_time = now
                    trace.emit("first_token", t=now, rid=req.rid, token=first)
                    self.metrics.record_first_token(now - req.arrival_time)
                    self.metrics.prefills += 1
                    slot = req.slot
                    self._lengths[slot] = req.prompt_len
                    self._last_tok[slot] = first
                    if self._finished(req):
                        self.scheduler.retire(req, now)
                        self._reset_slot(slot)
                        self._complete(req)
        elif decoding:
            self.metrics.record_decode_only_step()

        if decoding:
            self.metrics.record_step(len(decoding), self.cfg.max_slots,
                                     self.cache.alloc.occupancy())
            emit_tokens = trace.enabled
            for req in decoding:
                slot = req.slot
                req.output.append(int(nxt[slot]))
                self._lengths[slot] += 1
                self._last_tok[slot] = nxt[slot]
                if emit_tokens:
                    trace.emit("decode_token", t=now, rid=req.rid,
                               token=int(nxt[slot]))
                if self._finished(req):
                    self.scheduler.retire(req, now)
                    self._reset_slot(slot)
                    self._complete(req)
        return True
