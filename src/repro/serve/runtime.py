"""Continuous-batching serving runtime (tentpole of the serving subsystem).

Request lifecycle under the UNIFIED TOKEN-BUDGET STEP:

    submit() -> waiting -> [scheduler admits into a free slot if the
                family's capacity model accepts the request — admission
                itself runs no program]
             -> chunked prefill: each engine step packs up to
                `chunk_tokens` of pending prompt work — prompt SEGMENTS
                from up to `chunk_segments` requests, oldest admission
                first, greedy fill — into the step's prefill lane,
                committing each segment's per-request state (paged KV
                blocks, or a slot-pooled conv/SSM state row) in-program,
                chunk by chunk, while the decode lane advances EVERY
                in-flight request in the same compiled program (a long
                prompt never stalls the decode batch, and short prompts no
                longer waste the budget's tail)
             -> the chunk that completes the prompt also samples the first
                token (TTFT spans all of the prompt's chunks)
             -> joins the decode batch the NEXT step; greedy decode, one
                token per engine step; per-request state grows on demand
                where the family's state grows at all; retiring on
                eos/max_new -> capacity + slot freed, metrics recorded.

THE ENGINE IS FAMILY-AGNOSTIC.  Everything that knows what a family's
per-request device state *is* lives behind a `FamilyAdapter`
(`repro.serve.family`): the paged KV-cache, block tables and paged step
programs for attention decoders (`DecoderFamilyAdapter`); the fixed-size
slot-pooled conv/SSM state and its step programs for `MambaLM`
(`SSMFamilyAdapter`).  The engine's `step()` is pure orchestration —

    admit -> schedule chunk -> grow-or-preempt -> dispatch -> retire

— and every family-specific question routes through the adapter:
`grow_for_decode` (cover the next decode write), `claim_chunk` (cover a
prompt chunk dispatch; the ssm family claims its state row lazily here),
`swap_out`/`resume_commit` (preemption transport), `dispatch` (the one
step-program invocation), `victim_eligible` (narrow preemption victims to
requests whose eviction frees capacity).  Likewise the scheduler consults
the adapter's capacity object (`scheduler.PagedCapacity` /
`statecache.SlotCapacity`) for all admission/footprint arithmetic.

One engine step = ONE invocation of one of exactly TWO jitted programs per
family: the unified step (packed prefill lane + decode lane) when prompt
work is pending, the decode-only fast path when none is — the unified
program's chunk lane executes at its compiled width even when idle, so
chunk-less steps skip it entirely instead of masking it.  Both programs'
shapes are static in (slots, pool size, table/index width, chunk budget,
segment slots): admission, chunk packing, retirement, preemption and
resume are all pure data updates.  Each program compiles exactly once.

Under pool pressure the grow path preempts: when a request cannot extend
(paged family) or claim its first-chunk state row (ssm family), the
scheduler's victim (LIFO by admission, preferring the most remaining
budget, narrowed to capacity holders) has its state swapped out to a host
buffer, its slot and capacity are released, and it joins the resume queue.
Mid-prefill requests preempt the same way — `ServeRequest.prefilled` rides
along, so a resumed request continues its prompt at the next uncommitted
token.  Resume re-admits ahead of new arrivals and scatters the saved
state back through the family's jitted commit program at one fixed shape.
No token is recomputed and the step programs never recompile (preemption
only edits index *data*).

Key properties the fixed-batch `ServeEngine` lacks:

  * requests are admitted into *running* decode batches, and long prompts
    are time-sliced: a 200-token prompt crosses the device as
    ceil(200/chunk_tokens) budgeted chunks, each sharing its step with the
    whole decode batch, instead of a dedicated B=1 prefill program that
    stalls everyone (head-of-line interference);
  * short prompts are PACKED: one step's chunk carries segments from up to
    `chunk_segments` requests (greedy fill, oldest admission first), so a
    burst of small prompts fills the budget the head request leaves idle
    instead of spending one step each (the ssm family's packing width is
    1: the SSD recurrence threads one request's carry through the lane);
  * no cross-request padding: per-slot lengths/indices mean a 12-token
    prompt next to a 200-token prompt costs 12 tokens of state;
  * exactly TWO compiled programs serve every step (static slot/pool/chunk
    shapes; the decode-only variant skips the idle chunk lane); admission
    compiles nothing, ever;
  * the tuned `InferencePlan` drives dispatch per family: stage-qualified
    choices (`decode` / `prefill_chunk` for decoders, `ssm_decode` /
    `ssm_prefill_chunk` for the state-cache family) pick each lane's
    attention backend and matmul tables separately (see
    `repro.serve.router`, `repro.kernels.dispatch`).

The engine clock is injectable (`now_fn`) so benchmarks can replay Poisson
arrival traces in wall time or virtual time with identical scheduling.

Passing a `repro.serve.trace.TraceRecorder` as `trace=` records every
scheduler / allocator / step decision as a typed event on the engine clock
(admission, chunk packing, preemption and swap, pool accounting, step
dispatch with lane fill and device time, program compiles), each stamped
with the serving family.  The recorder threads through the scheduler and
the family's allocator, exports to Chrome-trace-event JSON for
`ui.perfetto.dev`, and feeds the trace audit (`repro.serve.traceview`).
Disabled — the default — every emission site holds the no-op recorder, so
serving costs one attribute lookup per site and the per-token loops skip
even that via the `enabled` flag.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

import jax
import numpy as np

from repro.distributed.sharding import (ShardingRules, params_shardings,
                                        prune_for_mesh)
from repro.serve.family import resolve_family_adapter
from repro.serve.kvcache import KVCacheConfig
from repro.serve.metrics import ServeMetrics
from repro.serve.router import DEFAULT_CHUNK_TOKENS, PlanRouter
from repro.serve.sampling import (SamplingParams, slot_sampling_arrays,
                                  truncate_at_eos)
from repro.serve.scheduler import ContinuousScheduler, ServeRequest
from repro.serve.statecache import StateCacheConfig
from repro.serve.trace import NULL_RECORDER, TraceRecorder


@dataclasses.dataclass
class RuntimeConfig:
    max_slots: int = 4                # decode batch width (compiled once)
    block_size: int = 16              # KV block granularity (token rows)
    max_blocks_per_seq: int = 8       # per-request table width
    num_blocks: Optional[int] = None  # pool size; default: slots*table + null
    max_new_tokens: int = 32          # default generation budget
    eos_id: int = -1                  # -1: never stop early
    # prompt tokens the unified step may carry per engine step (the prefill
    # lane's width).  None = max_seq: any admissible prompt prefills in one
    # chunk (the "unchunked" configuration — identical token streams, just
    # no slicing).  Smaller budgets slice long prompts across steps so the
    # decode batch keeps streaming.  The lane's width is baked into the
    # unified program, so every step that carries ANY prompt work executes
    # the full width — but chunk-less steps dispatch the compiled
    # decode-only program and skip the lane entirely, and segment packing
    # fills the width with several short prompts at once, so the budget is
    # only ever paid when (and as fully as) prompt work exists.  The
    # default is the shared `router.DEFAULT_CHUNK_TOKENS` so the engine
    # and an untuned serve plan can't drift onto different chunk shapes.
    # (The ssm family rounds the resolved width UP to a multiple of the
    # model's `ssm_chunk` so chunk boundaries split the SSD scan exactly
    # on block boundaries — see `family.SSMFamilyAdapter`.)
    chunk_tokens: Optional[int] = DEFAULT_CHUNK_TOKENS
    # prompt segments one step's chunk may pack.  Greedy fill means a step
    # carries min(chunk_segments, prefilling requests) segments; 1 restores
    # the single-request chunk lane.  A tuned plan may narrow this via its
    # prefill_chunk stage's `max_segments` choice (old Pallas plans, tuned
    # before the segmented kernel, narrow it to 1 — see
    # PlanRouter.chunk_segments); the narrowed value is the segmented
    # kernel's compiled descriptor height, so the tuned knob sizes the
    # block_q x max-segments grid itself.
    chunk_segments: int = 4
    # state-slot pool rows for the slot-pooled (ssm) family, INCLUDING the
    # reserved null row.  None = max_slots + 1 (every slot can hold state
    # simultaneously — no state-pool preemption).  Smaller pools force the
    # ordinary grow-or-preempt path at first-chunk claim time.  Ignored by
    # the paged family.
    state_slots: Optional[int] = None
    # prefix sharing (paged family only): admission may map a prompt's
    # full-block prefixes onto blocks other requests already committed
    # (refcounted, copy-on-write) and start prefill at the first unshared
    # token.  Off by default — token streams are byte-identical either
    # way; the differential suite toggles it explicitly.
    prefix_sharing: bool = False
    interpret: bool = True            # False: compile Pallas lanes on real TPU

    @property
    def max_seq(self) -> int:
        return self.block_size * self.max_blocks_per_seq

    @property
    def chunk_width(self) -> int:
        """The prefill lane's RESOLVED width: chunk_tokens clamped to
        [1, max_seq], with None meaning max_seq.  Pass THIS to
        `build_serve_plan(chunk_tokens=...)` so the plan's prefill_chunk
        stage is tuned at the width the engine actually runs."""
        return max(1, min(self.chunk_tokens or self.max_seq, self.max_seq))

    def kv_config(self) -> KVCacheConfig:
        nb = self.num_blocks
        if nb is None:
            nb = self.max_slots * self.max_blocks_per_seq + 1
        return KVCacheConfig(num_blocks=nb, block_size=self.block_size,
                             max_blocks_per_seq=self.max_blocks_per_seq,
                             prefix_sharing=self.prefix_sharing)

    def state_config(self) -> StateCacheConfig:
        ns = self.state_slots
        if ns is None:
            ns = self.max_slots + 1
        return StateCacheConfig(num_slots=ns)


class ContinuousEngine:
    """Slot-based continuous-batching engine over a family state cache."""

    # family-owned attributes tests and tools read off the engine; resolved
    # through the adapter so the seam stays invisible to existing callers
    _ADAPTER_ATTRS = ("_unified", "_decode_only", "_commit", "_cow", "cache",
                      "kv_cfg")

    def __init__(self, model, params, mesh, rules: ShardingRules,
                 cfg: RuntimeConfig, router: Optional[PlanRouter] = None,
                 now_fn: Optional[Callable[[], float]] = None,
                 trace: Optional[TraceRecorder] = None):
        self.model = model
        self.mesh = mesh
        self.cfg = cfg
        self.router = router or PlanRouter(None)
        self.now_fn = now_fn or time.perf_counter
        # the mesh tag stamped on step trace events and run metadata:
        # "<data>x<model>" ("1x1" on a single device) — the traceview audit
        # one-checks it the way it one-checks the family tag
        self.mesh_tag = "{}x{}".format(mesh.shape.get("data", 1),
                                       mesh.shape.get("model", 1))
        # structured event tracing (`repro.serve.trace`): the recorder is
        # threaded through the scheduler and the family's allocator so
        # every lifecycle / pool / step event lands in ONE stream on the
        # ENGINE clock.  Disabled (the default) it is the no-op recorder —
        # one attribute lookup per emission site, per-token hot loops guard
        # on `trace.enabled` and skip even that.
        self.trace = trace if trace is not None else NULL_RECORDER
        if self.trace.enabled and self.trace.now_fn is None:
            self.trace.now_fn = self.now_fn
        # the family seam: raises TypeError for families with neither a
        # paged nor a slot-pooled serving path.  Before the adapter builds
        # its step programs, the router folds the plan's per-stage layout
        # verdicts (and the mesh's divisibility guards) into the rules —
        # on a single-device mesh this returns `rules` untouched, so the
        # tuned layout table reaches the step builders exactly when a
        # model axis exists to shard over.
        adapter_cls = resolve_family_adapter(model)
        self.rules = self.router.serve_rules(rules, mesh, model.cfg,
                                             adapter_cls.family)
        # commit the params onto THIS mesh in the step programs' own layout
        # before any program runs: params trained (or loaded) on a
        # different mesh reshard once here, and the first step sees exactly
        # the in_shardings it compiled for — admission compiles nothing,
        # and no layout-shifted second executable can ever build
        self.params = jax.device_put(
            params, params_shardings(mesh, prune_for_mesh(self.rules, mesh),
                                     model.logical_axes()))
        self.adapter = adapter_cls(model, mesh, self.rules, cfg, self.router)
        self.family = self.adapter.family
        self.adapter.alloc.trace = self.trace
        self.scheduler = ContinuousScheduler(
            cfg.max_slots, trace=self.trace,
            capacity=self.adapter.capacity())
        self.scheduler.family = self.family
        self.metrics = ServeMetrics(family=self.family)
        self._rid = 0
        self._step_idx = 0
        self._done: List[ServeRequest] = []
        # the adapter's resolved prefill-lane geometry (see family.py)
        self._chunk_width = self.adapter.chunk_width
        self._chunk_segments = self.adapter.chunk_segments
        # per-slot host state (decode lane; prefilling slots stay zeroed so
        # their dummy decode row writes to the null sink)
        self._lengths = np.zeros((cfg.max_slots,), np.int32)
        self._last_tok = np.zeros((cfg.max_slots,), np.int32)

    def __getattr__(self, name):
        # family-owned state (compiled programs, cache, kv config) lives on
        # the adapter; keep the engine's historical attribute surface
        if name in type(self)._ADAPTER_ATTRS:
            return getattr(self.adapter, name)
        raise AttributeError(
            f"{type(self).__name__!r} object has no attribute {name!r}")

    # ------------------------------------------------------------ interface
    def submit(self, prompt: np.ndarray, max_new_tokens: Optional[int] = None,
               arrival_time: Optional[float] = None,
               sampling: Optional[SamplingParams] = None) -> int:
        self._rid += 1
        if max_new_tokens is None:
            max_new_tokens = self.cfg.max_new_tokens
        req = ServeRequest(
            rid=self._rid, prompt=np.asarray(prompt, np.int32),
            max_new_tokens=max_new_tokens,
            arrival_time=(arrival_time if arrival_time is not None
                          else self.now_fn()),
            sampling=sampling if sampling is not None else SamplingParams())
        self.scheduler.submit(req)
        return self._rid

    def run(self) -> List[ServeRequest]:
        """Step until every submitted request completes; returns them in
        completion order.  Idle steps (all slots empty, next arrival still
        in the future) back off briefly instead of spinning."""
        if self.metrics.start_time is None:
            self.metrics.start_time = self.now_fn()
        with self.mesh:
            while self.scheduler.has_work:
                if not self.step():
                    time.sleep(2e-4)
        self.metrics.end_time = self.now_fn()
        done, self._done = self._done, []
        return done

    def reset_metrics(self) -> None:
        """Fresh metrics (e.g. after a warm-up pass); compiled programs and
        cache state are kept."""
        self.metrics = ServeMetrics(family=self.family)

    # ------------------------------------------------- preemption / resume
    def _ensure_blocks(self, req: ServeRequest) -> None:
        """Grow req's state to cover its next decode write (position
        `lengths[slot]`), preempting victims while the pool is dry.  The
        submit-time guard (single-request worst case fits the pool) makes
        the loop terminate: once every other eligible request is evicted,
        req owns every allocated unit and growth cannot fail.  (Families
        with fixed-size state grow trivially — the adapter returns True.)"""
        need_rows = int(self._lengths[req.slot]) + 1
        while not self.adapter.grow_for_decode(req, need_rows):
            victim = self.scheduler.victim_for_preemption(
                exclude_rid=req.rid, eligible=self.adapter.victim_eligible)
            if victim is None:
                raise MemoryError(
                    f"request {req.rid} cannot grow to {need_rows} rows with "
                    "no victims left — submit() guard violated")
            self._preempt(victim)

    def _claim_chunk(self, req: ServeRequest, start: int, n: int) -> bool:
        """Cover a prompt chunk's dispatch footprint (the ssm family claims
        its state row lazily here; the paged family copy-on-writes any
        shared block the chunk's rows would land in), preempting capacity
        holders while the pool is dry.  False only when no eligible victim
        remains — the chunk then waits for a later step."""
        while not self.adapter.claim_chunk(req, start, n):
            victim = self.scheduler.victim_for_preemption(
                exclude_rid=req.rid, eligible=self.adapter.victim_eligible)
            if victim is None:
                return False
            self._preempt(victim)
        return True

    def _preempt(self, victim: ServeRequest) -> None:
        """Swap the victim's state out to host, free its capacity + slot,
        queue it for resume.  Works mid-prefill too: the committed chunks
        travel with the swap and `prefilled` marks where the prompt
        resumes."""
        slot = victim.slot
        nbytes = self.adapter.swap_out(victim.rid)
        self.scheduler.preempt(victim, self.now_fn())
        self._reset_slot(slot)
        self.metrics.record_preemption(nbytes)

    def _resume_all(self, reqs: List[ServeRequest]) -> None:
        """Swap re-admitted requests back in, segment-packed: up to
        `resume_segments` requests share ONE commit invocation, so a burst
        of K swap-ins costs ceil(K / resume_segments) program dispatches
        instead of K — the resume-path counterpart of chunk packing."""
        width = self.adapter.resume_segments
        for i in range(0, len(reqs), width):
            self._resume_group(reqs[i:i + width])

    def _resume_group(self, group: List[ServeRequest]) -> None:
        """One packed commit: scatter the group's host-side state back into
        their freshly claimed capacity (one fixed shape — see the adapters'
        `resume_commit`), then restore each slot's host state.  No forward
        pass — no token is recomputed; a mid-prefill request continues
        chunking from `prefilled`.  The batch's wall time is split evenly
        across the group for per-request swap-in accounting."""
        t0 = time.perf_counter()
        if self.trace.enabled:
            n_commit = self._commit._cache_size()
        nbytes = self.adapter.resume_commit(group)
        batch_s = time.perf_counter() - t0
        swap_in_s = batch_s / len(group)
        self.metrics.record_resume_commit(len(group))
        if self.trace.enabled and self._commit._cache_size() > n_commit:
            self.trace.emit("compile", program="commit", device_s=batch_s)
        for req, nb in zip(group, nbytes):
            if self.trace.enabled:
                self.trace.emit("swap_in", rid=req.rid, nbytes=nb)
                self.trace.emit("resume", rid=req.rid,
                                stall_s=req.last_stall_s,
                                swap_in_s=swap_in_s)
            self.metrics.record_resume(nb, req.last_stall_s,
                                       swap_in_s=swap_in_s)
            slot = req.slot
            if req.prefilling:
                # not in the decode batch yet: stay masked (zeroed) until
                # the remaining chunks commit the rest of the prompt
                self._reset_slot(slot)
            else:
                self._lengths[slot] = req.prompt_len + len(req.output) - 1
                self._last_tok[slot] = req.output[-1]

    def _reset_slot(self, slot: int) -> None:
        # stale lengths on a freed slot would index past the (all-null)
        # block table; zeroed state keeps every inactive slot's writes
        # pinned to the sink row.
        self._lengths[slot] = 0
        self._last_tok[slot] = 0

    def _finished(self, req: ServeRequest) -> bool:
        if len(req.output) >= req.max_new_tokens:
            return True
        # stop-at-first-eos ANYWHERE in the stream, the same rule
        # `truncate_at_eos` applies at retirement — not just when eos is
        # the latest token, so the two definitions cannot diverge
        return self.cfg.eos_id >= 0 and self.cfg.eos_id in req.output

    def _retire(self, req: ServeRequest, now: float) -> None:
        """Retire a finished request: truncate its stream at the first eos
        (the shared `truncate_at_eos` rule — so the finish event's digest
        and n_output describe the stream callers actually receive), then
        release the slot and record completion."""
        slot = req.slot
        req.output = truncate_at_eos(req.output, self.cfg.eos_id)
        self.scheduler.retire(req, now)
        self._reset_slot(slot)
        self._complete(req)

    def _complete(self, req: ServeRequest) -> None:
        self.metrics.record_completion(req.latency_s, len(req.output))
        self._done.append(req)

    # ----------------------------------------------------------- unified step
    def step(self) -> bool:
        """One engine step = one invocation of one of the family's TWO
        compiled step programs: admit (resumes swap back in; fresh arrivals
        just take a slot), pack the step's prefill chunk (token-budget
        accounting, greedy fill over up to `chunk_segments` requests), grow
        every *decoding* request's state to cover its next token and claim
        every packed segment's chunk footprint (preempting victims if the
        pool is dry), then dispatch either the unified program (packed
        chunk lane + decode lane) or — when no prompt work is pending —
        the decode-only fast path, which skips the idle chunk lane
        entirely.  Returns False when nothing ran."""
        now = self.now_fn()
        admitted = self.scheduler.admit(now)
        resuming = [r for r in admitted if self.adapter.is_swapped(r.rid)]
        if self.cfg.prefix_sharing:
            # fresh admissions run nothing; a non-zero `prefilled` on one
            # means admission adopted that many prompt tokens' KV from the
            # prefix index — work the chunk lane will never do
            rs = {r.rid for r in resuming}
            for req in admitted:
                if req.rid not in rs and req.prefilled > 0:
                    self.metrics.record_prefix_hit(req.prefilled)
        if resuming:
            self._resume_all(resuming)
        # fresh admissions run nothing here: their prompts stream
        # through the unified step's chunk lane, starting this step

        chunks = self.scheduler.next_chunks(self._chunk_width,
                                            self._chunk_segments)

        # on-demand growth for the decode batch: every decoding request
        # secures the unit its next write lands in.  A request preempted
        # as some later grower's victim drops out of this step (slot is
        # None by then) — including, possibly, any of the packed segments'
        # requests.
        for req in [r for r in self.scheduler.slots
                    if r is not None and not r.prefilling]:
            if req.slot is not None:
                self._ensure_blocks(req)
        # chunk-claim: each packed segment's request must hold its family
        # footprint before dispatch (ssm: lazy state-row claim; paged:
        # copy-on-write any shared block under the chunk's rows — the
        # prompt's blocks themselves were allocated at admission)
        chunks = [ch for ch in chunks
                  if ch[0].slot is not None and self._claim_chunk(*ch)]
        chunks = [ch for ch in chunks if ch[0].slot is not None]

        decoding = [r for r in self.scheduler.slots
                    if r is not None and not r.prefilling]
        if not decoding and not chunks:
            return bool(admitted)

        # decode lane inputs: prefilling slots are masked exactly like empty
        # ones (null index, zero length) — their dummy row writes to the sink
        dec_rids = [r.rid if (r is not None and not r.prefilling) else None
                    for r in self.scheduler.slots]

        trace = self.trace
        kind = "unified" if chunks else "decode_only"
        step_idx = self._step_idx
        self._step_idx += 1
        if trace.enabled:
            for req, start, n in chunks:
                trace.emit("chunk_scheduled", t=now, rid=req.rid,
                           start=start, n=n)
            trace.emit("step_begin", t=now, step=step_idx, kind=kind,
                       family=self.family, mesh=self.mesh_tag,
                       lane_width=self._chunk_width if chunks else 0,
                       segments=len(chunks),
                       chunk_tokens=sum(n for _, _, n in chunks),
                       decode_rows=len(decoding))
            prog = self._unified if chunks else self._decode_only
            n_compiled = prog._cache_size()

        # per-slot sampling knobs + PRNG key triples for the decode lane,
        # rebuilt each step from slot residency (pure data — the arrays are
        # traced inputs, so per-request sampling never retraces a program)
        dec_sampling, dec_keys = slot_sampling_arrays(self.scheduler.slots)

        t0 = time.perf_counter()
        nxt, seg_next = self.adapter.dispatch(
            self.params, dec_rids, self._lengths, self._last_tok, chunks,
            dec_sampling, dec_keys)
        step_s = time.perf_counter() - t0
        if trace.enabled and prog._cache_size() > n_compiled:
            trace.emit("compile", program=kind, device_s=step_s)
        # attribute chunk-only steps to prefill time, everything else to
        # decode time
        if decoding:
            self.metrics.decode_time_s += step_s
        else:
            self.metrics.prefill_time_s += step_s

        now = self.now_fn()
        if trace.enabled:
            trace.emit("step_end", t=now, step=step_idx, kind=kind,
                       family=self.family, mesh=self.mesh_tag,
                       lane_width=self._chunk_width if chunks else 0,
                       segments=len(chunks),
                       chunk_tokens=sum(n for _, _, n in chunks),
                       decode_rows=len(decoding), device_s=step_s)
        if chunks:
            self.metrics.record_chunk_step([n for _, _, n in chunks],
                                           self._chunk_width)
            for i, (req, start, n) in enumerate(chunks):
                req.prefilled = start + n
                # the chunk's KV is committed and final: index the prompt's
                # covered full-block prefixes for later admissions to adopt
                self.adapter.register_prefix(req)
                if trace.enabled:
                    trace.emit("chunk_committed", t=now, rid=req.rid,
                               start=start, n=n, prefilled=req.prefilled)
                if not req.prefilling:        # this chunk finished the prompt
                    first = int(seg_next[i])
                    req.output.append(first)
                    req.first_token_time = now
                    trace.emit("first_token", t=now, rid=req.rid, token=first)
                    self.metrics.record_first_token(now - req.arrival_time)
                    self.metrics.prefills += 1
                    slot = req.slot
                    self._lengths[slot] = req.prompt_len
                    self._last_tok[slot] = first
                    if self._finished(req):
                        self._retire(req, now)
        elif decoding:
            self.metrics.record_decode_only_step()

        if decoding:
            self.metrics.record_step(len(decoding), self.cfg.max_slots,
                                     self.adapter.occupancy())
            emit_tokens = trace.enabled
            for req in decoding:
                slot = req.slot
                req.output.append(int(nxt[slot]))
                self._lengths[slot] += 1
                self._last_tok[slot] = nxt[slot]
                if emit_tokens:
                    trace.emit("decode_token", t=now, rid=req.rid,
                               token=int(nxt[slot]))
                if self._finished(req):
                    self._retire(req, now)
        # copy-on-write copies performed while growing/claiming this step
        # (the allocator counts them; state-row allocators have none)
        drain = getattr(self.adapter.alloc, "drain_cow_copies", None)
        if drain is not None:
            copied = drain()
            if copied:
                self.metrics.record_cow(copied)
        return True
