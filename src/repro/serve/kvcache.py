"""Paged KV-cache manager for the continuous-batching runtime.

The monolithic `(B, max_seq, Hkv, hd)` cache of the fixed-batch engine wastes
HBM proportional to (longest sequence x batch): a 12-token request in a slot
sized for 4k tokens pins 4k rows.  Here the cache is a pool of fixed-size
*blocks* (`block_size` token rows each); a request owns a chain of physical
block ids (its *block table*) and blocks return to the free list the moment
the request completes — the vLLM PagedAttention layout, sized for the paper's
serve path.

Two layers of responsibility:

  * `BlockAllocator` — pure host-side bookkeeping: free-list, per-request
    block tables, alloc/free invariants.  Physical block 0 is reserved as the
    *null sink*: slot-table entries of inactive slots and padding positions
    point at it, so device-side scatters never need a mask branch.
  * `PagedKVCache`  — the device tensors: `k`/`v` pools shaped
    `(n_layers, num_blocks, block_size, n_kv_heads, hd)` plus helpers to
    build the dense `(max_slots, blocks_per_seq)` block-table array the
    jitted decode step consumes.  Shapes are static in the number of slots
    and pool blocks, so admission NEVER triggers recompilation.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

NULL_BLOCK = 0  # reserved sink block — never allocated to a request


@dataclasses.dataclass(frozen=True)
class KVCacheConfig:
    num_blocks: int = 64          # physical pool size (incl. the null block)
    block_size: int = 16          # token rows per block
    max_blocks_per_seq: int = 16  # bounds the per-slot block table width

    @property
    def max_seq(self) -> int:
        return self.block_size * self.max_blocks_per_seq

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)


class BlockAllocator:
    """Free-list allocation of physical blocks with per-request block tables."""

    def __init__(self, cfg: KVCacheConfig):
        if cfg.num_blocks < 2:
            raise ValueError("need at least 2 blocks (one is the null sink)")
        self.cfg = cfg
        # block 0 reserved as the null sink
        self._free: List[int] = list(range(cfg.num_blocks - 1, NULL_BLOCK, -1))
        self.tables: Dict[int, List[int]] = {}

    # ------------------------------------------------------------ queries
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return (self.cfg.num_blocks - 1) - len(self._free)

    def occupancy(self) -> float:
        usable = self.cfg.num_blocks - 1
        return self.num_used / usable if usable else 0.0

    def can_allocate(self, n_blocks: int) -> bool:
        return n_blocks <= len(self._free)

    # -------------------------------------------------------- alloc / free
    def allocate(self, rid: int, n_blocks: int) -> List[int]:
        """Claim `n_blocks` physical blocks for request `rid`."""
        if rid in self.tables:
            raise ValueError(f"request {rid} already holds blocks")
        if not self.can_allocate(n_blocks):
            raise MemoryError(
                f"KV pool exhausted: want {n_blocks}, free {len(self._free)}")
        blocks = [self._free.pop() for _ in range(n_blocks)]
        self.tables[rid] = blocks
        return blocks

    def extend(self, rid: int, n_tokens_total: int) -> bool:
        """Grow rid's table to cover `n_tokens_total`; False if pool is dry."""
        table = self.tables[rid]
        need = self.cfg.blocks_for(n_tokens_total) - len(table)
        if need <= 0:
            return True
        if need > len(self._free):
            return False
        for _ in range(need):
            table.append(self._free.pop())
        return True

    def free(self, rid: int) -> int:
        """Return all of rid's blocks to the free list."""
        blocks = self.tables.pop(rid)
        self._free.extend(reversed(blocks))
        return len(blocks)

    def check_invariants(self) -> None:
        """Every block is either free or owned by exactly one request."""
        owned = [b for t in self.tables.values() for b in t]
        assert NULL_BLOCK not in owned, "null block leaked into a table"
        assert NULL_BLOCK not in self._free, "null block leaked into free list"
        combined = sorted(owned + self._free)
        assert combined == list(range(1, self.cfg.num_blocks)), (
            f"block accounting broken: {combined}")
        assert len(set(owned)) == len(owned), "block double-owned"


class PagedKVCache:
    """Device-side paged K/V pools plus the allocator."""

    def __init__(self, cfg: KVCacheConfig, n_layers: int, n_kv_heads: int,
                 head_dim: int, dtype=jnp.bfloat16):
        self.cfg = cfg
        self.alloc = BlockAllocator(cfg)
        shape = (n_layers, cfg.num_blocks, cfg.block_size, n_kv_heads, head_dim)
        self.k = jnp.zeros(shape, dtype)
        self.v = jnp.zeros(shape, dtype)

    def table_array(self, slot_rids: List[Optional[int]]) -> np.ndarray:
        """Dense (max_slots, max_blocks_per_seq) int32 block-table array for
        the jitted decode step; unused entries point at the null sink."""
        out = np.full((len(slot_rids), self.cfg.max_blocks_per_seq),
                      NULL_BLOCK, np.int32)
        for s, rid in enumerate(slot_rids):
            if rid is None:
                continue
            table = self.alloc.tables[rid]
            out[s, : len(table)] = table
        return out
