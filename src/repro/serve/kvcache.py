"""Paged KV-cache manager for the continuous-batching runtime.

The monolithic `(B, max_seq, Hkv, hd)` cache of the fixed-batch engine wastes
HBM proportional to (longest sequence x batch): a 12-token request in a slot
sized for 4k tokens pins 4k rows.  Here the cache is a pool of fixed-size
*blocks* (`block_size` token rows each); a request owns a chain of physical
block ids (its *block table*) and blocks return to the free list the moment
the request completes — the vLLM PagedAttention layout, sized for the paper's
serve path.

Two layers of responsibility:

  * `BlockAllocator` — pure host-side bookkeeping: free-list, per-request
    block tables, alloc/free invariants.  Physical block 0 is reserved as the
    *null sink*: slot-table entries of inactive slots and padding positions
    point at it, so device-side scatters never need a mask branch.  A request
    can be *swapped out* (its blocks return to the pool while the allocator
    remembers how many it held) and later *swapped in* (fresh blocks of the
    same count, possibly different physical ids — the block table is the only
    indirection, so ids are free to change across a swap).
  * `PagedKVCache`  — the device tensors: `k`/`v` pools shaped
    `(n_layers, num_blocks, block_size, n_kv_heads, hd)` plus helpers to
    build the dense `(max_slots, blocks_per_seq)` block-table array the
    jitted decode step consumes.  Shapes are static in the number of slots
    and pool blocks, so admission NEVER triggers recompilation.

PREFIX SHARING (`KVCacheConfig.prefix_sharing`).  Blocks are REFCOUNTED: a
block may appear in many tables at once, `free` only returns it to the free
list when the last owner lets go.  A *prefix index* keys each registered
block on the exact token string `tokens[0 : (k+1) * block_size]` whose KV it
holds — position-dependent (RoPE) KV means the key must be the whole prefix,
not the block's own tokens.  Admission matches a new prompt's full-block
prefixes against the index and *adopts* the hits (refcount + 1), so a hot
system prompt is prefilled once, ever.  Freed registered blocks stay in the
index while they sit on the free list (inserted at the FRONT, so unregistered
blocks are reused first) and are *revived* on a later match; physically
reallocating a registered block invalidates its index entry.  A write landing
in a block with refcount > 1 must COPY-ON-WRITE first (`cow`): the writer
swaps a fresh private block into its own table and the device copies the
rows across — other owners keep reading the original.  Everything here is
host bookkeeping; the device copy is the caller's (`jit_cow_block`).
"""

from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.serve.trace import NULL_RECORDER

NULL_BLOCK = 0  # reserved sink block — never allocated to a request


@dataclasses.dataclass(frozen=True)
class KVCacheConfig:
    num_blocks: int = 64          # physical pool size (incl. the null block)
    block_size: int = 16          # token rows per block
    max_blocks_per_seq: int = 16  # bounds the per-slot block table width
    # prefix sharing: admission may map a prompt's full-block prefixes onto
    # blocks other requests already committed (refcount + copy-on-write).
    # Off by default: sharing is a scheduling optimization the byte-identity
    # differentials toggle explicitly.
    prefix_sharing: bool = False

    @property
    def max_seq(self) -> int:
        return self.block_size * self.max_blocks_per_seq

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)


def _prefix_key(tokens: np.ndarray, n_tokens: int) -> bytes:
    """Index key of the block covering tokens [n_tokens - block_size,
    n_tokens): the EXACT byte string of the whole prefix.  KV rows are a
    function of every token before them (positions, attention), so two
    blocks are interchangeable iff their full prefixes match."""
    return np.ascontiguousarray(tokens[:n_tokens], np.int32).tobytes()


class BlockAllocator:
    """Free-list allocation of physical blocks with per-request block tables,
    block refcounts and a token-keyed prefix index (copy-on-write sharing)."""

    def __init__(self, cfg: KVCacheConfig):
        if cfg.num_blocks < 2:
            raise ValueError("need at least 2 blocks (one is the null sink)")
        self.cfg = cfg
        # block 0 reserved as the null sink.  The free list doubles as the
        # prefix-cache eviction queue: `_pop_free` takes from the TAIL, and
        # `free` returns registered blocks to the FRONT, so cached KV
        # survives on the free list until the pool actually needs the block.
        self._free: List[int] = list(range(cfg.num_blocks - 1, NULL_BLOCK, -1))
        self.tables: Dict[int, List[int]] = {}
        # owners per block: a block is in `refcount` iff some table holds it,
        # with the value equal to the number of tables containing it
        self.refcount: Dict[int, int] = {}
        # prefix index: full-prefix key -> block id, plus the reverse map so
        # reallocating a block can invalidate its entry in O(1)
        self._index: Dict[bytes, int] = {}
        self._block_key: Dict[int, bytes] = {}
        # rid -> block count held at swap-out (no physical blocks owned)
        self.swapped: Dict[int, int] = {}
        # CoW copies since the engine last drained the counter (metrics)
        self._cow_copies = 0
        # structured event recorder (`repro.serve.trace`); the serving
        # engine rebinds it, the default no-op has near-zero cost and every
        # accounting event carries `free_after` so a trace audit can replay
        # pool conservation event by event
        self.trace = NULL_RECORDER

    # ------------------------------------------------------------ queries
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return (self.cfg.num_blocks - 1) - len(self._free)

    def occupancy(self) -> float:
        usable = self.cfg.num_blocks - 1
        return self.num_used / usable if usable else 0.0

    def can_allocate(self, n_blocks: int) -> bool:
        return n_blocks <= len(self._free)

    def drain_cow_copies(self) -> int:
        """Copy-on-write copies performed since the last drain (metrics)."""
        n, self._cow_copies = self._cow_copies, 0
        return n

    # -------------------------------------------------------- alloc / free
    def _pop_free(self) -> int:
        """Take one block off the free list for a FRESH allocation.  Popping
        a registered block is the prefix cache's eviction: its index entry
        dies here, before the block is rewritten."""
        b = self._free.pop()
        key = self._block_key.pop(b, None)
        if key is not None:
            del self._index[key]
        return b

    def allocate(self, rid: int, n_blocks: int) -> List[int]:
        """Claim `n_blocks` physical blocks for request `rid`."""
        if rid in self.tables:
            raise ValueError(f"request {rid} already holds blocks")
        if rid in self.swapped:
            raise ValueError(f"request {rid} is swapped out; use swap_in")
        if not self.can_allocate(n_blocks):
            raise MemoryError(
                f"KV pool exhausted: want {n_blocks}, free {len(self._free)}")
        blocks = [self._pop_free() for _ in range(n_blocks)]
        for b in blocks:
            self.refcount[b] = 1
        self.tables[rid] = blocks
        self.trace.emit("block_alloc", rid=rid, n=n_blocks,
                        free_after=len(self._free))
        return blocks

    def extend(self, rid: int, n_tokens_total: int) -> bool:
        """Grow rid's table to cover `n_tokens_total`; False if the pool is
        dry OR the request would exceed its table bound
        (`max_blocks_per_seq` — the dense `table_array` row width; growing
        past it would silently corrupt the dispatch-side scatter)."""
        if rid in self.swapped:
            raise ValueError(
                f"request {rid} is swapped out; swap_in before extending")
        table = self.tables[rid]
        target = self.cfg.blocks_for(n_tokens_total)
        if target > self.cfg.max_blocks_per_seq:
            return False
        need = target - len(table)
        if need <= 0:
            return True
        if need > len(self._free):
            return False
        for _ in range(need):
            b = self._pop_free()
            self.refcount[b] = 1
            table.append(b)
        self.trace.emit("block_extend", rid=rid, n=need,
                        free_after=len(self._free))
        return True

    def free(self, rid: int) -> int:
        """Drop rid's ownership of its blocks; a block returns to the free
        list only when its refcount hits zero (`released` on the event).
        Registered (prefix-indexed) blocks go to the FRONT of the free list
        — still matchable, evicted only after every unregistered block."""
        blocks = self.tables.pop(rid)
        released = 0
        for b in reversed(blocks):
            self.refcount[b] -= 1
            if self.refcount[b] == 0:
                del self.refcount[b]
                released += 1
                if b in self._block_key:
                    self._free.insert(0, b)
                else:
                    self._free.append(b)
        self.trace.emit("block_free", rid=rid, n=len(blocks),
                        released=released, free_after=len(self._free))
        return len(blocks)

    # ------------------------------------------------- prefix sharing / CoW
    def match_prefix(self, tokens: np.ndarray) -> List[int]:
        """The longest chain of indexed blocks covering `tokens`' full-block
        prefixes: block k matches iff the index holds the exact prefix
        tokens[0:(k+1)*block_size].  Walks in order and stops at the first
        miss (a later hit without its predecessors is unusable — the KV of
        block k embeds the whole prefix before it)."""
        if not self.cfg.prefix_sharing or not self._index:
            return []
        tokens = np.asarray(tokens, np.int32)
        bs = self.cfg.block_size
        matched: List[int] = []
        for k in range(len(tokens) // bs):
            b = self._index.get(_prefix_key(tokens, (k + 1) * bs))
            if b is None:
                break
            matched.append(b)
        return matched

    def share(self, rid: int, blocks: List[int]) -> None:
        """Adopt `blocks` (a `match_prefix` result) as the head of rid's
        table: live blocks gain an owner (refcount + 1); refcount-0 blocks
        still sitting on the free list are REVIVED (removed from the free
        list, refcount 1) — their KV was never overwritten, so the cached
        prefix outlives its original owner."""
        if rid in self.tables:
            raise ValueError(f"request {rid} already holds blocks")
        if rid in self.swapped:
            raise ValueError(f"request {rid} is swapped out; use swap_in")
        revived = 0
        for b in blocks:
            if b in self.refcount:
                self.refcount[b] += 1
            else:
                self._free.remove(b)
                self.refcount[b] = 1
                revived += 1
        self.tables[rid] = list(blocks)
        self.trace.emit("block_share", rid=rid, n=len(blocks),
                        revived=revived, free_after=len(self._free))

    def register_prefix(self, rid: int, tokens: np.ndarray,
                        n_tokens: int) -> None:
        """Index rid's blocks covering `tokens`' committed full-block
        prefixes (`n_tokens` of them are committed).  First registration
        wins: if a key is already indexed — a concurrent identical prompt
        that could not match at admission — the existing entry stands."""
        if not self.cfg.prefix_sharing:
            return
        tokens = np.asarray(tokens, np.int32)
        bs = self.cfg.block_size
        table = self.tables[rid]
        upto = min(int(n_tokens), len(tokens)) // bs
        for k in range(upto):
            b = table[k]
            if b in self._block_key:
                continue        # already indexed (adopted shared block)
            key = _prefix_key(tokens, (k + 1) * bs)
            if key in self._index:
                continue        # first registration wins
            self._index[key] = b
            self._block_key[b] = key

    def cow(self, rid: int, block_index: int) -> Optional[Tuple[int, int]]:
        """Copy-on-write rid's table entry `block_index` if it is shared:
        returns (src, dst) block ids for the caller's device copy, or None
        when the block is private (no copy needed).  Raises MemoryError on
        a dry pool — the engine preempts a victim and retries, exactly like
        `extend`.  The old block keeps its other owners (refcount >= 1
        afterwards), so a CoW never releases anything."""
        table = self.tables[rid]
        src = table[block_index]
        if self.refcount[src] <= 1:
            return None
        if not self._free:
            raise MemoryError(
                f"KV pool exhausted for copy-on-write (rid {rid})")
        dst = self._pop_free()
        self.refcount[src] -= 1
        self.refcount[dst] = 1
        table[block_index] = dst
        self._cow_copies += 1
        self.trace.emit("cow_copy", rid=rid, n=1,
                        free_after=len(self._free))
        return src, dst

    # ------------------------------------------------------------- swapping
    def swap_out(self, rid: int) -> int:
        """Release rid's physical blocks while remembering how many it held;
        returns the block count.  The caller is responsible for saving the
        block *contents* first (see `PagedKVCache.swap_out`)."""
        if rid in self.swapped:
            raise ValueError(f"request {rid} already swapped out")
        n = self.free(rid)
        self.swapped[rid] = n
        return n

    def swap_in(self, rid: int) -> List[int]:
        """Re-claim as many blocks as rid held at swap-out (fresh physical
        ids); raises MemoryError if the pool cannot cover them."""
        n = self.swapped[rid]
        if not self.can_allocate(n):
            raise MemoryError(
                f"KV pool exhausted on swap-in: want {n}, free "
                f"{len(self._free)}")
        del self.swapped[rid]
        return self.allocate(rid, n)

    def check_invariants(self) -> None:
        """Every block is free xor owned; an owned block's refcount equals
        the number of tables containing it; the prefix index is a bijection
        over blocks that still physically exist."""
        owned = Counter(b for t in self.tables.values() for b in t)
        assert NULL_BLOCK not in owned, "null block leaked into a table"
        assert NULL_BLOCK not in self._free, "null block leaked into free list"
        assert len(set(self._free)) == len(self._free), "free list duplicate"
        assert not (set(self._free) & set(owned)), "block both free and owned"
        assert sorted(set(self._free) | set(owned)) == \
            list(range(1, self.cfg.num_blocks)), (
                f"block accounting broken: free={sorted(self._free)} "
                f"owned={sorted(owned)}")
        assert self.refcount == dict(owned), (
            f"refcounts {self.refcount} != table occurrences {dict(owned)}")
        for rid, t in self.tables.items():
            assert len(set(t)) == len(t), f"table {rid} repeats a block"
        for key, b in self._index.items():
            assert self._block_key.get(b) == key, "index/reverse-map skew"
        for b, key in self._block_key.items():
            assert self._index.get(key) == b, "reverse-map/index skew"
            assert b in owned or b in self._free, (
                f"indexed block {b} neither owned nor free")
        assert not (set(self.swapped) & set(self.tables)), (
            "request both active and swapped out")
        assert all(n >= 0 for n in self.swapped.values())


class PagedKVCache:
    """Device-side paged K/V pools plus the allocator."""

    def __init__(self, cfg: KVCacheConfig, n_layers: int, n_kv_heads: int,
                 head_dim: int, dtype=jnp.bfloat16, sharding=None):
        self.cfg = cfg
        self.alloc = BlockAllocator(cfg)
        shape = (n_layers, cfg.num_blocks, cfg.block_size, n_kv_heads, head_dim)
        # `sharding` (a NamedSharding) creates the pools DIRECTLY in their
        # serving layout — blocks replicated, kv_heads over the model axis
        # — so the donated pool arguments carry the same sharding on the
        # first step as on every later one and exactly one executable per
        # program ever builds (no layout-shifting device_put afterwards).
        if sharding is not None:
            self.k = jnp.zeros(shape, dtype, device=sharding)
            self.v = jnp.zeros(shape, dtype, device=sharding)
        else:
            self.k = jnp.zeros(shape, dtype)
            self.v = jnp.zeros(shape, dtype)
        # rid -> (k_host, v_host) of shape (L, n_blocks, bs, Hkv, hd):
        # preempted requests' KV lives here, off-device, until swap-in
        self._swapped: Dict[int, tuple] = {}

    # ------------------------------------------------------------- swapping
    def is_swapped(self, rid: int) -> bool:
        return rid in self._swapped

    def swap_out(self, rid: int) -> int:
        """Copy rid's KV blocks to a host-side buffer and release the
        physical blocks; returns the bytes moved.  The request's KV survives
        preemption entirely off-device — a later `take_swapped` + commit
        scatters it back into (possibly different) physical blocks.  Shared
        blocks are saved too (their content is rid's prefix as much as
        anyone's); rid's ownership lapses but co-owners keep the originals
        live, so preempting a shared-block holder never disturbs them."""
        ids = jnp.asarray(self.alloc.tables[rid], jnp.int32)
        k_host = np.asarray(self.k[:, ids])
        v_host = np.asarray(self.v[:, ids])
        self._swapped[rid] = (k_host, v_host)
        nbytes = k_host.nbytes + v_host.nbytes
        self.alloc.trace.emit("swap_out", rid=rid, nbytes=nbytes,
                              n_blocks=len(self.alloc.tables[rid]))
        self.alloc.swap_out(rid)
        return nbytes

    def take_swapped(self, rid: int):
        """Pop rid's host-side (k, v) buffers for swap-in; the caller
        scatters them at the freshly allocated block table."""
        return self._swapped.pop(rid)

    def table_array(self, slot_rids: List[Optional[int]]) -> np.ndarray:
        """Dense (max_slots, max_blocks_per_seq) int32 block-table array for
        the jitted decode step; unused entries point at the null sink."""
        out = np.full((len(slot_rids), self.cfg.max_blocks_per_seq),
                      NULL_BLOCK, np.int32)
        for s, rid in enumerate(slot_rids):
            if rid is None:
                continue
            table = self.alloc.tables[rid]
            out[s, : len(table)] = table
        return out
