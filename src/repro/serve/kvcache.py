"""Paged KV-cache manager for the continuous-batching runtime.

The monolithic `(B, max_seq, Hkv, hd)` cache of the fixed-batch engine wastes
HBM proportional to (longest sequence x batch): a 12-token request in a slot
sized for 4k tokens pins 4k rows.  Here the cache is a pool of fixed-size
*blocks* (`block_size` token rows each); a request owns a chain of physical
block ids (its *block table*) and blocks return to the free list the moment
the request completes — the vLLM PagedAttention layout, sized for the paper's
serve path.

Two layers of responsibility:

  * `BlockAllocator` — pure host-side bookkeeping: free-list, per-request
    block tables, alloc/free invariants.  Physical block 0 is reserved as the
    *null sink*: slot-table entries of inactive slots and padding positions
    point at it, so device-side scatters never need a mask branch.  A request
    can be *swapped out* (its blocks return to the pool while the allocator
    remembers how many it held) and later *swapped in* (fresh blocks of the
    same count, possibly different physical ids — the block table is the only
    indirection, so ids are free to change across a swap).
  * `PagedKVCache`  — the device tensors: `k`/`v` pools shaped
    `(n_layers, num_blocks, block_size, n_kv_heads, hd)` plus helpers to
    build the dense `(max_slots, blocks_per_seq)` block-table array the
    jitted decode step consumes.  Shapes are static in the number of slots
    and pool blocks, so admission NEVER triggers recompilation.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.serve.trace import NULL_RECORDER

NULL_BLOCK = 0  # reserved sink block — never allocated to a request


@dataclasses.dataclass(frozen=True)
class KVCacheConfig:
    num_blocks: int = 64          # physical pool size (incl. the null block)
    block_size: int = 16          # token rows per block
    max_blocks_per_seq: int = 16  # bounds the per-slot block table width

    @property
    def max_seq(self) -> int:
        return self.block_size * self.max_blocks_per_seq

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)


class BlockAllocator:
    """Free-list allocation of physical blocks with per-request block tables."""

    def __init__(self, cfg: KVCacheConfig):
        if cfg.num_blocks < 2:
            raise ValueError("need at least 2 blocks (one is the null sink)")
        self.cfg = cfg
        # block 0 reserved as the null sink
        self._free: List[int] = list(range(cfg.num_blocks - 1, NULL_BLOCK, -1))
        self.tables: Dict[int, List[int]] = {}
        # rid -> block count held at swap-out (no physical blocks owned)
        self.swapped: Dict[int, int] = {}
        # structured event recorder (`repro.serve.trace`); the serving
        # engine rebinds it, the default no-op has near-zero cost and every
        # accounting event carries `free_after` so a trace audit can replay
        # pool conservation event by event
        self.trace = NULL_RECORDER

    # ------------------------------------------------------------ queries
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return (self.cfg.num_blocks - 1) - len(self._free)

    def occupancy(self) -> float:
        usable = self.cfg.num_blocks - 1
        return self.num_used / usable if usable else 0.0

    def can_allocate(self, n_blocks: int) -> bool:
        return n_blocks <= len(self._free)

    # -------------------------------------------------------- alloc / free
    def allocate(self, rid: int, n_blocks: int) -> List[int]:
        """Claim `n_blocks` physical blocks for request `rid`."""
        if rid in self.tables:
            raise ValueError(f"request {rid} already holds blocks")
        if rid in self.swapped:
            raise ValueError(f"request {rid} is swapped out; use swap_in")
        if not self.can_allocate(n_blocks):
            raise MemoryError(
                f"KV pool exhausted: want {n_blocks}, free {len(self._free)}")
        blocks = [self._free.pop() for _ in range(n_blocks)]
        self.tables[rid] = blocks
        self.trace.emit("block_alloc", rid=rid, n=n_blocks,
                        free_after=len(self._free))
        return blocks

    def extend(self, rid: int, n_tokens_total: int) -> bool:
        """Grow rid's table to cover `n_tokens_total`; False if pool is dry."""
        table = self.tables[rid]
        need = self.cfg.blocks_for(n_tokens_total) - len(table)
        if need <= 0:
            return True
        if need > len(self._free):
            return False
        for _ in range(need):
            table.append(self._free.pop())
        self.trace.emit("block_extend", rid=rid, n=need,
                        free_after=len(self._free))
        return True

    def free(self, rid: int) -> int:
        """Return all of rid's blocks to the free list."""
        blocks = self.tables.pop(rid)
        self._free.extend(reversed(blocks))
        self.trace.emit("block_free", rid=rid, n=len(blocks),
                        free_after=len(self._free))
        return len(blocks)

    # ------------------------------------------------------------- swapping
    def swap_out(self, rid: int) -> int:
        """Release rid's physical blocks while remembering how many it held;
        returns the block count.  The caller is responsible for saving the
        block *contents* first (see `PagedKVCache.swap_out`)."""
        if rid in self.swapped:
            raise ValueError(f"request {rid} already swapped out")
        n = self.free(rid)
        self.swapped[rid] = n
        return n

    def swap_in(self, rid: int) -> List[int]:
        """Re-claim as many blocks as rid held at swap-out (fresh physical
        ids); raises MemoryError if the pool cannot cover them."""
        n = self.swapped[rid]
        if not self.can_allocate(n):
            raise MemoryError(
                f"KV pool exhausted on swap-in: want {n}, free "
                f"{len(self._free)}")
        del self.swapped[rid]
        return self.allocate(rid, n)

    def check_invariants(self) -> None:
        """Every block is either free or owned by exactly one request."""
        owned = [b for t in self.tables.values() for b in t]
        assert NULL_BLOCK not in owned, "null block leaked into a table"
        assert NULL_BLOCK not in self._free, "null block leaked into free list"
        combined = sorted(owned + self._free)
        assert combined == list(range(1, self.cfg.num_blocks)), (
            f"block accounting broken: {combined}")
        assert len(set(owned)) == len(owned), "block double-owned"
        assert not (set(self.swapped) & set(self.tables)), (
            "request both active and swapped out")
        assert all(n >= 0 for n in self.swapped.values())


class PagedKVCache:
    """Device-side paged K/V pools plus the allocator."""

    def __init__(self, cfg: KVCacheConfig, n_layers: int, n_kv_heads: int,
                 head_dim: int, dtype=jnp.bfloat16):
        self.cfg = cfg
        self.alloc = BlockAllocator(cfg)
        shape = (n_layers, cfg.num_blocks, cfg.block_size, n_kv_heads, head_dim)
        self.k = jnp.zeros(shape, dtype)
        self.v = jnp.zeros(shape, dtype)
        # rid -> (k_host, v_host) of shape (L, n_blocks, bs, Hkv, hd):
        # preempted requests' KV lives here, off-device, until swap-in
        self._swapped: Dict[int, tuple] = {}

    # ------------------------------------------------------------- swapping
    def is_swapped(self, rid: int) -> bool:
        return rid in self._swapped

    def swap_out(self, rid: int) -> int:
        """Copy rid's KV blocks to a host-side buffer and release the
        physical blocks; returns the bytes moved.  The request's KV survives
        preemption entirely off-device — a later `take_swapped` + commit
        scatters it back into (possibly different) physical blocks."""
        ids = jnp.asarray(self.alloc.tables[rid], jnp.int32)
        k_host = np.asarray(self.k[:, ids])
        v_host = np.asarray(self.v[:, ids])
        self._swapped[rid] = (k_host, v_host)
        nbytes = k_host.nbytes + v_host.nbytes
        self.alloc.trace.emit("swap_out", rid=rid, nbytes=nbytes,
                              n_blocks=len(self.alloc.tables[rid]))
        self.alloc.swap_out(rid)
        return nbytes

    def take_swapped(self, rid: int):
        """Pop rid's host-side (k, v) buffers for swap-in; the caller
        scatters them at the freshly allocated block table."""
        return self._swapped.pop(rid)

    def table_array(self, slot_rids: List[Optional[int]]) -> np.ndarray:
        """Dense (max_slots, max_blocks_per_seq) int32 block-table array for
        the jitted decode step; unused entries point at the null sink."""
        out = np.full((len(slot_rids), self.cfg.max_blocks_per_seq),
                      NULL_BLOCK, np.int32)
        for s, rid in enumerate(slot_rids):
            if rid is None:
                continue
            table = self.alloc.tables[rid]
            out[s, : len(table)] = table
        return out
