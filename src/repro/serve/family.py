"""The engine/model-family seam: per-family adapters for continuous batching.

`ContinuousEngine` (runtime.py) is family-AGNOSTIC orchestration — admit,
schedule, grow-or-preempt, dispatch, retire — over an abstract notion of
"the family's per-request device state".  Everything that knows WHAT that
state is lives here, behind one adapter object per model family:

  * `DecoderFamilyAdapter` — the paged-KV family (attention decoders).
    Per-request state is a growing set of KV blocks: the adapter owns the
    `PagedKVCache`, the block-table bookkeeping, and the paged step
    programs (`jit_unified_step` / `jit_decode_only_step` /
    `jit_commit_prefill`).  This is a verbatim relocation of the logic the
    engine used to inline — same programs, same shapes, same call order —
    so carving the seam is a provable no-op: byte-identical token streams
    and the same two step executables.

  * `SSMFamilyAdapter` — the state-cache family (`zoo.MambaLM`).
    Per-request state is FIXED-SIZE (one depthwise-conv window plus one
    SSM state per layer), so the paged machinery collapses: the pool is a
    `SlotStateCache` grid of state rows, the "block table" degenerates to
    one traced row index per slot, growth is a no-op, and the footprint is
    claimed lazily when the request's first prompt chunk dispatches
    (`claim_chunk`) — which is how a state pool smaller than the slot
    count drives the engine's ordinary preemption path.

The adapter protocol (duck-typed; both classes implement it):

    family              str tag stamped on metrics and trace events
    chunk_width         the prefill lane's resolved token width
    chunk_segments      segments one chunk may pack (ssm: always 1)
    resume_segments     swap-ins one commit invocation may pack
    cache               the family's device-state container (swap buffers)
    alloc               its allocator (trace binding, occupancy, invariants)
    capacity()          the scheduler's capacity-seam object
    victim_eligible     predicate narrowing preemption victims (or None)
    grow_for_decode(req, need_rows) -> bool   cover the next decode write
    claim_chunk(req, start, n) -> bool        cover a prompt chunk dispatch
                        (decoder: copy-on-write any shared block the
                        chunk's rows [start, start+n) would land in)
    register_prefix(req)                      index req's committed
                        full-block prompt prefixes for later sharing
                        (ssm: no-op — state rows are not shareable)
    swap_out(rid) -> nbytes                   device state -> host buffer
    resume_commit(group) -> [nbytes, ...]     host buffers -> device state:
                        ONE commit invocation for up to resume_segments
                        re-admitted requests
    dispatch(params, dec_rids, lengths, last_tok, chunks,
             dec_sampling, dec_keys)
                        -> (next_tokens (slots,), seg_next | None)

The engine hands `dispatch` the decode lane's per-slot sampling/key arrays
(built by `repro.serve.sampling.slot_sampling_arrays`); each adapter packs
the chunk lane's per-segment arrays itself (token index 0 — a segment's
sample is the request's FIRST token) and threads both into its step
programs as traced data.

The engine reads `_unified` / `_decode_only` / `_commit` off the adapter
for compile-count accounting (each is a jitted program whose
`_cache_size()` pins the exactly-two-executables property).
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import ShardingRules, prune_for_mesh
from repro.launch.steps import (
    jit_commit_prefill,
    jit_cow_block,
    jit_decode_only_step,
    jit_ssm_commit_state,
    jit_ssm_decode_only_step,
    jit_ssm_unified_step,
    jit_unified_step,
    paged_pool_sharding,
    slot_state_shardings,
)
from repro.serve.kvcache import NULL_BLOCK, PagedKVCache
from repro.serve.router import PlanRouter, serve_stages
from repro.serve.sampling import segment_sampling_arrays
from repro.serve.scheduler import PagedCapacity, ServeRequest
from repro.serve.statecache import SlotStateCache, SlotCapacity


def resolve_family_adapter(model):
    """The adapter class serving `model`, by capability probe: the ssm
    slot-pooled entry points first (`decode_step_slots`), then the paged
    decode path.  Raises TypeError for families with neither — they serve
    through the fixed-batch `ServeEngine`."""
    if getattr(model.cfg, "family", None) == "ssm" and hasattr(
            model, "decode_step_slots"):
        return SSMFamilyAdapter
    if hasattr(model, "decode_step_paged"):
        return DecoderFamilyAdapter
    raise TypeError(
        f"{type(model).__name__} has no paged decode path; use the "
        "fixed-batch ServeEngine for this family")


class DecoderFamilyAdapter:
    """Paged-KV family: block-table bookkeeping + the paged step programs."""

    family = "decoder"

    def __init__(self, model, mesh, rules: ShardingRules, cfg,
                 router: PlanRouter):
        mcfg = model.cfg
        self.kv_cfg = cfg.kv_config()
        # the pools are BORN in their serving sharding (blocks replicated,
        # kv_heads over the model axis): the unified program's donated pool
        # arguments then carry the same sharding on the very first step as
        # on every later one, so exactly one executable ever builds — a
        # replicated-first-call would compile a second, layout-shifted copy
        pool_shard = paged_pool_sharding(model, mesh,
                                         prune_for_mesh(rules, mesh))
        self.cache = PagedKVCache(self.kv_cfg, mcfg.n_layers, mcfg.n_kv_heads,
                                  mcfg.hd, jnp.dtype(mcfg.dtype),
                                  sharding=pool_shard)
        # fixed prefill-lane geometry: the step's prompt-token budget and
        # the packed-segment descriptor height, both compiled in.  The
        # height is the EFFECTIVE packing width — cfg.chunk_segments
        # narrowed by the plan's tuned `max_segments` (old Pallas plans,
        # tuned before the segmented kernel existed, narrow it to 1) — so
        # the segmented kernel's grid is exactly as tall as the packing
        # the scheduler will actually do: the tuned knob sizes the grid,
        # it doesn't just throttle host-side packing under a wider one.
        self.chunk_width = cfg.chunk_width
        self.chunk_segments = max(1, min(
            cfg.chunk_segments,
            router.chunk_segments(default=cfg.chunk_segments)))
        # THE two compiled step programs: the unified step carrying the
        # decode batch plus one packed prompt chunk, and the decode-only
        # fast path for steps with no prompt work (the unified program's
        # chunk lane executes at its compiled width even when idle, so
        # skipping it is a dispatch decision, not a mask).  Attention
        # backends and per-stage matmul lane tables come from the plan's
        # stage choices (decode + the prefill_chunk stage), closed over at
        # trace time — dispatch never recompiles mid-serve, and admission
        # compiles nothing at all.
        decode_backend, _ = router.attention_backend("decode")
        chunk_backend, chunk_config = router.attention_backend(
            "prefill_chunk")
        self._unified = jit_unified_step(
            model, mesh, rules,
            decode_attn_backend=decode_backend,
            chunk_attn_backend=chunk_backend,
            chunk_attn_config=chunk_config,
            decode_matmul_table=router.matmul_table("decode"),
            chunk_matmul_table=router.matmul_table("prefill_chunk"),
            interpret=cfg.interpret)
        self._decode_only = jit_decode_only_step(
            model, mesh, rules,
            decode_attn_backend=decode_backend,
            decode_matmul_table=router.matmul_table("decode"),
            interpret=cfg.interpret)
        # resume-only commit (swap-in scatter): single full-width shape
        # carrying up to `resume_segments` requests per invocation, padded
        # segments diverted to the null sink
        self.resume_segments = self.chunk_segments
        self._commit = jit_commit_prefill(model, mesh, rules)
        # copy-on-write block duplication (prefix sharing); jit is lazy, so
        # this compiles at the FIRST shared-block write, never on admission
        self._cow = jit_cow_block(model, mesh, rules)

    # ------------------------------------------------------------- capacity
    @property
    def alloc(self):
        return self.cache.alloc

    def capacity(self) -> PagedCapacity:
        return PagedCapacity(self.kv_cfg, self.cache.alloc)

    # every resident holds blocks from admission: any victim frees capacity
    victim_eligible = None

    def _cow_rows(self, req: ServeRequest, lo_row: int, hi_row: int) -> bool:
        """Copy-on-write every shared block whose rows intersect
        [lo_row, hi_row) before a write lands there: the allocator swaps a
        fresh private block into req's table and the jitted copy program
        duplicates the payload — co-owners keep reading the original.
        False when the pool runs dry mid-way (the engine preempts a victim
        and retries; copies already made stay consistent)."""
        if not self.kv_cfg.prefix_sharing or hi_row <= lo_row:
            return True
        bs = self.kv_cfg.block_size
        for bi in range(lo_row // bs, (hi_row - 1) // bs + 1):
            try:
                copied = self.cache.alloc.cow(req.rid, bi)
            except MemoryError:
                return False
            if copied is not None:
                src, dst = copied
                self.cache.k, self.cache.v = self._cow(
                    self.cache.k, self.cache.v, np.int32(src), np.int32(dst))
        return True

    def grow_for_decode(self, req: ServeRequest, need_rows: int) -> bool:
        """Extend req's block table to cover its next decode write; False
        when the pool is dry (the engine preempts a victim and retries).
        The decode write lands at row need_rows - 1 — privatize that block
        first if it is shared (full-prompt prefix adoption can leave the
        last prompt block shared into decode)."""
        if not self.cache.alloc.extend(req.rid, need_rows):
            return False
        return self._cow_rows(req, need_rows - 1, need_rows)

    def claim_chunk(self, req: ServeRequest, start: int, n: int) -> bool:
        """Admission already allocated the prompt's blocks; the only lazy
        work is copy-on-write when the chunk's KV rows [start, start+n)
        would land in a block adopted from the prefix index."""
        return self._cow_rows(req, start, start + n)

    def register_prefix(self, req: ServeRequest) -> None:
        """Index req's committed full-block prompt prefixes so later
        admissions can adopt them (first registration wins)."""
        if self.kv_cfg.prefix_sharing:
            self.cache.alloc.register_prefix(
                req.rid, req.prompt, min(req.prefilled, req.prompt_len))

    # ------------------------------------------------------------- swapping
    def is_swapped(self, rid: int) -> bool:
        return self.cache.is_swapped(rid)

    def swap_out(self, rid: int) -> int:
        return self.cache.swap_out(rid)

    def resume_commit(self, group: List[ServeRequest]) -> List[int]:
        """Swap up to `resume_segments` re-admitted requests' KV back in
        with ONE commit invocation: each host buffer scatters into its
        freshly allocated blocks, every segment padded to the FULL table
        width and the group padded to the full segment count (padding ids
        point at the null sink with zero payloads) so exactly one commit
        shape ever traces.  Returns the bytes moved per request."""
        assert 0 < len(group) <= self.resume_segments
        bs = self.kv_cfg.block_size
        nb_pad = self.kv_cfg.max_blocks_per_seq
        n_seg = self.resume_segments
        hosts = [self.cache.take_swapped(r.rid) for r in group]
        k0 = hosts[0][0]
        L = k0.shape[0]
        ks = np.zeros((L, n_seg, nb_pad * bs, *k0.shape[3:]), k0.dtype)
        vs = np.zeros_like(ks)
        ids = np.full((n_seg, nb_pad), NULL_BLOCK, np.int32)
        nbytes: List[int] = []
        for i, (req, (k_host, v_host)) in enumerate(zip(group, hosts)):
            nbytes.append(k_host.nbytes + v_host.nbytes)
            table = self.cache.alloc.tables[req.rid]
            nb = k_host.shape[1]
            assert nb == len(table)
            ks[:, i, :nb * bs] = k_host.reshape(L, nb * bs, *k_host.shape[3:])
            vs[:, i, :nb * bs] = v_host.reshape(L, nb * bs, *v_host.shape[3:])
            ids[i, :nb] = table
        self.cache.k, self.cache.v = self._commit(
            self.cache.k, self.cache.v, jnp.asarray(ks), jnp.asarray(vs),
            jnp.asarray(ids))
        return nbytes

    # ------------------------------------------------------------- dispatch
    def _chunk_inputs(self, chunks: List[Tuple[ServeRequest, int, int]]):
        """Host-side prefill-lane arrays for a packed chunk: the segments'
        prompt slices concatenated from row 0 (fixed `chunk_width`,
        zero-padded), each segment's block table, and the (S, 3) descriptor
        array [row_offset, seg_len, kv_start].  Idle segment slots carry
        seg_len 0 with an all-null table (their row_offset sits at the fill
        level so offsets stay monotone; padding rows divert to the sink)."""
        c = self.chunk_width
        ns = self.chunk_segments
        toks = np.zeros((1, c), np.int32)
        tables = np.full((ns, self.kv_cfg.max_blocks_per_seq),
                         NULL_BLOCK, np.int32)
        info = np.zeros((ns, 3), np.int32)
        q0 = 0
        for i, (req, start, n) in enumerate(chunks):
            toks[0, q0:q0 + n] = req.prompt[start:start + n]
            held = self.cache.alloc.tables[req.rid]
            tables[i, :len(held)] = held
            info[i] = (q0, n, start)
            q0 += n
        info[len(chunks):, 0] = q0            # idle slots: empty span at fill
        return toks, tables, info

    def dispatch(self, params, dec_rids: List[Optional[int]],
                 lengths: np.ndarray, last_tok: np.ndarray,
                 chunks: List[Tuple[ServeRequest, int, int]],
                 dec_sampling: np.ndarray, dec_keys: np.ndarray):
        """Run ONE step program invocation: the unified step when `chunks`
        carries prompt work, else the decode-only fast path.  Returns the
        decode lane's next tokens (host, (slots,)) and the chunk segments'
        next-token samples ((segments,) or None)."""
        bt = jnp.asarray(self.cache.table_array(dec_rids))
        lens = jnp.asarray(lengths)
        tokens = jnp.asarray(last_tok[:, None])
        dsp = jnp.asarray(dec_sampling)
        dks = jnp.asarray(dec_keys)
        if chunks:
            ch_toks, seg_tables, seg_info = self._chunk_inputs(chunks)
            seg_sp, seg_ks = segment_sampling_arrays(chunks,
                                                     self.chunk_segments)
            nxt_dev, seg_next_dev, self.cache.k, self.cache.v = self._unified(
                params, self.cache.k, self.cache.v, bt, lens, tokens,
                jnp.asarray(ch_toks), jnp.asarray(seg_tables),
                jnp.asarray(seg_info), dsp, dks,
                jnp.asarray(seg_sp), jnp.asarray(seg_ks))
            nxt = np.asarray(nxt_dev, np.int32)
            return nxt, np.asarray(seg_next_dev, np.int32)
        # decode-only fast path: no prompt work pending, so the step
        # skips the chunk-wide forward instead of masking it
        nxt_dev, self.cache.k, self.cache.v = self._decode_only(
            params, self.cache.k, self.cache.v, bt, lens, tokens, dsp, dks)
        return np.asarray(nxt_dev, np.int32), None

    def occupancy(self) -> float:
        return self.cache.alloc.occupancy()


class SSMFamilyAdapter:
    """State-cache family: slot-pooled conv/SSM state + the ssm programs.

    Chunk geometry: the lane width is `cfg.chunk_width` rounded UP to a
    multiple of the model's SSD scan block (`cfg.ssm_chunk`) so every
    non-final prompt chunk splits the scan exactly on a block boundary —
    the condition under which chunked prefill is bitwise identical to the
    fixed-batch whole-prompt prefill.  Packing is 1: the SSD recurrence
    threads ONE request's carry through the lane, so segments cannot
    share it the way disjoint paged block-tables can."""

    family = "ssm"

    def __init__(self, model, mesh, rules: ShardingRules, cfg,
                 router: PlanRouter):
        mcfg = model.cfg
        q = max(1, mcfg.ssm_chunk)
        self.chunk_width = -(-cfg.chunk_width // q) * q
        self.chunk_segments = 1
        # resume packing is independent of chunk packing: state rows are
        # disjoint scatter targets, so one commit can carry many swap-ins
        # even though the SSD recurrence holds the chunk lane at width 1
        self.resume_segments = max(1, cfg.chunk_segments)
        self.state_cfg = cfg.state_config()
        # state pools born in their serving sharding (rows replicated,
        # feature dims over the model axis) — same one-executable donation
        # argument as the paged pools above
        self.cache = SlotStateCache.for_model(
            self.state_cfg, mcfg,
            shardings=slot_state_shardings(model, mesh,
                                           prune_for_mesh(rules, mesh)))
        chunk_stage, decode_stage = "ssm_prefill_chunk", "ssm_decode"
        assert chunk_stage in serve_stages(self.family)
        self._unified = jit_ssm_unified_step(
            model, mesh, rules,
            decode_matmul_table=router.matmul_table(decode_stage),
            chunk_matmul_table=router.matmul_table(chunk_stage),
            interpret=cfg.interpret)
        self._decode_only = jit_ssm_decode_only_step(
            model, mesh, rules,
            decode_matmul_table=router.matmul_table(decode_stage),
            interpret=cfg.interpret)
        self._commit = jit_ssm_commit_state(model, mesh, rules)

    # ------------------------------------------------------------- capacity
    @property
    def alloc(self):
        return self.cache.alloc

    def capacity(self) -> SlotCapacity:
        return SlotCapacity(self.cache.alloc)

    @property
    def victim_eligible(self):
        # fresh admission reserves nothing, so a resident that has not yet
        # dispatched its first chunk owns no state row — evicting it frees
        # no capacity.  Narrow victims to actual row holders.
        return lambda r: self.cache.alloc.holds(r.rid)

    def grow_for_decode(self, req: ServeRequest, need_rows: int) -> bool:
        # fixed-size state: nothing grows during decode
        return True

    def claim_chunk(self, req: ServeRequest, start: int, n: int) -> bool:
        """Lazily claim req's state row at first-chunk dispatch; False when
        the pool is dry (the engine preempts a row holder and retries).
        The chunk geometry is irrelevant: state rows are fixed-size and
        never shared, so there is nothing to copy-on-write."""
        if self.cache.alloc.holds(req.rid):
            return True
        if not self.cache.alloc.can_allocate(1):
            return False
        self.cache.alloc.allocate(req.rid)
        return True

    def register_prefix(self, req: ServeRequest) -> None:
        # recurrent state is a lossy summary of the whole prefix — rows are
        # owned by exactly one request, so there is no prefix index to feed
        return None

    # ------------------------------------------------------------- swapping
    def is_swapped(self, rid: int) -> bool:
        return self.cache.is_swapped(rid)

    def swap_out(self, rid: int) -> int:
        return self.cache.swap_out(rid)

    def resume_commit(self, group: List[ServeRequest]) -> List[int]:
        """Scatter up to `resume_segments` re-admitted requests' host-side
        (conv, ssm) states into their freshly claimed pool rows with ONE
        commit invocation.  The row array is traced data, padded entries
        point at the null row with zero payloads — one shape ever traces.
        Returns the bytes moved per request."""
        assert 0 < len(group) <= self.resume_segments
        n_seg = self.resume_segments
        hosts = [self.cache.take_swapped(r.rid) for r in group]
        conv0, ssm0 = hosts[0]
        conv = np.zeros((conv0.shape[0], n_seg, *conv0.shape[1:]),
                        conv0.dtype)
        ssm = np.zeros((ssm0.shape[0], n_seg, *ssm0.shape[1:]), ssm0.dtype)
        rows = np.zeros((n_seg,), np.int32)   # padding -> null row sink
        nbytes: List[int] = []
        for i, (req, (conv_host, ssm_host)) in enumerate(zip(group, hosts)):
            nbytes.append(conv_host.nbytes + ssm_host.nbytes)
            conv[:, i] = conv_host
            ssm[:, i] = ssm_host
            rows[i] = self.cache.alloc.slot_of(req.rid)
        self.cache.conv, self.cache.ssm = self._commit(
            self.cache.conv, self.cache.ssm, jnp.asarray(conv),
            jnp.asarray(ssm), jnp.asarray(rows))
        return nbytes

    # ------------------------------------------------------------- dispatch
    def dispatch(self, params, dec_rids: List[Optional[int]],
                 lengths: np.ndarray, last_tok: np.ndarray,
                 chunks: List[Tuple[ServeRequest, int, int]],
                 dec_sampling: np.ndarray, dec_keys: np.ndarray):
        """One ssm step program invocation.  The decode lane maps each slot
        to its state row (`index_array`; idle/prefilling slots hit the null
        row); the chunk lane carries at most ONE segment (packing width 1).
        Traced scalars go in as strongly-typed np.int32 so the weak-typed
        Python-int path can never trace a second executable."""
        state_idx = jnp.asarray(self.cache.index_array(dec_rids))
        tokens = jnp.asarray(last_tok[:, None])
        dsp = jnp.asarray(dec_sampling)
        dks = jnp.asarray(dec_keys)
        if chunks:
            req, start, n = chunks[0]
            ch_toks = np.zeros((1, self.chunk_width), np.int32)
            ch_toks[0, :n] = req.prompt[start:start + n]
            row = self.cache.alloc.slot_of(req.rid)
            ch_sp, ch_ks = segment_sampling_arrays(chunks, 1)
            nxt_dev, ch_next_dev, self.cache.conv, self.cache.ssm = \
                self._unified(
                    params, self.cache.conv, self.cache.ssm, state_idx,
                    tokens, jnp.asarray(ch_toks), np.int32(row),
                    np.int32(n), np.int32(start), dsp, dks,
                    jnp.asarray(ch_sp), jnp.asarray(ch_ks))
            nxt = np.asarray(nxt_dev, np.int32)
            return nxt, np.asarray(ch_next_dev, np.int32).reshape(1)
        nxt_dev, self.cache.conv, self.cache.ssm = self._decode_only(
            params, self.cache.conv, self.cache.ssm, state_idx, tokens,
            dsp, dks)
        return np.asarray(nxt_dev, np.int32), None

    def occupancy(self) -> float:
        return self.cache.alloc.occupancy()
