"""Fault-tolerant training loop.

Production posture on a 1000+-node cluster:
  * checkpoint/restart — atomic checkpoints every `ckpt_every` steps
    (async, one-deep pipeline) + auto-resume from the latest valid step,
    including optimizer state and the (stateless) data-pipeline cursor;
  * preemption handling — a `PREEMPT` sentinel file (what a cluster agent
    writes on SIGTERM) triggers save-and-exit at the next step boundary;
  * straggler mitigation — per-step wall time is tracked against a rolling
    median; steps slower than `straggler_factor`x are logged with their
    step index (on real fleets this feeds the scheduler's hot-spare swap;
    the hook is the seam) and counted in metrics;
  * elastic restart — `Trainer.restore` resharding-device_puts state onto
    the *current* mesh, so the job may resume on a different topology.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticLMData
from repro.distributed.sharding import ShardingRules
from repro.launch.steps import TrainConfig, jit_train_step, make_state_shardings
from repro.optim import adamw_init


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep_ckpts: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    preempt_file: Optional[str] = None  # default: <ckpt_dir>/PREEMPT
    train: TrainConfig = dataclasses.field(default_factory=TrainConfig)


class Trainer:
    def __init__(self, model, mesh, rules: ShardingRules, data: SyntheticLMData,
                 cfg: TrainerConfig):
        self.model = model
        self.mesh = mesh
        self.rules = rules
        self.data = data
        self.cfg = cfg
        self.ckpt = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep_ckpts)
        self.preempt_file = cfg.preempt_file or os.path.join(cfg.ckpt_dir, "PREEMPT")
        self.step_times: List[float] = []
        self.stragglers: List[int] = []
        self.metrics_log: List[Dict[str, float]] = []

        b0 = data.batch(0)
        self.batch_specs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                            for k, v in b0.items()}
        self._step_fn = jit_train_step(model, mesh, rules, cfg.train,
                                       self.batch_specs)

    # ------------------------------------------------------------------
    def init_state(self, seed: int = 0) -> Dict[str, Any]:
        with self.mesh:
            params = self.model.init(jax.random.PRNGKey(seed))
            opt = adamw_init(params)
        return {"params": params, "opt": opt}

    def restore_or_init(self, seed: int = 0):
        template = jax.eval_shape(lambda: self.init_state(seed))
        p_shard, opt_shard = make_state_shardings(
            self.model, self.mesh, self.rules, self.cfg.train)
        shardings = {"params": p_shard, "opt": opt_shard}
        try:
            step, state = self.ckpt.restore_latest(template, shardings)
        except Exception:
            step, state = None, None
        if state is None:
            return 0, self.init_state(seed)
        return step, state

    # ------------------------------------------------------------------
    def run(self, start_step: Optional[int] = None,
            state: Optional[Dict[str, Any]] = None,
            on_step: Optional[Callable[[int, Dict[str, float]], None]] = None):
        if state is None:
            start_step, state = self.restore_or_init()
        step = start_step or 0
        preempted = False

        with self.mesh:
            while step < self.cfg.steps:
                batch = {k: jnp.asarray(v)
                         for k, v in self.data.batch(step).items()}
                t0 = time.perf_counter()
                state["params"], state["opt"], metrics = self._step_fn(
                    state["params"], state["opt"], batch)
                loss = float(metrics["loss"])  # blocks; realistic step time
                dt = time.perf_counter() - t0
                # first two steps include jit compile — exclude from the
                # straggler baseline (fleet warm-up convention)
                if step - (start_step or 0) >= 2:
                    if len(self.step_times) >= 3:
                        med = float(np.median(self.step_times[-32:]))
                        if dt > self.cfg.straggler_factor * med:
                            self.stragglers.append(step)
                    self.step_times.append(dt)

                step += 1
                row = {"step": step, "loss": loss, "step_s": dt,
                       "grad_norm": float(metrics.get("grad_norm", 0.0))}
                self.metrics_log.append(row)
                if on_step:
                    on_step(step, row)
                if step % self.cfg.log_every == 0:
                    tok = int(np.prod(batch["tokens"].shape))
                    print(f"step {step:5d} loss {loss:8.4f} "
                          f"({tok / dt:,.0f} tok/s, {dt * 1e3:.0f} ms)")

                if step % self.cfg.ckpt_every == 0 or step == self.cfg.steps:
                    self.ckpt.save_async(step, state,
                                         {"data": self.data.state_dict(step)})
                if os.path.exists(self.preempt_file):
                    print(f"preemption requested; checkpointing at step {step}")
                    self.ckpt.wait()
                    self.ckpt.save(step, state,
                                   {"data": self.data.state_dict(step),
                                    "preempted": True})
                    preempted = True
                    break

        self.ckpt.wait()
        return step, state, {"preempted": preempted,
                             "stragglers": self.stragglers}
