"""Process-level platform configuration — the bits that must land in the
environment BEFORE the first `import jax`.

JAX's CPU backend exposes exactly one device unless
`--xla_force_host_platform_device_count=N` is in `XLA_FLAGS` when the
backend initializes, and backend initialization happens at first import.
That makes host-device-count a *launcher* concern, not a library one: any
entry point that wants a multi-device CPU mesh (the tensor-parallel bench
sweep, the serving example, the CI mesh-smoke job) has to set the flag
before anything in its import graph touches jax.

This module therefore imports NOTHING from jax at module scope and has no
side effects on import.  Entry points use it like:

    from repro import platform
    platform.configure_from_argv()     # peeks --devices N from sys.argv
    import jax                         # backend now sees N host devices

or explicitly: `platform.set_host_device_count(4)`.

Setting the flag after jax has initialized cannot work, so that case warns
and leaves the environment alone rather than silently lying about the
device count the process will actually see.
"""

from __future__ import annotations

import os
import re
import sys
import warnings
from typing import List, Optional

_FLAG = "--xla_force_host_platform_device_count"


def _jax_initialized() -> bool:
    """True once jax is imported (its backends latch XLA_FLAGS then)."""
    return "jax" in sys.modules


def host_device_count() -> Optional[int]:
    """The host device count currently requested in XLA_FLAGS, or None."""
    m = re.search(rf"{_FLAG}=(\d+)", os.environ.get("XLA_FLAGS", ""))
    return int(m.group(1)) if m else None


def set_host_device_count(n: int) -> bool:
    """Request `n` virtual host devices from the CPU backend.

    Merges into any existing XLA_FLAGS (replacing a previous
    host-device-count flag, preserving everything else).  Returns True if
    the environment was updated; False — with a warning — when jax is
    already imported and the flag can no longer take effect.
    """
    n = int(n)
    if n < 1:
        raise ValueError(f"host device count must be >= 1, got {n}")
    if _jax_initialized():
        seen = host_device_count() or 1
        if seen != n:
            warnings.warn(
                f"jax is already imported; cannot change host device count "
                f"to {n} (the backend latched XLA_FLAGS at import, "
                f"currently {seen}). Call repro.platform before importing "
                f"jax.", RuntimeWarning, stacklevel=2)
            return False
        return True
    flags = [f for f in os.environ.get("XLA_FLAGS", "").split()
             if not f.startswith(f"{_FLAG}=")]
    flags.append(f"{_FLAG}={n}")
    os.environ["XLA_FLAGS"] = " ".join(flags)
    return True


def configure_from_argv(argv: Optional[List[str]] = None) -> Optional[int]:
    """Peek `--devices N` out of `argv` (default `sys.argv`) and apply it
    before the caller's jax import.

    This deliberately bypasses argparse: parsers live *below* the entry
    point's jax imports, far too late to influence backend init.  The flag
    stays in argv for the real parser to consume (and document).  Returns
    the device count applied, or None when the flag is absent.
    """
    args = list(sys.argv if argv is None else argv)
    n: Optional[int] = None
    for i, a in enumerate(args):
        if a == "--devices" and i + 1 < len(args):
            n = int(args[i + 1])
        elif a.startswith("--devices="):
            n = int(a.split("=", 1)[1])
    if n is not None:
        set_host_device_count(n)
    return n
