"""Step builders: train_step / prefill_step / serve_step with shardings.

These are what `dryrun.py` lowers for every (arch × shape × mesh) cell and
what the trainer/server run for real.  Everything sharding-related funnels
through `ShardingRules`, so a hillclimb iteration = new rules + re-lower.

Distributed-optimization features:
  * microbatched gradient accumulation (`microbatches > 1`) — emits the
    per-microbatch grad pattern XLA's latency-hiding scheduler can overlap
    with the next microbatch's compute;
  * ZeRO-1 — optimizer moments sharded along the 'zero' (data) axis on the
    first divisible dim of each leaf;
  * donated params/opt-state/cache buffers;
  * optional int8-compressed pod-axis gradient reduction (see
    repro.optim.compress) for the DCN hop.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.kernels.dispatch import matmul_dispatch
from repro.kernels.sampling import sample_tokens
from repro.distributed.sharding import (
    DEFAULT_RULES,
    ShardingRules,
    activation_rules,
    params_shardings,
    prune_for_mesh,
)
from repro.optim import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    zero1: bool = True
    compress_pod_grads: bool = False
    opt: AdamWConfig = AdamWConfig()


def cost_dict(cost) -> Dict[str, float]:
    """Normalise `Compiled.cost_analysis()` across JAX versions: newer
    releases return one properties dict, older ones a one-element list."""
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


# ---------------------------------------------------------------- shardings
def batch_logical_axes(batch_specs: Dict[str, Any]) -> Dict[str, Any]:
    out = {}
    for k, v in batch_specs.items():
        out[k] = ("batch",) + (None,) * (len(v.shape) - 1)
    return out


def rules_for_shape(cfg: ModelConfig, shape: ShapeSpec,
                    mesh: Mesh) -> ShardingRules:
    """Shape- and config-aware rule overrides (the baseline sharding scheme;
    the §Perf hillclimb iterates by overriding the result).

    Divisibility fallbacks (each dim must divide its mesh axis):
      * kv_heads/heads indivisible by |model|  -> replicate (note: GQA archs
        with few KV heads keep K/V projections replicated — a known baseline
        cost, see EXPERIMENTS.md);
      * vocab indivisible                       -> shard the embed-table
        d_model dim instead ('embed_vec' -> model);
      * n_experts indivisible                   -> TP inside experts
        ('expert_ffn' -> model) instead of EP.
    """
    rules = DEFAULT_RULES
    m = mesh.shape.get("model", 1)
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)

    if cfg.n_heads and cfg.n_heads % m:
        rules = rules.replace(heads=None)
    if cfg.n_kv_heads and cfg.n_kv_heads % m:
        rules = rules.replace(kv_heads=None)
    if cfg.d_ff and cfg.d_ff % m:
        rules = rules.replace(ffn=None)
    if cfg.vocab % m:
        rules = rules.replace(vocab=None)
        if cfg.d_model % m == 0:
            rules = rules.replace(embed_vec="model")
    if cfg.n_experts:
        if cfg.n_experts % m:
            rules = rules.replace(experts=None)
            if cfg.d_expert % m == 0:
                rules = rules.replace(expert_ffn="model")
    if cfg.ssm_state:
        d_in = cfg.ssm_expand * cfg.d_model
        nh = d_in // cfg.ssm_head_dim
        conv_dim = d_in + 2 * cfg.ssm_state
        if nh % m:
            rules = rules.replace(ssm_heads=None)
        if conv_dim % m:
            rules = rules.replace(conv_dim=None)

    if (shape.kind == "decode" and cfg.n_kv_heads and cfg.n_kv_heads % m
            and shape.seq_len % m == 0):
        # §Perf cell (b): GQA KV heads indivisible by |model| would replicate
        # the KV cache over the model axis — shard the cache sequence dim
        # there instead (11.6x on the dominant memory term for granite
        # decode_32k; see EXPERIMENTS.md).
        rules = rules.replace(kv_seq="model")

    if shape.global_batch % dp != 0 or shape.global_batch < dp:
        # batch unshardable (long_500k B=1): replicate batch, shard the
        # sequence/state dims instead (SP).
        d = mesh.shape.get("data", 1)
        rules = rules.replace(
            batch=None,
            kv_seq="data" if shape.seq_len % d == 0 else None,
            ssm_state="data" if (cfg.ssm_state and cfg.ssm_state % d == 0) else None,
            seq="data" if shape.seq_len % d == 0 else None,
        )
    if shape.kind == "train" and shape.seq_len >= 16_384:
        rules = rules.replace(seq="data")  # SP for long-sequence training
    return rules


def zero1_axes(logical_tree, shapes_tree, mesh: Mesh, rules: ShardingRules):
    """Rewrite the first shardable None axis of each optimizer-moment leaf to
    'zero' (the data axis) when the dim divides evenly — ZeRO-1."""
    zset = rules.lookup("zero")
    zsize = mesh.shape.get(zset, 1) if isinstance(zset, str) else 1

    def rewrite(axes, shaped):
        if zsize <= 1:
            return axes
        used = {a for a in axes if a is not None}
        out = list(axes)
        for i, (a, dim) in enumerate(zip(axes, shaped.shape)):
            if a is None and dim % zsize == 0 and "zero" not in used:
                out[i] = "zero"
                break
        return tuple(out)

    return jax.tree.map(
        rewrite, logical_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))


def make_state_shardings(model, mesh: Mesh, rules: ShardingRules,
                         train_cfg: Optional[TrainConfig] = None):
    """NamedShardings for (params, opt_state) trees."""
    p_logical = model.logical_axes()
    p_shard = params_shardings(mesh, rules, p_logical)
    if train_cfg is None:
        return p_shard, None
    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    mv_logical = p_logical
    if train_cfg.zero1:
        mv_logical = zero1_axes(p_logical, params_shapes, mesh, rules)
    mv_shard = params_shardings(mesh, rules, mv_logical)
    opt_shard = {"m": mv_shard, "v": mv_shard,
                 "step": NamedSharding(mesh, P())}
    return p_shard, opt_shard


def make_batch_shardings(mesh: Mesh, rules: ShardingRules, batch_specs):
    return {
        k: NamedSharding(
            mesh, rules.spec(("batch",) + (None,) * (len(v.shape) - 1)))
        for k, v in batch_specs.items()
    }


# ---------------------------------------------------------------- train step
def make_train_step(model, train_cfg: TrainConfig, rules: ShardingRules):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def loss_fn(params, batch):
        l, metrics = model.loss(params, batch)
        return l, metrics

    def train_step(params, opt_state, batch):
        with activation_rules(rules):
            k = train_cfg.microbatches
            if k == 1:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)
            else:
                micro = jax.tree.map(
                    lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]),
                    batch)

                def acc_body(carry, mb):
                    g_acc, l_acc = carry
                    (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                        params, mb)
                    g_acc = jax.tree.map(jnp.add, g_acc, g)
                    return (g_acc, l_acc + l), None

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (grads, loss), _ = jax.lax.scan(
                    acc_body, (zeros, jnp.zeros((), jnp.float32)), micro)
                grads = jax.tree.map(lambda g: g / k, grads)
                loss = loss / k
                metrics = {"loss": loss}
            params, opt_state, om = adamw_update(
                params, grads, opt_state, train_cfg.opt)
            metrics = dict(metrics, **om)
        return params, opt_state, metrics

    return train_step


def jit_train_step(model, mesh: Mesh, rules: ShardingRules,
                   train_cfg: TrainConfig, batch_specs):
    rules = prune_for_mesh(rules, mesh)
    p_shard, opt_shard = make_state_shardings(model, mesh, rules, train_cfg)
    b_shard = make_batch_shardings(mesh, rules, batch_specs)
    step = make_train_step(model, train_cfg, rules)
    return jax.jit(
        step,
        in_shardings=(p_shard, opt_shard, b_shard),
        out_shardings=(p_shard, opt_shard, None),
        donate_argnums=(0, 1),
    )


# ---------------------------------------------------------------- serve steps
def make_prefill_step(model, rules: ShardingRules, max_seq: int):
    def prefill_step(params, batch):
        with activation_rules(rules):
            return model.prefill(params, batch, max_seq)

    return prefill_step


def make_serve_step(model, rules: ShardingRules):
    def serve_step(params, cache, tokens):
        with activation_rules(rules):
            return model.decode_step(params, cache, tokens)

    return serve_step


def cache_shardings(model, mesh: Mesh, rules: ShardingRules, batch: int,
                    max_seq: int):
    logical = model.cache_logical_axes()
    cache_shapes = jax.eval_shape(lambda: model.init_cache(batch, max_seq))

    def spec_of(axes, shaped):
        axes = tuple(axes) + (None,) * (len(shaped.shape) - len(axes))
        return NamedSharding(mesh, rules.spec(axes))

    return jax.tree.map(
        spec_of, logical, cache_shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))


def jit_prefill_step(model, mesh: Mesh, rules: ShardingRules, batch_specs,
                     max_seq: int, batch: int):
    rules = prune_for_mesh(rules, mesh)
    p_shard, _ = make_state_shardings(model, mesh, rules, None)
    b_shard = make_batch_shardings(mesh, rules, batch_specs)
    c_shard = cache_shardings(model, mesh, rules, batch, max_seq)
    step = make_prefill_step(model, rules, max_seq)
    return jax.jit(step, in_shardings=(p_shard, b_shard),
                   out_shardings=(None, c_shard))


def jit_serve_step(model, mesh: Mesh, rules: ShardingRules, batch: int,
                   max_seq: int):
    rules = prune_for_mesh(rules, mesh)
    p_shard, _ = make_state_shardings(model, mesh, rules, None)
    c_shard = cache_shardings(model, mesh, rules, batch, max_seq)
    tok_shard = NamedSharding(mesh, rules.spec(("batch", None)))
    step = make_serve_step(model, rules)
    return jax.jit(step, in_shardings=(p_shard, c_shard, tok_shard),
                   out_shardings=(None, c_shard), donate_argnums=(1,))


# ------------------------------------------------- paged (continuous) serving
def paged_pool_sharding(model, mesh: Mesh, rules: ShardingRules):
    """NamedSharding of the paged KV pool: blocks replicated, kv_heads
    sharded along 'model' exactly like the monolithic cache's head axis."""
    axes = model.paged_cache_logical_axes()["k"]
    return NamedSharding(mesh, rules.spec(axes))


def jit_unified_step(model, mesh: Mesh, rules: ShardingRules,
                     decode_attn_backend: str = "xla",
                     chunk_attn_backend: str = "xla", chunk_attn_config=None,
                     decode_matmul_table=None, chunk_matmul_table=None,
                     interpret: bool = True):
    """(params, k_pool, v_pool,
        dec_tables, dec_lengths, dec_tokens,   # decode lane: every slot
        ch_tokens, seg_tables, seg_info,       # prefill lane: packed chunk
        dec_sampling, dec_keys,                # per-slot sampling (traced)
        seg_sampling, seg_keys)                # per-segment sampling (traced)
        -> (dec_next (slots,), seg_next (S,), k_pool, v_pool)

    THE serving step program for steps that carry prompt work: each
    invocation carries up to `chunk_tokens` of pending prompt work —
    ch_tokens is a fixed-width (1, C) buffer PACKED with contiguous prompt
    segments from up to S requests, described by the traced (S, 3)
    descriptor array `seg_info` ([row_offset, seg_len, kv_start] per
    segment) and the (S, nbt) per-segment block tables — alongside a
    decode token for every in-flight slot.  All lanes share the paged
    pool: every chunk row scatters its K/V into its OWN segment's blocks
    (committed incrementally, chunk by chunk) and the decode lane appends
    one row per active slot, all inside a single compiled program.

    Every argument shape is static in (slots, pool blocks, table width,
    chunk budget, segment slots), so admission, chunk progress, packing,
    retirement, preemption and resume are pure data updates — this program
    compiles exactly ONCE.  Idle segment slots are masked by data (seg_len
    0 with an all-null table; padding rows divert to the sink block), and
    slots that are empty or still prefilling carry all-null decode tables
    with length 0.  Masking hides results, not FLOPs — the chunk lane
    executes at its compiled width whenever THIS program runs, which is
    exactly why chunk-less steps dispatch `jit_decode_only_step` instead
    (the second and last step executable; see ContinuousEngine.step).

    seg_next holds each segment's next-token sample, valid only for
    segments that complete their prompt this step (the host consumes
    exactly those).  Sampling is FUSED per lane via
    `repro.kernels.sampling.sample_tokens`: the (rows, 3) float32
    [temperature, top_k, top_p] and (rows, 3) int32 [seed, rid,
    token_index] arrays are traced data, so per-request knobs never
    retrace — greedy rows (temperature 0) reduce bitwise to the argmax
    path this program always had.  The attention backends and the
    per-stage matmul tables (the plan's `decode` and `prefill_chunk`
    stage choices) are closed over — static at trace time, zero per-step
    dispatch cost."""
    rules = prune_for_mesh(rules, mesh)
    p_shard, _ = make_state_shardings(model, mesh, rules, None)
    pool_shard = paged_pool_sharding(model, mesh, rules)
    slot_shard = NamedSharding(mesh, rules.spec(("batch",)))
    row_shard = NamedSharding(mesh, rules.spec(("batch", None)))

    def unified_step(params, k_pool, v_pool, dec_tables, dec_lengths,
                     dec_tokens, ch_tokens, seg_tables, seg_info,
                     dec_sampling, dec_keys, seg_sampling, seg_keys):
        with activation_rules(rules):
            # prefill lane: a packed chunk of prompt segments, K/V committed
            # to each segment's blocks in-program (no separate commit)
            with matmul_dispatch(chunk_matmul_table, interpret=interpret):
                ch_logits, k_pool, v_pool = model.prefill_packed_paged(
                    params, k_pool, v_pool, seg_tables, ch_tokens,
                    seg_info, attn_backend=chunk_attn_backend,
                    attn_config=chunk_attn_config, attn_interpret=interpret)
            # decode lane: one token for every slot (the lanes touch
            # disjoint blocks — a request never prefills and decodes in the
            # same step — so XLA is free to schedule them together)
            with matmul_dispatch(decode_matmul_table, interpret=interpret):
                logits, k_pool, v_pool = model.decode_step_paged(
                    params, k_pool, v_pool, dec_tables, dec_lengths,
                    dec_tokens, attn_backend=decode_attn_backend,
                    attn_interpret=interpret)
        # keyed sampling fused for all lanes: seg_next[s] is the first
        # token of segment s's request, valid only when that segment
        # completes its prompt (the host consumes it exactly then)
        nxt = sample_tokens(logits[:, -1], dec_sampling, dec_keys)
        seg_next = sample_tokens(ch_logits[0], seg_sampling, seg_keys)
        return nxt, seg_next, k_pool, v_pool

    return jax.jit(
        unified_step,
        in_shardings=(p_shard, pool_shard, pool_shard, row_shard, slot_shard,
                      row_shard, None, None, None, None, None, None, None),
        out_shardings=(None, None, pool_shard, pool_shard),
        donate_argnums=(1, 2),
    )


def jit_decode_only_step(model, mesh: Mesh, rules: ShardingRules,
                         decode_attn_backend: str = "xla",
                         decode_matmul_table=None, interpret: bool = True):
    """(params, k_pool, v_pool, dec_tables, dec_lengths, dec_tokens,
        dec_sampling, dec_keys)
        -> (dec_next (slots,), k_pool, v_pool)

    The decode-only fast path: the unified step's decode lane compiled
    WITHOUT the chunk lane.  `jit_unified_step` executes its prefill lane
    at the full compiled chunk width even when every descriptor row is
    idle — the budget would price every step — so the engine dispatches
    this program instead whenever no prompt work is pending.  Pool/table
    shapes and shardings match the unified program exactly (the donated
    pools ping-pong between the two executables without a layout shift),
    and the decode lane's float program is identical — an active slot's
    attention never reads the sink block the idle chunk lane would have
    scribbled on, so switching programs step to step is invisible to the
    token streams.  With it the serving runtime owns exactly TWO step
    executables, chosen per step by whether prompt work exists; admission
    still compiles nothing."""
    rules = prune_for_mesh(rules, mesh)
    p_shard, _ = make_state_shardings(model, mesh, rules, None)
    pool_shard = paged_pool_sharding(model, mesh, rules)
    slot_shard = NamedSharding(mesh, rules.spec(("batch",)))
    row_shard = NamedSharding(mesh, rules.spec(("batch", None)))

    def decode_only_step(params, k_pool, v_pool, dec_tables, dec_lengths,
                         dec_tokens, dec_sampling, dec_keys):
        with activation_rules(rules):
            with matmul_dispatch(decode_matmul_table, interpret=interpret):
                logits, k_pool, v_pool = model.decode_step_paged(
                    params, k_pool, v_pool, dec_tables, dec_lengths,
                    dec_tokens, attn_backend=decode_attn_backend,
                    attn_interpret=interpret)
        nxt = sample_tokens(logits[:, -1], dec_sampling, dec_keys)
        return nxt, k_pool, v_pool

    return jax.jit(
        decode_only_step,
        in_shardings=(p_shard, pool_shard, pool_shard, row_shard, slot_shard,
                      row_shard, None, None),
        out_shardings=(None, pool_shard, pool_shard),
        donate_argnums=(1, 2),
    )


def jit_commit_prefill(model, mesh: Mesh, rules: ShardingRules):
    """(k_pool, v_pool, ks, vs, block_ids) -> (k_pool, v_pool)

    Scatter up to S resuming requests' per-layer K/V
    (L, S, S_pad, Hkv, hd) into the physical pool at `block_ids`
    ((S, S_pad/block_size) entries; padding entries — short tables and
    empty segment rows alike — point at the null sink block, whose payload
    rows are zeros and which is never read).  Donates the pools.

    Since the unified step commits prefill KV in-program (chunk by chunk),
    this is now only the *resume* path: preempted requests' swapped-out
    KV, read back from the host buffers and scattered into their freshly
    allocated blocks (`ContinuousEngine._resume_group`).  Resume always
    pads to S segments of the full table width (max_blocks_per_seq
    blocks), so exactly one shape ever traces — no bucket ladder anywhere
    in the serving runtime — and a burst of K swap-ins lands in
    ceil(K / S) invocations instead of K."""
    rules = prune_for_mesh(rules, mesh)
    pool_shard = paged_pool_sharding(model, mesh, rules)

    def commit(k_pool, v_pool, ks, vs, block_ids):
        n_layers, block_size = k_pool.shape[0], k_pool.shape[2]
        n_seg, s_pad = ks.shape[1], ks.shape[2]
        nb = s_pad // block_size
        kb = ks.reshape(n_layers, n_seg * nb, block_size, *ks.shape[3:])
        vb = vs.reshape(n_layers, n_seg * nb, block_size, *vs.shape[3:])
        flat_ids = block_ids.reshape(-1)
        k_pool = k_pool.at[:, flat_ids].set(kb.astype(k_pool.dtype))
        v_pool = v_pool.at[:, flat_ids].set(vb.astype(v_pool.dtype))
        return k_pool, v_pool

    return jax.jit(commit, in_shardings=(pool_shard, pool_shard, None, None,
                                         None),
                   out_shardings=(pool_shard, pool_shard),
                   donate_argnums=(0, 1))


def jit_cow_block(model, mesh: Mesh, rules: ShardingRules):
    """(k_pool, v_pool, src, dst) -> (k_pool, v_pool)

    Device side of copy-on-write: duplicate physical block `src` into
    `dst` in both pools.  The host allocator has already repointed the
    writing request's block table at `dst`; co-owners keep reading `src`.
    Block ids are traced scalars, so every CoW shares ONE executable —
    lazily compiled at the first copy, never on admission.  Donates the
    pools (they ping-pong exactly like the step programs')."""
    rules = prune_for_mesh(rules, mesh)
    pool_shard = paged_pool_sharding(model, mesh, rules)

    def copy(k_pool, v_pool, src, dst):
        k_pool = k_pool.at[:, dst].set(k_pool[:, src])
        v_pool = v_pool.at[:, dst].set(v_pool[:, src])
        return k_pool, v_pool

    return jax.jit(copy,
                   in_shardings=(pool_shard, pool_shard, None, None),
                   out_shardings=(pool_shard, pool_shard),
                   donate_argnums=(0, 1))


# ------------------------------------------- slot-pooled (continuous) serving
# The state-cache families' counterparts of the paged builders above: the
# per-request state is fixed-size (conv window + SSM state), so the pool is
# a (layers, num_slots, ...) grid, the "block table" degenerates to ONE
# traced row index per request, and there is no growth and no in-decode
# extension — otherwise the program discipline is identical: every shape is
# static in (slots, pool rows, chunk width), exactly two step executables,
# admission compiles nothing.

def slot_state_shardings(model, mesh: Mesh, rules: ShardingRules):
    """(conv NamedSharding, ssm NamedSharding) of the slot state pools:
    pool rows replicated, feature axes sharded per the model's declared
    logical axes (`MambaLM.slot_state_logical_axes`)."""
    axes = model.slot_state_logical_axes()
    return (NamedSharding(mesh, rules.spec(axes["conv"])),
            NamedSharding(mesh, rules.spec(axes["ssm"])))


def jit_ssm_unified_step(model, mesh: Mesh, rules: ShardingRules,
                         decode_matmul_table=None, chunk_matmul_table=None,
                         interpret: bool = True):
    """(params, conv_pool, ssm_pool,
        dec_state_idx, dec_tokens,                # decode lane: every slot
        ch_tokens, ch_state_idx, ch_seg_len, ch_seg_start,  # prefill lane
        dec_sampling, dec_keys,                   # per-slot sampling (traced)
        ch_sampling, ch_keys)                     # (1, 3) chunk sampling
        -> (dec_next (slots,), ch_next (), conv_pool, ssm_pool)

    THE ssm serving step for steps that carry prompt work: one C-token
    prompt segment (C a multiple of `cfg.ssm_chunk`, rows past `ch_seg_len`
    dt-masked into exact identities) committed into the chunk request's
    state row, alongside a decode token for every in-flight slot
    (`dec_state_idx` maps slot -> pool row; idle/prefilling slots point at
    the null row).  The lanes touch disjoint pool rows — a request never
    prefills and decodes in the same step — so XLA may schedule them
    freely.  Every index is traced data: admission, chunk progress,
    retirement, preemption and resume never recompile, and `ch_seg_start
    == 0` selects zero carries in-program so a freshly claimed row needs no
    zeroing pass.  `ch_next` is the segment's next-token sample, consumed
    by the host only when the segment completes its prompt.  Sampling is
    fused exactly as in the paged steps (`repro.kernels.sampling`): the
    per-slot / per-chunk sampling and key arrays are traced data, greedy
    rows reduce bitwise to the argmax path."""
    rules = prune_for_mesh(rules, mesh)
    p_shard, _ = make_state_shardings(model, mesh, rules, None)
    conv_shard, ssm_shard = slot_state_shardings(model, mesh, rules)
    slot_shard = NamedSharding(mesh, rules.spec(("batch",)))
    row_shard = NamedSharding(mesh, rules.spec(("batch", None)))

    def ssm_unified_step(params, conv_pool, ssm_pool, dec_state_idx,
                         dec_tokens, ch_tokens, ch_state_idx, ch_seg_len,
                         ch_seg_start, dec_sampling, dec_keys, ch_sampling,
                         ch_keys):
        with activation_rules(rules):
            # prefill lane: one prompt segment, state committed in-program
            with matmul_dispatch(chunk_matmul_table, interpret=interpret):
                ch_logits, conv_pool, ssm_pool = model.prefill_chunk_slots(
                    params, conv_pool, ssm_pool, ch_state_idx, ch_tokens,
                    ch_seg_len, ch_seg_start)
            # decode lane: one token for every slot
            with matmul_dispatch(decode_matmul_table, interpret=interpret):
                logits, conv_pool, ssm_pool = model.decode_step_slots(
                    params, conv_pool, ssm_pool, dec_state_idx, dec_tokens)
        nxt = sample_tokens(logits[:, -1], dec_sampling, dec_keys)
        ch_next = sample_tokens(ch_logits[:, -1], ch_sampling, ch_keys)[0]
        return nxt, ch_next, conv_pool, ssm_pool

    return jax.jit(
        ssm_unified_step,
        in_shardings=(p_shard, conv_shard, ssm_shard, slot_shard, row_shard,
                      None, None, None, None, None, None, None, None),
        out_shardings=(None, None, conv_shard, ssm_shard),
        donate_argnums=(1, 2),
    )


def jit_ssm_decode_only_step(model, mesh: Mesh, rules: ShardingRules,
                             decode_matmul_table=None,
                             interpret: bool = True):
    """(params, conv_pool, ssm_pool, dec_state_idx, dec_tokens,
        dec_sampling, dec_keys)
        -> (dec_next (slots,), conv_pool, ssm_pool)

    The ssm decode-only fast path: the unified step's decode lane compiled
    without the chunk lane, dispatched whenever no prompt work is pending.
    Pool shapes/shardings match the unified program exactly, so the donated
    pools ping-pong between the two executables without a layout shift, and
    the decode lane's float program is identical — switching programs is
    invisible to the token streams."""
    rules = prune_for_mesh(rules, mesh)
    p_shard, _ = make_state_shardings(model, mesh, rules, None)
    conv_shard, ssm_shard = slot_state_shardings(model, mesh, rules)
    slot_shard = NamedSharding(mesh, rules.spec(("batch",)))
    row_shard = NamedSharding(mesh, rules.spec(("batch", None)))

    def ssm_decode_only_step(params, conv_pool, ssm_pool, dec_state_idx,
                             dec_tokens, dec_sampling, dec_keys):
        with activation_rules(rules):
            with matmul_dispatch(decode_matmul_table, interpret=interpret):
                logits, conv_pool, ssm_pool = model.decode_step_slots(
                    params, conv_pool, ssm_pool, dec_state_idx, dec_tokens)
        nxt = sample_tokens(logits[:, -1], dec_sampling, dec_keys)
        return nxt, conv_pool, ssm_pool

    return jax.jit(
        ssm_decode_only_step,
        in_shardings=(p_shard, conv_shard, ssm_shard, slot_shard, row_shard,
                      None, None),
        out_shardings=(None, conv_shard, ssm_shard),
        donate_argnums=(1, 2),
    )


def jit_ssm_commit_state(model, mesh: Mesh, rules: ShardingRules):
    """(conv_pool, ssm_pool, conv, ssm, rows) -> (conv_pool, ssm_pool)

    Scatter up to S resuming requests' per-layer state (conv
    (L, S, W-1, conv_dim), ssm (L, S, nh, hd, n)) into pool rows `rows`
    ((S,) entries; padding entries point at the null row 0 with zero
    payloads — zeros over zeros, never read) — the ssm resume path:
    preempted requests' swapped-out state read back from the host buffers
    into their freshly claimed rows.  `rows` is traced data, so exactly
    one shape ever traces, and a burst of K swap-ins lands in ceil(K / S)
    invocations.  Donates the pools."""
    rules = prune_for_mesh(rules, mesh)
    conv_shard, ssm_shard = slot_state_shardings(model, mesh, rules)

    def commit(conv_pool, ssm_pool, conv, ssm, rows):
        conv_pool = conv_pool.at[:, rows].set(conv.astype(conv_pool.dtype))
        ssm_pool = ssm_pool.at[:, rows].set(ssm.astype(ssm_pool.dtype))
        return conv_pool, ssm_pool

    return jax.jit(commit,
                   in_shardings=(conv_shard, ssm_shard, None, None, None),
                   out_shardings=(conv_shard, ssm_shard),
                   donate_argnums=(0, 1))
