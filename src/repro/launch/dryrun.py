import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod) out
     of 512 forced host devices,
  2. lowers the real train_step / prefill_step / serve_step with the
     baseline sharding rules (`rules_for_shape`),
  3. compiles it — sharding mismatches, un-partitionable ops and
     compile-time OOMs fail HERE, which is the point,
  4. records memory_analysis / cost_analysis / per-collective byte counts
     (parsed from the optimized HLO) into a JSON artifact for the roofline
     analysis (benchmarks/bench_roofline.py + EXPERIMENTS.md).

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out artifacts/dryrun]
"""

import argparse
import dataclasses
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.configs import SHAPES, get_config, input_specs, runnable, REGISTRY
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (
    TrainConfig,
    cost_dict,
    jit_prefill_step,
    jit_serve_step,
    jit_train_step,
    make_state_shardings,
    cache_shardings,
    rules_for_shape,
)
from repro.models import build_model

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Per-collective operand bytes (per-device shapes in partitioned HLO).

    For each collective instruction we sum its *operand* tensor sizes (the
    bytes placed on the wire by this device); `x chips` gives the global
    wire bytes used in the roofline's collective term.
    """
    per_kind: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"^(?:ROOT )?%?[\w.\-]+ = ", s)
        if not m:
            continue
        kind = None
        rest = s[m.end():]
        for k in _COLLECTIVES:
            if re.search(rf"\b{k}(-start|-done)?\(", rest):
                kind = k
                break
        if kind is None or f"{kind}-done(" in rest:
            continue  # -done carries no new bytes
        paren = rest.find("(")
        shapes = _SHAPE_RE.findall(rest[paren:])
        operand_bytes = sum(_shape_bytes(d, dims) for d, dims in shapes)
        if operand_bytes == 0:  # operands printed without types: use result
            shapes = _SHAPE_RE.findall(rest[:paren])
            operand_bytes = sum(_shape_bytes(d, dims) for d, dims in shapes)
        per_kind[kind] += operand_bytes
        counts[kind] += 1
    total = sum(per_kind.values())
    return {"per_kind_bytes": per_kind, "counts": counts, "total_bytes": total}


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               train_cfg: Optional[TrainConfig] = None) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    skip = runnable(cfg, shape)
    if skip:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skip", "reason": skip}

    mesh = make_production_mesh(multi_pod=multi_pod)
    if os.environ.get("REPRO_LAYERS"):
        # reduced-layer lowering for per-layer cost extrapolation on cells
        # whose full unrolled cost program is compile-time prohibitive
        cfg = dataclasses.replace(cfg, n_layers=int(os.environ["REPRO_LAYERS"]))
    if os.environ.get("REPRO_CAPF"):
        cfg = dataclasses.replace(
            cfg, capacity_factor=float(os.environ["REPRO_CAPF"]))
    if shape.kind == "train":
        # baseline activation-checkpoint policy for training lowerings:
        # 'full' = save only layer inputs, recompute the block in backward
        # (the §Perf hillclimb compares 'dots'/'none' per cell).
        cfg = dataclasses.replace(cfg, remat=os.environ.get("REPRO_REMAT", "full"))
    model = build_model(cfg)
    rules = rules_for_shape(cfg, shape, mesh)
    # §Perf hillclimb hook: REPRO_RULES="kv_seq=model,ffn=,heads=data" etc.
    overrides = os.environ.get("REPRO_RULES", "")
    if overrides:
        kv = {}
        for item in overrides.split(","):
            k, _, v = item.partition("=")
            v = v.strip()
            kv[k.strip()] = tuple(v.split("+")) if "+" in v else (v or None)
        rules = rules.replace(**kv)
    train_cfg = train_cfg or TrainConfig(
        microbatches=int(os.environ.get("REPRO_MICROBATCHES", "4")),
        zero1=True)
    do_cost = os.environ.get("REPRO_COST_PROGRAM", "1") == "1"
    t0 = time.perf_counter()

    def _lower(cost_program: bool):
        from repro.models import runmode
        with runmode.cost_mode(cost_program):
            if shape.kind == "train":
                from repro.optim import adamw_init
                opt_shapes = jax.eval_shape(adamw_init, params_shapes)
                tc = (dataclasses.replace(train_cfg, microbatches=1)
                      if cost_program else train_cfg)
                fn = jit_train_step(model, mesh, rules, tc, batch_specs)
                return fn.lower(params_shapes, opt_shapes, batch_specs)
            if shape.kind == "prefill":
                fn = jit_prefill_step(model, mesh, rules, batch_specs,
                                      max_seq=shape.seq_len,
                                      batch=shape.global_batch)
                return fn.lower(params_shapes, batch_specs)
            b = shape.global_batch
            cache_shapes = jax.eval_shape(
                lambda: model.init_cache(b, shape.seq_len))
            fn = jit_serve_step(model, mesh, rules, b, shape.seq_len)
            tok = jax.ShapeDtypeStruct((b, 1), np.int32)
            return fn.lower(params_shapes, cache_shapes, tok)

    with mesh:
        params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        batch_specs = input_specs(cfg, shape)

        # ---- deploy program: compile proof + memory analysis
        lowered = _lower(cost_program=False)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0
        mem = compiled.memory_analysis()

        # ---- cost program: unrolled scans so cost_analysis counts every
        # layer; direct attention/loss so chunk scans don't hide FLOPs.
        t0 = time.perf_counter()
        cost_meta = {"method": "unrolled"}
        if do_cost:
            try:
                cost_compiled = _lower(cost_program=True).compile()
                cost = cost_dict(cost_compiled.cost_analysis())
                coll = collective_bytes(cost_compiled.as_text())
                del cost_compiled
            except Exception as e:  # fall back to the scanned program
                cost_meta = {"method": f"scanned-fallback ({e})"}
                cost = cost_dict(compiled.cost_analysis())
                coll = collective_bytes(compiled.as_text())
        else:
            cost_meta = {"method": "scanned"}
            cost = cost_dict(compiled.cost_analysis())
            coll = collective_bytes(compiled.as_text())
        t_cost = time.perf_counter() - t0

    n_dev = int(np.prod(list(mesh.shape.values())))
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "cost_program_s": round(t_cost, 2),
        "cost_method": cost_meta["method"],
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes_per_device": (
                getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0)),
        },
        "cost": {
            "flops_per_device": float(cost.get("flops", 0.0)),
            "transcendentals": float(cost.get("transcendentals", 0.0)),
            "bytes_accessed_per_device": float(cost.get("bytes accessed", 0.0)),
        },
        "collectives": coll,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "tokens": (shape.global_batch * shape.seq_len
                   if shape.kind != "decode" else shape.global_batch),
        "kind": shape.kind,
    }
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(REGISTRY) + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--continue-on-error", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = []
    archs = list(REGISTRY) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                cells.append((arch, shape, mp))

    failures = 0
    for arch, shape, mp in cells:
        mesh_name = "2x16x16" if mp else "16x16"
        tag = f"{arch}__{shape}__{mesh_name}"
        out_path = os.path.join(args.out, tag + ".json")
        if os.path.exists(out_path):
            print(f"[cached] {tag}")
            continue
        print(f"[lower+compile] {tag} ...", flush=True)
        try:
            res = lower_cell(arch, shape, mp)
        except Exception as e:
            failures += 1
            res = {"arch": arch, "shape": shape, "mesh": mesh_name,
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
            print(f"  ERROR: {e}")
            if not args.continue_on_error:
                with open(out_path, "w") as f:
                    json.dump(res, f, indent=1)
                raise
        with open(out_path, "w") as f:
            json.dump(res, f, indent=1)
        if res["status"] == "ok":
            print(f"  ok: compile {res['compile_s']}s, "
                  f"{res['cost']['flops_per_device']:.3e} flops/dev, "
                  f"{res['memory']['peak_bytes_per_device'] / 2**30:.2f} GiB/dev, "
                  f"{res['collectives']['total_bytes'] / 2**20:.1f} MiB collectives/dev")
        elif res["status"] == "skip":
            print(f"  {res['reason']}")
    print(f"done; {failures} failures")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
