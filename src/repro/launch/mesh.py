"""Production mesh builders.

`make_production_mesh` is a FUNCTION (not module state) so importing this
module never touches jax device state; `dryrun.py` sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax use.

Mesh axes:
  pod    — crosses the data-centre network (DCN); only DP gradient
           reductions ride it.
  data   — in-pod data parallel / ZeRO-1 / sequence parallel.
  model  — in-pod tensor/expert parallel; all TP collectives stay on ICI.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Optional[Tuple[str, ...]] = None):
    """General mesh helper for tests/examples (e.g. (2, 2) on 4 host devs)."""
    if axes is None:
        axes = ("data", "model") if len(shape) == 2 else ("pod", "data", "model")
    return jax.make_mesh(shape, axes)


def single_device_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def mesh_axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1
