"""Production mesh builders.

`make_production_mesh` is a FUNCTION (not module state) so importing this
module never touches jax device state; `dryrun.py` sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax use.

Mesh axes:
  pod    — crosses the data-centre network (DCN); only DP gradient
           reductions ride it.
  data   — in-pod data parallel / ZeRO-1 / sequence parallel.
  model  — in-pod tensor/expert parallel; all TP collectives stay on ICI.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Optional[Tuple[str, ...]] = None):
    """General mesh helper for tests/examples (e.g. (2, 2) on 4 host devs)."""
    if axes is None:
        axes = ("data", "model") if len(shape) == 2 else ("pod", "data", "model")
    return jax.make_mesh(shape, axes)


def single_device_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def tp_mesh(tp: int):
    """A (1, tp) data x model serving mesh over the FIRST `tp` local
    devices.  Deliberately a device SUBSET (jax.make_mesh insists on using
    every device), so one multi-device host process can race tp=1/2/4
    meshes side by side — the TP bench sweep and the cross-mesh
    byte-identity differential both depend on that."""
    import numpy as np
    from jax.sharding import Mesh

    devs = jax.devices()
    if tp > len(devs):
        raise ValueError(
            f"tp={tp} needs {tp} devices but the process has {len(devs)}; "
            "set --devices (repro.platform) before the first jax import")
    return Mesh(np.asarray(devs[:tp]).reshape(1, tp), ("data", "model"))


def mesh_axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1
