"""zamba2-1.2b [hybrid] — Zamba2 1.2B (arXiv:2411.15242; hf).

38 Mamba2 layers, d_model=2048, shared attention block (32H kv=32,
head_dim 64) applied every 6 layers with concat(hidden, embeddings) input;
d_ff=8192 for the shared block MLP; ssm_state=64; vocab=32000.
Sub-quadratic -> runs long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab=32000,
    ssm_state=64,
    ssm_head_dim=64,
    attn_every=6,
    sub_quadratic=True,
)
