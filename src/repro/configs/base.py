"""Model/architecture configuration.

One `ModelConfig` instance fully determines a model in the zoo.  Every
assigned architecture has a module in this package exporting `CONFIG`
(exact published numbers) plus the four standard input shapes; smoke tests
use `reduced()` versions of the same family.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 1e4
    mrope_sections: Tuple[int, ...] = ()   # vlm: (t, h, w) pairs, sum = head_dim/2
    norm: str = "rms"                # rms | layer
    act: str = "silu"
    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0
    n_shared_experts: int = 0
    norm_topk: bool = True
    capacity_factor: float = 1.25
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    ssm_chunk: int = 256
    # hybrid (zamba2): one shared attention block applied every `attn_every`
    attn_every: int = 0
    # encoder-decoder (whisper): encoder layer count + stub frontend length
    n_enc_layers: int = 0
    enc_seq: int = 0
    # vlm (qwen2-vl): stub patch embeddings prepended to the sequence
    n_vision_tokens: int = 0
    # compute policy
    dtype: str = "bfloat16"
    remat: str = "none"              # none | dots | full
    sub_quadratic: bool = False      # may run long_500k

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(1, self.n_kv_heads)

    def reduced(self, **overrides) -> "ModelConfig":
        """Same family, laptop scale — used by the per-arch smoke tests."""
        small: Dict = dict(
            n_layers=min(self.n_layers, 2 if self.family != "hybrid" else 4),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) or 2,
            head_dim=32,
            d_ff=256,
            vocab=512,
        )
        if self.n_experts:
            small.update(n_experts=8, top_k=min(self.top_k, 2), d_expert=64)
        if self.ssm_state:
            small.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
        if self.attn_every:
            small.update(attn_every=2)
        if self.n_enc_layers:
            small.update(n_enc_layers=2, enc_seq=32)
        if self.n_vision_tokens:
            small.update(n_vision_tokens=8)
        if self.mrope_sections:
            small.update(mrope_sections=(4, 6, 6))  # head_dim 32 -> 16 pairs
        small.update(overrides)
        return dataclasses.replace(self, **small)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), used for the
        MODEL_FLOPS = 6·N·D roofline term."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab * d * 2  # in + out (untied)
        if self.family == "ssm":
            d_in = self.ssm_expand * d
            nh = d_in // self.ssm_head_dim
            conv_dim = d_in + 2 * self.ssm_state
            per = (d * (2 * d_in + 2 * self.ssm_state + nh)  # in_proj
                   + self.conv_width * conv_dim + 3 * nh + d_in + d_in * d)
            return emb + L * per
        attn = d * (self.n_heads * self.hd) + 2 * d * (self.n_kv_heads * self.hd) \
            + (self.n_heads * self.hd) * d
        if self.family in ("dense", "vlm"):
            return emb + L * (attn + 3 * d * self.d_ff)
        if self.family == "moe":
            route = d * self.n_experts
            experts = self.n_experts * 3 * d * self.d_expert
            shared = self.n_shared_experts * 3 * d * self.d_expert
            return emb + L * (attn + route + experts + shared)
        if self.family == "hybrid":
            d_in = self.ssm_expand * d
            nh = d_in // self.ssm_head_dim
            conv_dim = d_in + 2 * self.ssm_state
            mamba = (d * (2 * d_in + 2 * self.ssm_state + nh)
                     + self.conv_width * conv_dim + 3 * nh + d_in + d_in * d)
            return emb + L * mamba + (attn + 3 * d * self.d_ff + 2 * d * d)
        if self.family == "encdec":
            enc = self.n_enc_layers * (attn + 2 * d * self.d_ff)
            dec = L * (2 * attn + 2 * d * self.d_ff)
            return emb + enc + dec
        raise ValueError(self.family)

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.n_layers
        emb = self.vocab * d * 2
        attn = d * (self.n_heads * self.hd) + 2 * d * (self.n_kv_heads * self.hd) \
            + (self.n_heads * self.hd) * d
        active = (self.top_k + self.n_shared_experts) * 3 * d * self.d_expert
        return emb + L * (attn + d * self.n_experts + active)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One benchmark cell: (kind, seq_len, global_batch)."""

    name: str
    kind: str        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}
