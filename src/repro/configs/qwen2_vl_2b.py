"""qwen2-vl-2b [vlm] — Qwen2-VL 2B backbone (arXiv:2409.12191; hf).

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936; M-RoPE with
(t, h, w) sections (16, 24, 24) over head_dim 128; dynamic-resolution vision
frontend is a STUB — `input_specs` provides 256 pre-computed patch
embeddings prepended to the sequence.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab=151936,
    rope_theta=1e6,
    mrope_sections=(16, 24, 24),
    n_vision_tokens=256,
)
