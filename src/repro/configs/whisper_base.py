"""whisper-base [audio] — Whisper base (arXiv:2212.04356; unverified).

6 encoder + 6 decoder layers, d_model=512 8H (kv=8) d_ff=2048 vocab=51865;
enc-dec with layer-norm + GELU; conv audio frontend is a STUB —
`input_specs` provides 1500 pre-computed frame embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab=51865,
    norm="layer",
    act="gelu",
    n_enc_layers=6,
    enc_seq=1500,
)
