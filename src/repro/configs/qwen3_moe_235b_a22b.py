"""qwen3-moe-235b-a22b [moe] — Qwen3-MoE 235B-A22B (hf:Qwen family; hf).

94L d_model=4096 64H (GQA kv=4) expert d_ff=1536 vocab=151936;
MoE 128 experts top-8, no shared experts; qk_norm.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab=151936,
    rope_theta=1e6,
    qk_norm=True,
    n_experts=128,
    top_k=8,
    d_expert=1536,
    norm_topk=True,
)
