"""Architecture registry: 10 assigned archs + the paper's own ResNet-18.

`get_config(name)` returns the exact published ModelConfig;
`input_specs(cfg, shape)` returns ShapeDtypeStruct stand-ins for every model
input of a benchmark cell (weak-type-correct, shardable, no allocation);
`runnable(cfg, shape)` implements the documented skip matrix
(long_500k -> sub-quadratic archs only).
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ModelConfig, ShapeSpec
from repro.configs import (
    granite_3_8b,
    internlm2_20b,
    mamba2_2_7b,
    qwen2_moe_a2_7b,
    qwen2_vl_2b,
    qwen3_1_7b,
    qwen3_moe_235b_a22b,
    starcoder2_15b,
    whisper_base,
    zamba2_1_2b,
)

REGISTRY: Dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        qwen2_vl_2b,
        qwen3_1_7b,
        internlm2_20b,
        granite_3_8b,
        starcoder2_15b,
        qwen3_moe_235b_a22b,
        qwen2_moe_a2_7b,
        zamba2_1_2b,
        mamba2_2_7b,
        whisper_base,
    )
}

ARCH_NAMES = list(REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {ARCH_NAMES}")
    return REGISTRY[name]


def runnable(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    """None if the (arch, shape) cell runs; else the documented skip reason."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("SKIP: pure full-attention arch — 512k-token cache/prefill is "
                "not sub-quadratic (see DESIGN.md §Arch-applicability)")
    return None


def input_specs(cfg: ModelConfig, shape: ShapeSpec, for_loss: bool = True):
    """ShapeDtypeStructs for the model-input batch of one cell."""
    b = shape.global_batch
    f32 = jnp.float32
    i32 = jnp.int32

    if shape.kind == "train":
        s = shape.seq_len
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if for_loss:
            batch["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        if cfg.family == "vlm":
            batch["vision_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_vision_tokens, cfg.d_model), f32)
        if cfg.family == "encdec":
            batch["audio_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.enc_seq, cfg.d_model), f32)
        return batch

    if shape.kind == "prefill":
        s = shape.seq_len
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.family == "vlm":
            batch["vision_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_vision_tokens, cfg.d_model), f32)
        if cfg.family == "encdec":
            batch["audio_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.enc_seq, cfg.d_model), f32)
        return batch

    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}

    raise ValueError(shape.kind)


__all__ = [
    "REGISTRY", "ARCH_NAMES", "SHAPES", "ModelConfig", "ShapeSpec",
    "get_config", "input_specs", "runnable",
]
