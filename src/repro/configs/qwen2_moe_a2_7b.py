"""qwen2-moe-a2.7b [moe] — Qwen1.5-MoE A2.7B (hf:Qwen/Qwen1.5-MoE-A2.7B; hf).

24L d_model=2048 16H (GQA kv=16) expert d_ff=1408 vocab=151936;
60 routed experts top-4 + 4 shared experts.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab=151936,
    rope_theta=1e6,
    n_experts=60,
    top_k=4,
    d_expert=1408,
    n_shared_experts=4,
    norm_topk=False,
)
