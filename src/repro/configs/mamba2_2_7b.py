"""mamba2-2.7b [ssm] — Mamba2 2.7B, SSD (arXiv:2405.21060; unverified).

64L d_model=2560, attention-free; ssm_state=128, head_dim 64, expand 2,
conv width 4; vocab=50280.  Sub-quadratic -> runs long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    conv_width=4,
    sub_quadratic=True,
)
