"""Tuned Pallas TPU kernels — WPK's generated-code lane.

The paper's compute hot-spots are exactly these: convolution (its headline
benchmark), the matmul family, and fused operators produced by graph fusion.
Each kernel module pairs with `ref.py` (pure-jnp oracle) and is exposed via
`ops.py` (jit-friendly wrappers with tuned-config dispatch).  On this
CPU-only container all kernels run in interpret mode; on TPU
`interpret=False` compiles them natively with the tuned BlockSpec tiling.
"""

from repro.kernels import ops, ref
from repro.kernels.ops import (
    attention,
    attention_decode,
    conv2d,
    fused_elementwise,
    matmul,
)

__all__ = [
    "ops",
    "ref",
    "matmul",
    "conv2d",
    "attention",
    "attention_decode",
    "fused_elementwise",
]
