"""Tuned Pallas TPU matmul — the WPK "generated code" for the matmul family.

Schedule knobs (from `MatmulTemplate`): block sizes (bm, bn, bk), grid-major
`order` ('mn' keeps an A row-band resident across the n sweep, 'nm' keeps a
B column-band resident), and `k_unroll` (compiler hint only — the MXU
pipeline depth; it does not change the math).

The K axis is the innermost ('arbitrary') grid dimension with an f32 VMEM
accumulator; the epilogue optionally fuses bias + activation (the graph
fusion pass emits `fused_matmul` nodes that land here — one kernel launch for
matmul+bias+act, the paper's in-placed fused-operator implementation).

Inputs are padded to block multiples by the `ops.py` wrapper, so zero
K-padding contributes nothing to the accumulator and M/N padding is sliced
off the output.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pallas_compat import tpu_compiler_params, vmem_scratch
from repro.kernels.ref import apply_activation


def _compiler_params(order):
    # TPU compiler params are advisory; interpret mode ignores them.
    sem = ("parallel", "parallel", "arbitrary")
    return tpu_compiler_params(dimension_semantics=sem)


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, kt: int,
                   activation: Optional[str], out_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == kt - 1)
    def _epilogue():
        out = acc_ref[...]
        if b_ref is not None:
            out = out + b_ref[...].astype(jnp.float32)
        o_ref[...] = apply_activation(out, activation).astype(out_dtype)


def matmul_padded(
    x: jnp.ndarray,          # (M, K), M % bm == 0, K % bk == 0
    w: jnp.ndarray,          # (K, N), N % bn == 0
    bias: Optional[jnp.ndarray],  # (1, N) or None
    *,
    bm: int,
    bn: int,
    bk: int,
    order: str = "mn",
    k_unroll: int = 1,       # schedule hint; no effect on semantics
    activation: Optional[str] = None,
    out_dtype=None,
    interpret: bool = True,
) -> jnp.ndarray:
    m, k = x.shape
    _, n = w.shape
    mt, nt, kt = m // bm, n // bn, k // bk
    out_dtype = out_dtype or x.dtype

    if order == "mn":
        grid = (mt, nt, kt)
        xmap = lambda i, j, kk: (i, kk)
        wmap = lambda i, j, kk: (kk, j)
        omap = lambda i, j, kk: (i, j)
        bmap = lambda i, j, kk: (0, j)
    else:  # 'nm': n-major grid
        grid = (nt, mt, kt)
        xmap = lambda j, i, kk: (i, kk)
        wmap = lambda j, i, kk: (kk, j)
        omap = lambda j, i, kk: (i, j)
        bmap = lambda j, i, kk: (0, j)

    in_specs = [
        pl.BlockSpec((bm, bk), xmap),
        pl.BlockSpec((bk, bn), wmap),
    ]
    args = [x, w]
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, bn), bmap))
        args.append(bias)

    kernel = functools.partial(
        _matmul_kernel if bias is not None else _matmul_nobias_kernel,
        kt=kt, activation=activation, out_dtype=out_dtype,
    )
    kwargs = {}
    params = _compiler_params(order)
    if params is not None and not interpret:
        kwargs["compiler_params"] = params
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), omap),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[_acc_scratch(bm, bn)],
        interpret=interpret,
        **kwargs,
    )(*args)


def _acc_scratch(bm: int, bn: int):
    return vmem_scratch((bm, bn), jnp.float32)


def _matmul_nobias_kernel(x_ref, w_ref, o_ref, acc_ref, *, kt, activation, out_dtype):
    _matmul_kernel(x_ref, w_ref, None, o_ref, acc_ref, kt=kt,
                   activation=activation, out_dtype=out_dtype)
