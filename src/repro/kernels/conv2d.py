"""Tuned Pallas TPU conv2d — the paper's headline operator, rethought for TPU.

The paper's CUDA template carves the output into per-thread tiles
(T_x/T_y/T_z × Tile_x/Tile_y/Tile_z).  TPU has no thread blocks; the natural
mapping is an *implicit GEMM*: the kernel keeps one whole input image
resident in VMEM (HBM→VMEM once — the in-kernel im2col never materialises
the M×K patch matrix in HBM), carves the output into
(row_block rows × bn output channels) VMEM tiles, and drives the MXU with
(OW × Kh·Kw·Cin) @ (Kh·Kw·Cin × bn) dots assembled from statically-unrolled
Kh×Kw shifted slices.

Schedule knobs (from `Conv2dTemplate`): bn (output-channel block),
row_block (output rows per grid step, sharing one halo), plus bm/bk/order
which shape the *fallback* GEMM path used when the image does not fit VMEM
(`ops.conv2d` falls back to XLA patch extraction + the tuned matmul kernel).

Padding (SAME) is applied by the wrapper, so the kernel only sees VALID
convolutions on pre-padded inputs.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import apply_activation


def _conv_kernel(x_ref, w_ref, b_ref, o_ref, *, kh: int, kw: int, stride: int,
                 row_block: int, ow: int, activation: Optional[str], out_dtype):
    """One grid step: out rows [i*row_block, ...) × out channels block j for
    image n.  x_ref: (1, Hp, Wp, Cin); w_ref: (Kh*Kw*Cin, bn);
    o_ref: (1, row_block, OW, bn)."""
    i = pl.program_id(1)
    x = x_ref[0]                      # (Hp, Wp, Cin)
    cin = x.shape[-1]

    for r in range(row_block):        # static unroll over the row block
        base = (i * row_block + r) * stride
        # Assemble the (OW, Kh*Kw*Cin) patch matrix for this output row.
        cols = []
        for dh in range(kh):
            row = jax.lax.dynamic_slice_in_dim(x, base + dh, 1, axis=0)[0]  # (Wp, Cin)
            for dw in range(kw):
                span = (ow - 1) * stride + 1
                seg = jax.lax.dynamic_slice(row, (dw, 0), (span, cin))
                if stride > 1:
                    seg = seg[::stride]
                cols.append(seg)                          # (OW, Cin)
        patches = jnp.concatenate(cols, axis=-1)          # (OW, Kh*Kw*Cin)
        acc = jnp.dot(patches, w_ref[...], preferred_element_type=jnp.float32)
        if b_ref is not None:
            acc = acc + b_ref[...].astype(jnp.float32)
        o_ref[0, r] = apply_activation(acc, activation).astype(out_dtype)


def conv2d_direct(
    x: jnp.ndarray,                 # (N, Hp, Wp, Cin) — already padded
    w: jnp.ndarray,                 # (Kh, Kw, Cin, Cout)
    bias: Optional[jnp.ndarray],    # (1, Cout) or None
    *,
    stride: int = 1,
    bn: int = 128,
    row_block: int = 4,
    activation: Optional[str] = None,
    out_dtype=None,
    interpret: bool = True,
) -> jnp.ndarray:
    n, hp, wp, cin = x.shape
    kh, kw, _, cout = w.shape
    oh = (hp - kh) // stride + 1
    ow = (wp - kw) // stride + 1
    out_dtype = out_dtype or x.dtype

    bn = min(bn, max(128, cout))
    # pad channel dim of weights/bias to bn multiple
    cout_p = -(-cout // bn) * bn
    w2 = jnp.reshape(w, (kh * kw * cin, cout))
    if cout_p != cout:
        w2 = jnp.pad(w2, ((0, 0), (0, cout_p - cout)))
        if bias is not None:
            bias = jnp.pad(bias, ((0, 0), (0, cout_p - cout)))
    # pad rows to row_block multiple
    oh_p = -(-oh // row_block) * row_block
    hp_need = (oh_p - 1) * stride + kh
    if hp_need > hp:
        x = jnp.pad(x, ((0, 0), (0, hp_need - hp), (0, 0), (0, 0)))
        hp = hp_need

    grid = (n, oh_p // row_block, cout_p // bn)
    kernel = functools.partial(
        _conv_kernel if bias is not None else _conv_nobias_kernel,
        kh=kh, kw=kw, stride=stride, row_block=row_block, ow=ow,
        activation=activation, out_dtype=out_dtype,
    )
    in_specs = [
        pl.BlockSpec((1, hp, wp, cin), lambda nn, i, j: (nn, 0, 0, 0)),
        pl.BlockSpec((kh * kw * cin, bn), lambda nn, i, j: (0, j)),
    ]
    args = [x, w2]
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, bn), lambda nn, i, j: (0, j)))
        args.append(bias)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, row_block, ow, bn), lambda nn, i, j: (nn, i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((n, oh_p, ow, cout_p), out_dtype),
        interpret=interpret,
    )(*args)
    return out[:, :oh, :, :cout]


def _conv_nobias_kernel(x_ref, w_ref, o_ref, **kw):
    _conv_kernel(x_ref, w_ref, None, o_ref, **kw)
