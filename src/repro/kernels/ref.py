"""Pure-jnp oracles for every Pallas kernel.

Every kernel in this package is tested shape/dtype-swept against these
functions with `assert_allclose`.  They are deliberately written in the most
obvious way possible — no cleverness, no blocking — so that a mismatch
always indicts the kernel, not the oracle.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def apply_activation(x: jnp.ndarray, activation: Optional[str]) -> jnp.ndarray:
    if activation in (None, "none", "identity"):
        return x
    return {
        "relu": jax.nn.relu,
        "gelu": jax.nn.gelu,
        "silu": jax.nn.silu,
        "tanh": jnp.tanh,
        "sigmoid": jax.nn.sigmoid,
    }[activation](x)


def matmul_ref(x, w, bias=None, activation=None, out_dtype=None):
    out_dtype = out_dtype or x.dtype
    out = jnp.dot(x, w, preferred_element_type=jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return apply_activation(out, activation).astype(out_dtype)


def conv2d_ref(x, w, bias=None, stride=1, padding="SAME", activation=None):
    """NHWC x (Kh,Kw,Cin,Cout) -> NHWC."""
    dn = jax.lax.conv_dimension_numbers(x.shape, w.shape, ("NHWC", "HWIO", "NHWC"))
    out = jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding, dimension_numbers=dn,
        preferred_element_type=jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return apply_activation(out, activation).astype(x.dtype)


def attention_ref(q, k, v, causal=True, scale=None):
    """(B, Sq, H, D) x (B, Skv, Hkv, D) -> (B, Sq, H, D), GQA-aware."""
    h, hkv = q.shape[2], k.shape[2]
    if h != hkv:
        rep = h // hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = scale or (1.0 / np.sqrt(q.shape[-1]))
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        sq, skv = q.shape[1], k.shape[1]
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


def attention_decode_ref(q, k, v, lengths=None, scale=None):
    """Single-token decode: q (B, H, D) against cache k/v (B, S, Hkv, D).
    `lengths` (B,) masks cache positions >= length."""
    h, hkv = q.shape[1], k.shape[1 + 1]
    if h != hkv:
        rep = h // hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = scale or (1.0 / np.sqrt(q.shape[-1]))
    logits = jnp.einsum("bhd,bkhd->bhk", q, k).astype(jnp.float32) * scale
    if lengths is not None:
        pos = jnp.arange(k.shape[1])[None, None, :]
        logits = jnp.where(pos < lengths[:, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhk,bkhd->bhd", p, v)


def fused_elementwise_ref(x, chain, extras=()):
    """Chain of elementwise stages; binary stages pop from `extras`."""
    extras = list(extras)
    for stage in chain:
        op = stage["op"] if isinstance(stage, dict) else stage
        if op in ("add", "mul", "sub", "div"):
            rhs = extras.pop(0)
            x = {"add": jnp.add, "mul": jnp.multiply,
                 "sub": jnp.subtract, "div": jnp.divide}[op](x, rhs)
        else:
            x = apply_activation(x, op)
    return x
