"""Version-compat shim over `jax.experimental.pallas.tpu`.

The TPU compiler-params dataclass was renamed across JAX releases
(`CompilerParams` -> `TPUCompilerParams` -> back again in newer trees), and
on CPU-only builds the TPU module may not import at all.  Every kernel
module goes through this shim instead of touching `pltpu` directly, so a
JAX upgrade is a one-file fix:

  * `pltpu`                  — the TPU pallas module, or None when absent;
  * `tpu_compiler_params()`  — construct compiler params by keyword,
                               whichever class name this JAX exposes
                               (returns None when unavailable);
  * `vmem_scratch()`         — a VMEM scratch allocation, falling back to
                               `pl.MemoryRef` for pure-interpret setups.
"""

from __future__ import annotations

from typing import Any, Optional

import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except Exception:  # pragma: no cover - CPU-only wheels without the TPU module
    pltpu = None

# The compiler-params class under whichever name this JAX release uses.
_COMPILER_PARAMS_CLS = None
if pltpu is not None:
    for _name in ("TPUCompilerParams", "CompilerParams"):
        _COMPILER_PARAMS_CLS = getattr(pltpu, _name, None)
        if _COMPILER_PARAMS_CLS is not None:
            break


def tpu_compiler_params(**kwargs: Any) -> Optional[Any]:
    """Build TPU compiler params from keywords; None if unsupported.

    Unknown keywords are dropped (older releases accept fewer fields) so
    callers can always pass the full set of hints they want.
    """
    if _COMPILER_PARAMS_CLS is None:
        return None
    fields = getattr(_COMPILER_PARAMS_CLS, "__dataclass_fields__", None)
    if fields is not None:
        kwargs = {k: v for k, v in kwargs.items() if k in fields}
    try:
        return _COMPILER_PARAMS_CLS(**kwargs)
    except TypeError:  # pragma: no cover - exotic signature drift
        return None


def vmem_scratch(shape, dtype=jnp.float32):
    """A VMEM scratch ref, degrading to pl.MemoryRef without the TPU module."""
    if pltpu is not None:
        return pltpu.VMEM(shape, dtype)
    return pl.MemoryRef(shape, dtype)  # pragma: no cover
