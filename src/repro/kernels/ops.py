"""Public jit'd wrappers over the tuned Pallas kernels.

These are the functions the WPK runtime engine and the model zoo call.  Each
wrapper:

  * accepts the tuned `config` dict produced by the automated searches
    (None -> a safe aligned default),
  * handles padding/reshaping so the kernels only ever see block-aligned
    shapes (zero K/KV padding is mathematically inert; M/N padding is sliced
    off),
  * exposes `interpret=` — True on this CPU container, False on real TPU,
  * falls back to the XLA lowering where a kernel is out of its envelope
    (e.g. image too large for whole-image VMEM residency in conv2d_direct).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref as _ref
from repro.kernels.attention import (
    flash_attention_padded,
    flash_decode_paged,
    flash_decode_padded,
    flash_prefill_paged,
)
from repro.kernels.conv2d import conv2d_direct
from repro.kernels.fused import fused_elementwise as _fused_elementwise
from repro.kernels.matmul import matmul_padded

Config = Optional[Dict[str, Any]]

_DEF_MM = {"bm": 128, "bn": 128, "bk": 128, "order": "mn", "k_unroll": 1}
_DEF_ATT = {"block_q": 128, "block_kv": 128}


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    target = -(-size // mult) * mult
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads)


def matmul(
    x: jnp.ndarray,                # (..., K)
    w: jnp.ndarray,                # (K, N)
    bias: Optional[jnp.ndarray] = None,
    *,
    config: Config = None,
    activation: Optional[str] = None,
    out_dtype=None,
    interpret: bool = True,
) -> jnp.ndarray:
    cfg = dict(_DEF_MM, **(config or {}))
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = w.shape[-1]
    x2 = x.reshape(-1, k)
    m = x2.shape[0]
    bm = min(cfg["bm"], max(8, m))
    bn, bk = cfg["bn"], cfg["bk"]
    x2 = _pad_to(_pad_to(x2, 0, bm), 1, bk)
    w2 = _pad_to(_pad_to(w, 0, bk), 1, bn)
    b2 = None
    if bias is not None:
        b2 = _pad_to(bias.reshape(1, -1), 1, bn)
    out = matmul_padded(
        x2, w2, b2, bm=bm, bn=bn, bk=bk, order=cfg.get("order", "mn"),
        k_unroll=cfg.get("k_unroll", 1), activation=activation,
        out_dtype=out_dtype or x.dtype, interpret=interpret)
    return out[:m, :n].reshape(*lead, n)


def conv2d(
    x: jnp.ndarray,                   # NCHW or NHWC
    w: jnp.ndarray,                   # OIHW (NCHW) or HWIO (NHWC)
    bias: Optional[jnp.ndarray] = None,
    *,
    stride: int = 1,
    padding: str = "SAME",
    layout: str = "NHWC",
    activation: Optional[str] = None,
    config: Config = None,
    interpret: bool = True,
    vmem_limit: int = 64 * 1024 * 1024,
) -> jnp.ndarray:
    cfg = {**_DEF_MM, "row_block": 4, **(config or {})}
    if layout == "NCHW":
        x = jnp.transpose(x, (0, 2, 3, 1))
        w = jnp.transpose(w, (2, 3, 1, 0))
    n, h, wd, cin = x.shape
    kh, kw, _, cout = w.shape

    if padding == "SAME":
        oh = -(-h // stride)
        ow = -(-wd // stride)
        pad_h = max(0, (oh - 1) * stride + kh - h)
        pad_w = max(0, (ow - 1) * stride + kw - wd)
        x = jnp.pad(x, ((0, 0), (pad_h // 2, pad_h - pad_h // 2),
                        (pad_w // 2, pad_w - pad_w // 2), (0, 0)))

    img_bytes = x.shape[1] * x.shape[2] * cin * x.dtype.itemsize
    if img_bytes <= vmem_limit:
        out = conv2d_direct(
            x, w, bias.reshape(1, -1) if bias is not None else None,
            stride=stride, bn=cfg["bn"], row_block=cfg.get("row_block", 4),
            activation=activation, interpret=interpret)
    else:
        # Fallback: XLA patch extraction + tuned Pallas GEMM.
        patches = jax.lax.conv_general_dilated_patches(
            x, (kh, kw), (stride, stride), "VALID",
            dimension_numbers=jax.lax.conv_dimension_numbers(
                x.shape, w.shape, ("NHWC", "HWIO", "NHWC")))
        po, ph, pw_, pc = patches.shape
        out = matmul(
            patches.reshape(-1, pc),
            w.transpose(2, 0, 1, 3).reshape(pc, cout),
            bias, config=cfg, activation=activation, interpret=interpret,
        ).reshape(po, ph, pw_, cout)

    if layout == "NCHW":
        out = jnp.transpose(out, (0, 3, 1, 2))
    return out


def attention(
    q: jnp.ndarray,                  # (B, Sq, H, D)
    k: jnp.ndarray,                  # (B, Skv, Hkv, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    config: Config = None,
    interpret: bool = True,
) -> jnp.ndarray:
    cfg = dict(_DEF_ATT, **(config or {}))
    b, sq, h, d = q.shape
    _, skv, hkv, _ = k.shape
    q_per_kv = h // hkv
    bq = min(cfg["block_q"], max(128, sq))
    bkv = min(cfg["block_kv"], max(128, skv))

    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, skv, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, skv, d)
    sq_p = -(-sq // bq) * bq
    skv_p = -(-skv // bkv) * bkv
    qf = _pad_to(qf, 1, bq)
    kf = _pad_to(kf, 1, bkv)
    vf = _pad_to(vf, 1, bkv)
    if skv_p != skv and not causal:
        # mask the padded tail by pushing keys to -inf via a causal=False trick:
        # zero-pad keys produce logits*scale = 0; safer to slice after ref-style
        # handling — we instead rely on causal masking or exact multiples in
        # production paths; for the general case fall back to the oracle.
        return _ref.attention_ref(q, k, v, causal=causal, scale=scale)
    out = flash_attention_padded(
        qf, kf, vf, block_q=bq, block_kv=bkv, causal=causal, scale=scale,
        q_per_kv=q_per_kv, interpret=interpret)
    out = out[:, :sq].reshape(b, h, sq, d).transpose(0, 2, 1, 3)
    return out


def attention_decode(
    q: jnp.ndarray,        # (B, H, D)
    k_cache: jnp.ndarray,  # (B, S, Hkv, D)
    v_cache: jnp.ndarray,
    lengths: jnp.ndarray,  # (B,)
    *,
    scale: Optional[float] = None,
    config: Config = None,
    interpret: bool = True,
) -> jnp.ndarray:
    cfg = dict(_DEF_ATT, **(config or {}))
    b, h, d = q.shape
    _, s, hkv, _ = k_cache.shape
    bkv = min(cfg["block_kv"], max(128, s))
    group = h // hkv

    outs = []
    for g in range(hkv):  # per-KV-head grouping keeps the cache un-replicated
        qg = q[:, g * group : (g + 1) * group]          # (B, group, D)
        kg = _pad_to(k_cache[:, :, g], 1, bkv)          # (B, S_p, D)
        vg = _pad_to(v_cache[:, :, g], 1, bkv)
        outs.append(flash_decode_padded(qg, kg, vg, lengths, block_kv=bkv,
                                        scale=scale, interpret=interpret))
    return jnp.concatenate(outs, axis=1)


def attention_decode_paged(
    q: jnp.ndarray,             # (B, H, D)
    k_pool: jnp.ndarray,        # (num_blocks, block_size, Hkv, D)
    v_pool: jnp.ndarray,
    lengths: jnp.ndarray,       # (B,) valid context lengths (incl. new token)
    block_tables: jnp.ndarray,  # (B, nbt) physical block ids per slot
    *,
    scale: Optional[float] = None,
    config: Config = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Paged decode attention over the block pool (continuous-batching lane).

    Same per-KV-head grouping as `attention_decode`, but the cache argument
    is the shared physical pool + per-slot block tables instead of a dense
    per-sequence cache, so admission of a new request only rewrites the
    (host-built) tables — shapes, and therefore the compiled program, are
    invariant."""
    del config  # block geometry is fixed by the pool; nothing to tune yet
    b, h, d = q.shape
    hkv = k_pool.shape[2]
    group = h // hkv

    outs = []
    for g in range(hkv):
        qg = q[:, g * group : (g + 1) * group]          # (B, group, D)
        kg = k_pool[:, :, g]                            # (nb, bs, D)
        vg = v_pool[:, :, g]
        outs.append(flash_decode_paged(qg, kg, vg, lengths, block_tables,
                                       scale=scale, interpret=interpret))
    return jnp.concatenate(outs, axis=1)


def attention_prefill_packed(
    q: jnp.ndarray,             # (1, C, H, D) packed chunk queries
    k_pool: jnp.ndarray,        # (num_blocks, block_size, Hkv, D)
    v_pool: jnp.ndarray,
    seg_tables: jnp.ndarray,    # (S, nbt) per-segment physical block ids
    seg_info: jnp.ndarray,      # (S, 3) int32 [row_offset, seg_len, kv_start]
    *,
    scale: Optional[float] = None,
    config: Config = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Segment-packed paged prefill attention over the block pool (the
    prefill lane of the unified serving step).

    Same per-KV-head grouping as `attention_decode_paged`, but the query
    buffer carries contiguous prompt segments from up to S requests: each
    row attends causally to every committed row of its OWN request (earlier
    chunks included, co-packed neighbours masked) through its segment's
    scalar-prefetched block table.  The descriptors are traced data —
    packing geometry never recompiles.  The tuned `config` contributes
    `block_q` (prompt positions per query tile); together with the segment
    count it fixes the kernel's block_q x max-segments grid, the knobs the
    plan's `prefill_chunk` stage races."""
    cfg = dict(_DEF_ATT, **(config or {}))
    _, c, h, d = q.shape
    hkv = k_pool.shape[2]
    group = h // hkv
    bq = min(cfg.get("block_q") or c, c)
    info = jnp.asarray(seg_info, jnp.int32)

    outs = []
    for g in range(hkv):  # per-KV-head grouping keeps the pool un-replicated
        qg = q[0, :, g * group: (g + 1) * group]        # (C, group, D)
        outs.append(flash_prefill_paged(
            qg, k_pool[:, :, g], v_pool[:, :, g], seg_tables, info,
            block_q=bq, scale=scale, interpret=interpret))
    return jnp.concatenate(outs, axis=1)[None]          # (1, C, H, D)


def attention_prefill_paged(
    q: jnp.ndarray,             # (1, C, H, D) one request's chunk queries
    k_pool: jnp.ndarray,        # (num_blocks, block_size, Hkv, D)
    v_pool: jnp.ndarray,
    block_tables: jnp.ndarray,  # (1, nbt) physical block ids
    chunk_start,                # scalar int32: rows committed before the chunk
    chunk_len,                  # scalar int32: real rows in this chunk
    *,
    scale: Optional[float] = None,
    config: Config = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Single-request chunked prefill — the S=1 special case of
    `attention_prefill_packed` (kept as the stable entry point for callers
    that carry one request's chunk per step)."""
    zero = jnp.zeros((), jnp.int32)
    seg_info = jnp.stack([zero, jnp.asarray(chunk_len, jnp.int32),
                          jnp.asarray(chunk_start, jnp.int32)])[None]
    return attention_prefill_packed(
        q, k_pool, v_pool, block_tables, seg_info,
        scale=scale, config=config, interpret=interpret)


def fused_elementwise(
    x: jnp.ndarray,
    chain: Sequence[Dict[str, Any]],
    extras: Sequence[jnp.ndarray] = (),
    *,
    config: Config = None,
    interpret: bool = True,
) -> jnp.ndarray:
    cfg = config or {}
    return _fused_elementwise(x, chain, extras,
                              block_rows=cfg.get("block_rows", 256),
                              interpret=interpret)
