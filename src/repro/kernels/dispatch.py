"""Backend dispatch: from an `InferencePlan` choice to a kernel callable.

The WPK plan records, per stage-qualified operator, WHICH lane won the race
(`xla` vs a tuned Pallas template) and the tuned schedule config.  This
module is the serve-time bridge that makes those choices executable:

  * a **lane registry** mapping a backend name to a callable with the
    uniform signature ``lane(x, w, *, config, activation, interpret)`` —
    `xla` lowers through a plain einsum/`@` (the vendor-library lane) and
    `pallas_matmul` through the tuned `ops.matmul` kernel, with the
    activation fused into the kernel epilogue where the template supports
    it (the XLA lane applies it afterwards, so numerics agree);
  * a **dispatch context** (`matmul_dispatch`) holding a per-stage table
    ``role -> (backend, config)`` for the model's named matmuls
    (``qkv_proj`` / ``mlp_up`` / ``mlp_down`` / ``lm_head``).  The context
    is consulted at *trace* time — the step builders in `repro.launch.steps`
    install it around the jitted program body, so the chosen lane is baked
    into the compiled program and costs nothing per step;
  * `dispatch_dense(role, x, w)` — what `models.common.dense` calls for a
    role-tagged projection.  With no active context (training, the fixed
    batch engine, any non-serve path) it is exactly ``x @ w``.

`PlanRouter.matmul_table(stage)` (see `repro.serve.router`) produces the
tables from a tuned serve plan; unknown roles and planless runs fall back to
the XLA lane, so the runtime stays correct, just untuned.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Callable, Dict, Optional, Tuple

import jax.numpy as jnp

from repro.kernels.ref import apply_activation

# (backend name, tuned config) — the executable projection of an OpChoice.
MatmulChoice = Tuple[str, Dict[str, Any]]
# role -> choice, one table per serve stage (prefill / decode).
MatmulTable = Dict[str, MatmulChoice]

# The model's routable matmul roles, mirroring the serve graph's
# stage-qualified node names (see repro.serve.router.build_serve_graph).
MATMUL_ROLES = ("qkv_proj", "mlp_up", "mlp_down", "lm_head")

LaneFn = Callable[..., jnp.ndarray]

_LANES: Dict[str, LaneFn] = {}


def register_lane(name: str):
    """Register a matmul lane under `name` (decorator)."""

    def deco(fn: LaneFn) -> LaneFn:
        _LANES[name] = fn
        return fn

    return deco


def lanes() -> Dict[str, LaneFn]:
    """Registered lane name -> callable (copy; mutate via register_lane)."""
    return dict(_LANES)


@register_lane("xla")
def xla_lane(x: jnp.ndarray, w: jnp.ndarray, *, config: Optional[Dict] = None,
             activation: Optional[str] = None,
             interpret: bool = True) -> jnp.ndarray:
    """Vendor-library lane: plain XLA dot (+ unfused activation)."""
    del config, interpret
    return apply_activation(x @ w, activation)


@register_lane("pallas_matmul")
def pallas_matmul_lane(x: jnp.ndarray, w: jnp.ndarray, *,
                       config: Optional[Dict] = None,
                       activation: Optional[str] = None,
                       interpret: bool = True) -> jnp.ndarray:
    """Tuned lane: the Pallas MXU matmul with the searched schedule config;
    the activation (when the role carries one) runs in the kernel epilogue."""
    from repro.kernels import ops  # lazy: keep kernel imports off hot import paths

    return ops.matmul(x, w, config=config, activation=activation,
                      interpret=interpret)


# ----------------------------------------------------------------- context
_tls = threading.local()


class _DispatchCtx:
    __slots__ = ("table", "interpret")

    def __init__(self, table: MatmulTable, interpret: bool):
        self.table = table
        self.interpret = interpret


@contextlib.contextmanager
def matmul_dispatch(table: Optional[MatmulTable], interpret: bool = True):
    """Install a per-stage matmul dispatch table for the enclosed trace.

    Like `sharding.activation_rules`, this is consulted while jit TRACES the
    program, so the table must be installed around the traced body (the
    `repro.launch.steps` builders do this) and its choices become static
    properties of the compiled program."""
    prev = getattr(_tls, "ctx", None)
    _tls.ctx = _DispatchCtx(dict(table or {}), interpret)
    try:
        yield
    finally:
        _tls.ctx = prev


def active_table() -> Optional[MatmulTable]:
    """The currently installed table (None outside a dispatch context)."""
    ctx = getattr(_tls, "ctx", None)
    return None if ctx is None else ctx.table


def dispatch_dense(role: Optional[str], x: jnp.ndarray, w: jnp.ndarray,
                   activation: Optional[str] = None) -> jnp.ndarray:
    """Route one role-tagged projection through the chosen lane.

    Outside a dispatch context — or for a role the table does not name —
    this is the XLA lane, i.e. exactly `x @ w` (+ activation)."""
    ctx = getattr(_tls, "ctx", None)
    if ctx is None or role is None:
        return xla_lane(x, w, activation=activation)
    backend, config = ctx.table.get(role, ("xla", {}))
    lane = _LANES.get(backend)
    if lane is None:
        raise KeyError(
            f"plan chose unknown matmul backend {backend!r} for role "
            f"{role!r}; registered lanes: {sorted(_LANES)}")
    return lane(x, w, config=config, activation=activation,
                interpret=ctx.interpret)
