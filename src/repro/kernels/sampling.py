"""Fused per-row keyed sampling: the step programs' token-selection tail.

Woodpecker-DL's inference thesis — exploit structure known before the run
to fuse and pre-select per-operator implementations — applies to token
selection too: temperature / top-k / top-p are per-REQUEST knobs, but the
serving step programs compile once per family, so the knobs must enter as
*traced data*, never as trace-time constants.  This module is the pure
device-side routine the four serving step programs
(`repro.launch.steps.jit_unified_step` / `jit_decode_only_step` and the
ssm pair) fuse behind their logits, and the host-facing policy layer
(`repro.serve.sampling`) packs the matching arrays.

Conventions (shared with `repro.serve.sampling`):

  * `sampling` — float32 (rows, 3): [temperature, top_k, top_p] per row.
    temperature <= 0 selects greedy argmax BITWISE (the sampled lane's
    result is discarded by a `where`, so a temperature-0 row reproduces
    the pre-sampling argmax path exactly); top_k < 1 disables the top-k
    mask; top_p >= 1 disables the nucleus mask.
  * `keys` — int32 (rows, 3): [seed, rid, token_index].  The PRNG key is
    derived INSIDE the program as fold_in(fold_in(PRNGKey(seed), rid),
    token_index), a pure per-row function of the triple — a token's draw
    depends on nothing but its own (seed, rid, token_index), so sampled
    streams replay bitwise across batch packings, chunk schedules,
    preemption/resume, and across engines (the continuous runtime and the
    fixed-batch differential baseline share this exact routine).

Every row is computed by the same vmapped element-wise/sort/cumsum float
program regardless of batch height, which is the same per-row-identity
property the serving tests already pin for the argmax path.

Tie semantics (documented, deterministic): top-k keeps every logit EQUAL
to the k-th largest (a tie at the threshold keeps more than k entries);
top-p keeps every probability equal to the smallest nucleus member's.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# temperature floor for the sampled lane; temperature <= 0 rows never use
# the scaled logits (the `where` picks argmax), the floor only keeps the
# discarded lane finite
_TEMP_EPS = 1e-6


def derive_key(seed, rid, token_index):
    """Per-token PRNG key from the (seed, rid, token_index) triple; all
    three may be traced scalars."""
    key = jax.random.PRNGKey(seed)
    key = jax.random.fold_in(key, rid)
    return jax.random.fold_in(key, token_index)


def mask_top_k(x, k):
    """Keep the k largest entries of `x` (last axis), mask the rest to
    -inf.  `k` is a traced int scalar; k < 1 or k >= size disables the
    mask.  Ties at the k-th value are all kept."""
    v = x.shape[-1]
    kk = jnp.where((k < 1) | (k >= v), v, k).astype(jnp.int32)
    thr = jnp.sort(x, axis=-1)[v - kk]
    return jnp.where(x < thr, -jnp.inf, x)


def mask_top_p(x, p):
    """Nucleus mask over logits `x` (one row): keep the MINIMAL set of
    highest-probability tokens whose total probability reaches `p`, mask
    the rest to -inf.  `p` is a traced float scalar; p >= 1 disables the
    mask.  Ties at the smallest kept probability are all kept."""
    probs = jax.nn.softmax(x, axis=-1)
    sp = jnp.sort(probs, axis=-1)[::-1]
    csum_before = jnp.cumsum(sp) - sp           # mass strictly above each
    keep = csum_before < p                      # minimal covering prefix
    thr = jnp.min(jnp.where(keep, sp, jnp.inf))
    masked = jnp.where(probs < thr, -jnp.inf, x)
    return jnp.where(p >= 1.0, x, masked)


def _sample_row(logits_row, sampling_row, key_row):
    """One row's token: argmax when temperature <= 0 (bitwise the greedy
    path), else a categorical draw from the temperature-scaled, top-k /
    top-p masked distribution under the row's derived key."""
    greedy = jnp.argmax(logits_row, axis=-1).astype(jnp.int32)
    temp = sampling_row[0]
    x = logits_row.astype(jnp.float32) / jnp.maximum(temp, _TEMP_EPS)
    x = mask_top_k(x, sampling_row[1].astype(jnp.int32))
    x = mask_top_p(x, sampling_row[2])
    key = derive_key(key_row[0], key_row[1], key_row[2])
    sampled = jax.random.categorical(key, x).astype(jnp.int32)
    return jnp.where(temp > 0.0, sampled, greedy)


def sample_tokens(logits, sampling, keys):
    """(rows, V) logits + (rows, 3) sampling + (rows, 3) keys ->
    (rows,) int32 next tokens.  Pure function of its arguments — safe to
    fuse inside any jitted step program; every argument is traced data so
    per-request knobs never retrace."""
    return jax.vmap(_sample_row)(logits, sampling, keys)
