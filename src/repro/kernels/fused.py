"""Fused elementwise-chain Pallas kernel.

The fusion pass (§2.1) collapses chains of elementwise operators into one
`fused_elementwise` node; this kernel is its single-launch implementation —
"complete the whole computation within only one kernel launch to eliminate
the intermediate data movement overhead" (paper §1).  One block streams
HBM→VMEM→HBM exactly once regardless of chain length.

Tunable: block_rows (how many rows of the flattened (R, C) view per step).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import apply_activation

_BINARY = {"add": jnp.add, "mul": jnp.multiply, "sub": jnp.subtract, "div": jnp.divide}


def _chain_kernel(*refs, chain: Sequence[Dict[str, Any]], n_extra: int):
    x_ref, extra_refs, o_ref = refs[0], refs[1 : 1 + n_extra], refs[-1]
    x = x_ref[...]
    ei = 0
    for stage in chain:
        op = stage["op"] if isinstance(stage, dict) else stage
        if op in _BINARY:
            x = _BINARY[op](x, extra_refs[ei][...])
            ei += 1
        else:
            x = apply_activation(x, op)
    o_ref[...] = x


def fused_elementwise(
    x: jnp.ndarray,
    chain: Sequence[Dict[str, Any]],
    extras: Sequence[jnp.ndarray] = (),
    *,
    block_rows: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """Apply `chain` to x in one kernel.  All extras must be broadcastable to
    x's shape; we require same-shape here (the fusion pass guarantees it)."""
    orig_shape = x.shape
    flat = x.reshape(-1)
    extras = [e.reshape(-1) for e in extras]
    n = flat.shape[0]
    # pick a lane-friendly 2-D view
    cols = 128
    rows = -(-n // cols)
    pad = rows * cols - n
    if pad:
        flat = jnp.pad(flat, (0, pad))
        extras = [jnp.pad(e, (0, pad)) for e in extras]
    x2 = flat.reshape(rows, cols)
    extras2 = [e.reshape(rows, cols) for e in extras]
    br = min(block_rows, rows)
    rt = -(-rows // br)
    if rows % br:
        extra_rows = rt * br - rows
        x2 = jnp.pad(x2, ((0, extra_rows), (0, 0)))
        extras2 = [jnp.pad(e, ((0, extra_rows), (0, 0))) for e in extras2]

    kernel = functools.partial(_chain_kernel, chain=tuple(
        tuple(sorted(s.items())) and s for s in chain), n_extra=len(extras2))
    spec = pl.BlockSpec((br, cols), lambda i: (i, 0))
    out = pl.pallas_call(
        kernel,
        grid=(rt,),
        in_specs=[spec] * (1 + len(extras2)),
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, *extras2)
    return out.reshape(-1)[:n].reshape(orig_shape)
