"""Tuned Pallas TPU flash attention (prefill + decode).

Online-softmax attention with VMEM-resident running max/denominator/output
accumulator — no B·H·Sq·Skv logits tensor ever touches HBM (the XLA
"vendor" lowering materialises it; that is exactly the gap WPK's backend
selection exploits for long sequences).

Schedule knobs (from `AttentionTemplate`): block_q, block_kv.  The grid is
(B·H, Sq/block_q, Skv/block_kv) with the KV axis innermost ('arbitrary');
causal masking skips fully-masked KV blocks via `pl.when` so the causal
prefill does ~half the work.

GQA is handled by the wrapper (`ops.attention`): the KV head index map
divides by the group size — KV blocks are *shared* across the query heads of
a group, not materialised per head.

The decode variant (single query token against a long cache) uses the same
online softmax with block_q folded away and a `length` scalar masking the
unwritten cache tail.

The *paged* variants serve the continuous-batching runtime: the KV argument
is the shared physical block pool and a scalar-prefetched block table
indirects each grid step to its physical block — `flash_decode_paged` for
one query row per slot, `flash_prefill_paged` for a *segment-packed* prompt
chunk (the block-table-aware prefill kernel of the unified token-budget
step): the chunk's query rows carry contiguous prompt segments from up to S
requests, each segment's `(row_offset, seg_len, kv_start)` descriptor and
block table travel in the scalar-prefetch lane, and the kernel masks
cross-segment attention, so packing geometry is data and never recompiles.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.pallas_compat import pltpu, vmem_scratch

NEG_INF = -1e30


def _scratch(shape, dtype=jnp.float32):
    return vmem_scratch(shape, dtype)


# ---------------------------------------------------------------- prefill
def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  kt: int, block_q: int, block_kv: int, scale: float,
                  causal: bool, out_dtype):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _body():
        q = q_ref[0]                                   # (bq, d)
        k = k_ref[0]                                   # (bkv, d)
        v = v_ref[0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = ki * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]                            # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        # Skip KV blocks entirely above the diagonal.
        @pl.when(ki * block_kv <= qi * block_q + block_q - 1)
        def _():
            _body()
    else:
        _body()

    @pl.when(ki == kt - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l).astype(out_dtype)


def flash_attention_padded(
    q: jnp.ndarray,   # (BH, Sq, D)  — Sq % block_q == 0
    k: jnp.ndarray,   # (BHkv, Skv, D)
    v: jnp.ndarray,
    *,
    block_q: int = 512,
    block_kv: int = 512,
    causal: bool = True,
    scale: Optional[float] = None,
    q_per_kv: int = 1,
    interpret: bool = True,
) -> jnp.ndarray:
    bh, sq, d = q.shape
    _, skv, _ = k.shape
    qt, kt = sq // block_q, skv // block_kv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)

    kernel = functools.partial(
        _flash_kernel, kt=kt, block_q=block_q, block_kv=block_kv,
        scale=scale, causal=causal, out_dtype=q.dtype)

    return pl.pallas_call(
        kernel,
        grid=(bh, qt, kt),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, i, j, q_per_kv=q_per_kv: (b // q_per_kv, j, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, i, j, q_per_kv=q_per_kv: (b // q_per_kv, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            _scratch((block_q, 1)),
            _scratch((block_q, 1)),
            _scratch((block_q, d)),
        ],
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------- decode
def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, kt: int, block_kv: int, scale: float, out_dtype):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[0, 0]

    @pl.when(ki * block_kv < length)
    def _body():
        q = q_ref[0]                                   # (H, D)
        k = k_ref[0]                                   # (bkv, D)
        v = v_ref[0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (H, bkv)
        kpos = ki * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == kt - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l).astype(out_dtype)


def _paged_decode_kernel(len_ref, bt_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, nbt: int, block_size: int,
                         scale: float, out_dtype):
    """One (slot, logical-block) grid step of paged decode attention.

    The physical KV block this step reads was selected by the BlockSpec
    index map from the scalar-prefetched block table — the kernel body only
    ever sees a dense (block_size, D) tile, so the online softmax is
    identical to the monolithic decode kernel."""
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]

    @pl.when(j * block_size < length)
    def _body():
        q = q_ref[0]                                   # (H, D)
        k = k_ref[0]                                   # (block_size, D)
        v = v_ref[0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        kpos = j * block_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nbt - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l).astype(out_dtype)


def _paged_prefill_kernel(info_ref, bt_ref, q_ref, k_ref, v_ref, o_ref,
                          m_ref, l_ref, acc_ref, *, ns: int, nbt: int,
                          block_size: int, block_q: int, group: int,
                          scale: float, out_dtype):
    """One (query-tile, segment, logical-block) grid step of segment-packed
    paged prefill attention — `_paged_decode_kernel` generalised from 1
    query row to a chunk of `block_q` prompt positions (x `group` query
    heads each) carrying contiguous segments from up to `ns` requests.

    The physical KV block this step reads was selected by the BlockSpec
    index map from segment s's scalar-prefetched block table, so each
    segment's rows attend to every previously *committed* row of their OWN
    request (earlier chunks + the segment's rows, scattered before the
    kernel runs) and never to a co-packed neighbour's: rows outside the
    segment's [q0, q0+qn) row span are masked to NEG_INF for this (s, j)
    step.  Causality is positional within the segment: chunk row r sits at
    absolute position `kv_start + r - q0` of its request and masks
    strictly-future key rows, which also hides whatever stale data lives
    beyond the request's committed length.  A row's running max stays at
    NEG_INF through foreign segments' blocks (every entry masked -> p==1
    garbage), and the first in-segment block rescales that garbage by
    alpha = exp(NEG_INF - m_real) == 0 exactly, so packing is invisible to
    the online softmax; rows past the packed fill never see an unmasked
    block and are discarded by the caller."""
    i = pl.program_id(0)
    s = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when((s == 0) & (j == 0))
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q0 = info_ref[s, 0]          # segment's first row within the chunk
    qn = info_ref[s, 1]          # segment length in rows (0 = idle slot)
    kv0 = info_ref[s, 2]         # committed rows before this segment's chunk
    total = kv0 + qn             # committed rows once this segment lands
    tile0 = i * block_q
    # largest absolute position any of this tile's rows can hold in s's
    # request (rows beyond the segment are masked in the body)
    qpos_max = kv0 + tile0 + block_q - 1 - q0

    # Skip (segment, block) steps that cannot contribute: idle segment
    # slots, tiles that hold none of the segment's rows, blocks entirely
    # above the tile's diagonal, and blocks holding no committed row.
    @pl.when((qn > 0) & (tile0 < q0 + qn) & (tile0 + block_q > q0)
             & (j * block_size <= qpos_max) & (j * block_size < total))
    def _body():
        q = q_ref[...]                                 # (block_q*group, D)
        k = k_ref[0]                                   # (block_size, D)
        v = v_ref[0]
        st = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        row = jax.lax.broadcasted_iota(jnp.int32, st.shape, 0)
        qrow = tile0 + row // group                    # row within the chunk
        qpos = kv0 + qrow - q0                         # row's own-request pos
        kpos = j * block_size + jax.lax.broadcasted_iota(jnp.int32, st.shape, 1)
        ok = (qrow >= q0) & (qrow < q0 + qn) & (kpos <= qpos)
        st = jnp.where(ok, st, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(st, -1, keepdims=True))
        p = jnp.exp(st - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when((s == ns - 1) & (j == nbt - 1))
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_ref[...] / l).astype(out_dtype)


def flash_prefill_paged(
    q: jnp.ndarray,             # (C, G, D) one KV-head group's chunk queries
    k_pool: jnp.ndarray,        # (num_blocks, block_size, D) one KV head's pool
    v_pool: jnp.ndarray,
    seg_tables: jnp.ndarray,    # (S, nbt) int32 per-segment physical block ids
    seg_info: jnp.ndarray,      # (S, 3) int32 [row_offset, seg_len, kv_start]
    *,
    block_q: Optional[int] = None,
    scale: Optional[float] = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Block-table-aware segment-packed prefill flash attention.

    `flash_decode_paged` generalised from one query row to a packed prompt
    chunk: the (C, G, D) query buffer carries contiguous prompt segments
    from up to S requests (segment s occupies chunk rows
    [seg_info[s,0], seg_info[s,0]+seg_info[s,1])), the grid walks
    (query tiles x segments x each segment's *logical* blocks), and segment
    s's scalar-prefetched table indirects to its request's physical pool
    blocks, so every row attends to all previously committed KV of its OWN
    request — earlier chunks included, co-packed neighbours excluded —
    without materialising a gathered contiguous cache.  The descriptors
    ride in the scalar-prefetch lane, so packing geometry is *data*, never
    a new compile; a single-request chunk is just S=1 (or idle descriptor
    rows with seg_len 0).  `block_q` (prompt positions per query tile) is
    the schedule knob the plan's `prefill_chunk` stage tunes — together
    with the segment axis it defines the kernel's block_q x max-segments
    grid."""
    if pltpu is None:  # pragma: no cover - no TPU pallas module at all
        raise NotImplementedError("paged prefill kernel needs pallas TPU")
    c, g, d = q.shape
    _, block_size, _ = k_pool.shape
    ns, nbt = seg_tables.shape
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    bq = min(block_q or c, c)
    c_pad = -(-c // bq) * bq
    qf = q.reshape(c * g, d)
    if c_pad != c:
        qf = jnp.pad(qf, ((0, (c_pad - c) * g), (0, 0)))
    rows = bq * g

    kernel = functools.partial(
        _paged_prefill_kernel, ns=ns, nbt=nbt, block_size=block_size,
        block_q=bq, group=g, scale=scale, out_dtype=q.dtype)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,      # segment descriptors + block tables
        grid=(c_pad // bq, ns, nbt),
        in_specs=[
            pl.BlockSpec((rows, d), lambda i, s, j, info, bt: (i, 0)),
            pl.BlockSpec((1, block_size, d),
                         lambda i, s, j, info, bt: (bt[s, j], 0, 0)),
            pl.BlockSpec((1, block_size, d),
                         lambda i, s, j, info, bt: (bt[s, j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((rows, d), lambda i, s, j, info, bt: (i, 0)),
        scratch_shapes=[
            _scratch((rows, 1)),
            _scratch((rows, 1)),
            _scratch((rows, d)),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((c_pad * g, d), q.dtype),
        interpret=interpret,
    )(seg_info.astype(jnp.int32), seg_tables.astype(jnp.int32),
      qf, k_pool, v_pool)
    return out.reshape(c_pad, g, d)[:c]


def flash_decode_paged(
    q: jnp.ndarray,             # (B, H, D) single new token per sequence
    k_pool: jnp.ndarray,        # (num_blocks, block_size, D) one KV head's pool
    v_pool: jnp.ndarray,
    lengths: jnp.ndarray,       # (B,) int32 valid context lengths
    block_tables: jnp.ndarray,  # (B, nbt) int32 physical block ids
    *,
    scale: Optional[float] = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Block-table-aware flash decode: the grid walks each slot's *logical*
    blocks and the scalar-prefetched table indirects to physical pool blocks,
    so the kernel never materialises a gathered contiguous cache."""
    if pltpu is None:  # pragma: no cover - no TPU pallas module at all
        raise NotImplementedError("paged decode kernel needs pallas TPU")
    b, h, d = q.shape
    _, block_size, _ = k_pool.shape
    nbt = block_tables.shape[1]
    scale = scale if scale is not None else 1.0 / np.sqrt(d)

    kernel = functools.partial(
        _paged_decode_kernel, nbt=nbt, block_size=block_size, scale=scale,
        out_dtype=q.dtype)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,      # lengths + block table drive the DMA
        grid=(b, nbt),
        in_specs=[
            pl.BlockSpec((1, h, d), lambda bb, j, lens, bt: (bb, 0, 0)),
            pl.BlockSpec((1, block_size, d),
                         lambda bb, j, lens, bt: (bt[bb, j], 0, 0)),
            pl.BlockSpec((1, block_size, d),
                         lambda bb, j, lens, bt: (bt[bb, j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda bb, j, lens, bt: (bb, 0, 0)),
        scratch_shapes=[
            _scratch((h, 1)),
            _scratch((h, 1)),
            _scratch((h, d)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), block_tables.astype(jnp.int32), q,
      k_pool, v_pool)


def flash_decode_padded(
    q: jnp.ndarray,        # (B, H, D) single new token per sequence
    k: jnp.ndarray,        # (B, Skv, D) one KV head's cache (GQA grouped out)
    v: jnp.ndarray,
    lengths: jnp.ndarray,  # (B,) int32 valid cache lengths
    *,
    block_kv: int = 512,
    scale: Optional[float] = None,
    interpret: bool = True,
) -> jnp.ndarray:
    b, h, d = q.shape
    _, skv, _ = k.shape
    kt = skv // block_kv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)

    kernel = functools.partial(_decode_kernel, kt=kt, block_kv=block_kv,
                               scale=scale, out_dtype=q.dtype)
    lengths2d = lengths.astype(jnp.int32).reshape(b, 1)
    return pl.pallas_call(
        kernel,
        grid=(b, kt),
        in_specs=[
            pl.BlockSpec((1, 1), lambda bb, j: (bb, 0)),
            pl.BlockSpec((1, h, d), lambda bb, j: (bb, 0, 0)),
            pl.BlockSpec((1, block_kv, d), lambda bb, j: (bb, j, 0)),
            pl.BlockSpec((1, block_kv, d), lambda bb, j: (bb, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda bb, j: (bb, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        scratch_shapes=[
            _scratch((h, 1)),
            _scratch((h, 1)),
            _scratch((h, d)),
        ],
        interpret=interpret,
    )(lengths2d, q, k, v)
