"""AdamW + cosine LR schedule + global-norm clipping (pure JAX pytrees).

Optimizer state is shaped exactly like params, so the ZeRO-1 sharding rule
("zero" logical axis on the largest divisible dim, handled in
`repro.launch.steps.opt_state_logical_axes`) applies uniformly.  Master
weights are f32; the model casts to bf16 at use (see models/).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def cosine_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, tree), norm


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda t: jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), t)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(
    params, grads, state, cfg: AdamWConfig
) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2

    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                     state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2)
                     * jnp.square(g.astype(jnp.float32)), state["v"], grads)
    t = step.astype(jnp.float32)
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t

    def upd(p, m_, v_):
        u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + cfg.eps)
        return (p.astype(jnp.float32) - lr * (u + cfg.weight_decay
                                              * p.astype(jnp.float32))).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, {"m": m, "v": v, "step": step}, metrics
