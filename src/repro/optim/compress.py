"""int8 gradient compression for cross-pod (DCN) reductions.

Pod-to-pod bandwidth is ~30x scarcer than in-pod ICI, so the pod-axis
gradient reduction is the first collective to compress at multi-pod scale.
`compressed_allgather_mean` runs under `shard_map` over the 'pod' axis:

    f32 all-reduce           : ~2 x 4N bytes on the wire
    int8 all-gather + local  : P x N x 1 byte  (P=2 pods -> ~4x fewer bytes)

Per-tensor symmetric scaling keeps the quantisation error ~0.4% of the grad
scale; the trainer exposes it behind `TrainConfig.compress_pod_grads` and the
collective shows up as an int8 all-gather in the lowered HLO (visible to the
roofline's collective-bytes parser).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def int8_compress(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x.astype(jnp.float32))) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_allgather_mean(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Mean over `axis_name` using int8 all-gather (call under shard_map)."""
    q, scale = int8_compress(x)
    qs = jax.lax.all_gather(q, axis_name)              # int8 on the wire
    ss = jax.lax.all_gather(scale, axis_name)
    deq = qs.astype(jnp.float32) * ss.reshape((-1,) + (1,) * x.ndim)
    return jnp.mean(deq, axis=0).astype(x.dtype)
