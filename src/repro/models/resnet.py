"""ResNet-18 as a WPK computational graph — the paper's evaluation model.

The paper's §3 inputs: Caffe-trained ResNet-18, NCHW layout, N=1, C=3,
H=224, W=224 (the text says W=244 once; the canonical 224 is used — noted
as a likely typo).  Weights are randomly initialised (inference *speed* is
weight-independent); BN is in inference form (folded scale/shift).

`resnet18_graph()` returns the Graph the WPK pipeline optimizes;
`conv_groups()` returns the deduplicated convolution set of Figure 2b under
the paper's identity criterion (same input/output shape, filter size,
stride, padding).
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.core.graph import Graph
from repro.core.schedules import OpDesc

STAGES = ((64, 2, 1), (128, 2, 2), (256, 2, 2), (512, 2, 2))


def _conv(g: Graph, rng, x: str, cin: int, cout: int, k: int, stride: int,
          in_hw: int, relu: bool = True, bn: bool = True) -> Tuple[str, int]:
    out_hw = -(-in_hw // stride)
    n = g.tensors[x].shape[0]
    w = g.add_constant(g.fresh("w"),
                       (rng.standard_normal((cout, cin, k, k)) *
                        np.sqrt(2.0 / (cin * k * k))).astype(np.float32))
    y = g.add_node("conv2d", [x, w], (n, cout, out_hw, out_hw),
                   {"stride": stride, "padding": "SAME", "layout": "NCHW"})
    if bn:
        sc = g.add_constant(g.fresh("bn_s"),
                            (rng.random(cout) * 0.5 + 0.75).astype(np.float32))
        sh = g.add_constant(g.fresh("bn_b"),
                            (rng.standard_normal(cout) * 0.1).astype(np.float32))
        y = g.add_node("batch_norm", [y, sc, sh], (n, cout, out_hw, out_hw),
                       {"layout": "NCHW"})
    if relu:
        y = g.add_node("relu", [y], (n, cout, out_hw, out_hw))
    return y, out_hw


def resnet18_graph(batch: int = 1, image: int = 224, n_classes: int = 1000,
                   seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    g = Graph("resnet18")
    x = g.add_input("image", (batch, 3, image, image))

    # stem: 7x7/64 s2 + maxpool 3x3 s2
    y, hw = _conv(g, rng, x, 3, 64, 7, 2, image)
    hw = hw // 2
    y = g.add_node("max_pool", [y], (batch, 64, hw, hw),
                   {"kernel": 3, "stride": 2, "padding": "SAME", "layout": "NCHW"})

    cin = 64
    for cout, blocks, first_stride in STAGES:
        for b in range(blocks):
            stride = first_stride if b == 0 else 1
            identity = y
            out_hw = -(-hw // stride)
            y1, _ = _conv(g, rng, y, cin, cout, 3, stride, hw)
            y2, _ = _conv(g, rng, y1, cout, cout, 3, 1, out_hw, relu=False)
            if stride != 1 or cin != cout:  # projection shortcut
                identity, _ = _conv(g, rng, identity, cin, cout, 1, stride, hw,
                                    relu=False)
            y = g.add_node("add", [y2, identity], (batch, cout, out_hw, out_hw))
            y = g.add_node("relu", [y], (batch, cout, out_hw, out_hw))
            hw, cin = out_hw, cout

    y = g.add_node("global_avg_pool", [y], (batch, 512), {"layout": "NCHW"})
    wf = g.add_constant("fc_w", (rng.standard_normal((512, n_classes)) *
                                 np.sqrt(1.0 / 512)).astype(np.float32))
    bf = g.add_constant("fc_b", np.zeros(n_classes, np.float32))
    y = g.add_node("matmul", [y, wf], (batch, n_classes))
    y = g.add_node("bias_add", [y, bf], (batch, n_classes))
    g.set_outputs([y])
    g.validate()
    return g


def conv_groups(batch: int = 1, image: int = 224) -> List[Tuple[str, OpDesc]]:
    """Deduplicated convolution groups of ResNet-18 (Figure 2b's c1..cN),
    using the paper's computational-identity criterion."""
    convs: List[Tuple[int, int, int, int]] = []  # (hw, cin, cout, k, stride)
    hw = image
    convs.append((hw, 3, 64, 7, 2))
    hw = -(-hw // 2) // 2
    cin = 64
    for cout, blocks, first_stride in STAGES:
        for b in range(blocks):
            stride = first_stride if b == 0 else 1
            out_hw = -(-hw // stride)
            convs.append((hw, cin, cout, 3, stride))
            convs.append((out_hw, cout, cout, 3, 1))
            if stride != 1 or cin != cout:
                convs.append((hw, cin, cout, 1, stride))
            hw, cin = out_hw, cout

    seen: Dict[str, str] = {}
    groups: List[Tuple[str, OpDesc]] = []
    for (h, ci, co, k, s) in convs:
        op = OpDesc.conv2d(batch, h, h, ci, co, k, k, stride=s,
                           padding="SAME", dtype="bfloat16")
        key = op.signature()
        if key not in seen:
            name = f"c{len(groups) + 1}"
            seen[key] = name
            groups.append((name, op))
    return groups
