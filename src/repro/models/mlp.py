"""MLP + Mixture-of-Experts layers.

The MoE uses capacity-based scatter dispatch (GShard-style) formulated as
gather/scatter + batched einsum so it (a) compiles on any mesh, (b) shards
experts over the `model` axis (EP — XLA inserts the all-to-alls at the
resharding boundary), and (c) has compiled FLOPs ≈ top-k active FLOPs ×
capacity_factor, keeping the roofline analysis honest (no dense all-experts
overcounting).
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.common import Params, dense, dense_init


def _act(x, kind: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[kind](x)


# ------------------------------------------------------------------ dense MLP
def mlp_init(rng, d: int, f: int, gated: bool = True) -> Params:
    k1, k2, k3 = jax.random.split(rng, 3)
    p = {"w_in": dense_init(k1, d, f), "w_out": dense_init(k2, f, d)}
    if gated:
        p["w_gate"] = dense_init(k3, d, f)
    return p


def mlp_logical_axes(gated: bool = True) -> Params:
    p = {"w_in": {"w": ("embed", "ffn")}, "w_out": {"w": ("ffn", "embed")}}
    if gated:
        p["w_gate"] = {"w": ("embed", "ffn")}
    return p


def mlp_apply(p: Params, x: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    # role-tagged for the serve plan's stage matmul dispatch; the activation
    # rides into the tuned kernel's epilogue (XLA lane applies it after).
    if "w_gate" in p:
        h = dense(p["w_in"], x, role="mlp_up")
        h = dense(p["w_gate"], x, role="mlp_up", activation=act) * h
    else:
        h = dense(p["w_in"], x, role="mlp_up", activation=act)
    h = constrain(h, ("batch", None, "ffn"))
    return dense(p["w_out"], h, role="mlp_down")


# ------------------------------------------------------------------ MoE
def moe_init(rng, cfg: ModelConfig) -> Params:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_expert
    k1, k2, k3, k4, k5 = jax.random.split(rng, 5)
    scale = 1.0 / np.sqrt(d)
    p: Params = {
        "router": dense_init(k1, d, e),
        "w_in": jax.random.normal(k2, (e, d, f), jnp.float32) * scale,
        "w_gate": jax.random.normal(k3, (e, d, f), jnp.float32) * scale,
        "w_out": jax.random.normal(k4, (e, f, d), jnp.float32) / np.sqrt(f),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(k5, d, cfg.n_shared_experts * f)
        p["shared_gate"] = dense_init(k5, d, 1)
    return p


def moe_logical_axes(cfg: ModelConfig) -> Params:
    p = {
        "router": {"w": ("embed", None)},
        "w_in": ("experts", "embed", "expert_ffn"),
        "w_gate": ("experts", "embed", "expert_ffn"),
        "w_out": ("experts", "expert_ffn", "embed"),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_logical_axes()
        p["shared_gate"] = {"w": ("embed", None)}
    return p


def moe_capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    cap = int(np.ceil(tokens_per_group * cfg.top_k / cfg.n_experts
                      * cfg.capacity_factor))
    return max(4, -(-cap // 4) * 4)


def moe_apply(p: Params, cfg: ModelConfig, x: jnp.ndarray,
              act: str = "silu") -> jnp.ndarray:
    """x: (B, S, d).  Groups = sequences (decode: the whole batch is one
    group).  Returns (B, S, d) plus auxiliary-loss-free routing (inference
    framework — no load-balancing loss term needed for the forward).
    """
    b, s, d = x.shape
    squeeze = False
    if s == 1:                     # decode: group across the batch instead
        x = x.reshape(1, b, d)
        b, s = 1, b
        squeeze = True
    e, k = cfg.n_experts, cfg.top_k
    cap = moe_capacity(cfg, s)

    logits = dense(p["router"], x)                      # (B, S, E)
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    top_p, top_i = jax.lax.top_k(probs, k)              # (B, S, k)
    if cfg.norm_topk:
        top_p = top_p / jnp.sum(top_p, -1, keepdims=True)
    top_p = top_p.astype(x.dtype)

    # Position of each (token, choice) inside its expert's capacity queue.
    flat_i = top_i.reshape(b, s * k)                    # (B, T)
    onehot = jax.nn.one_hot(flat_i, e, dtype=jnp.int32) # (B, T, E)
    pos_all = jnp.cumsum(onehot, axis=1) - onehot       # tokens before me
    pos = jnp.take_along_axis(pos_all, flat_i[..., None], -1)[..., 0]  # (B, T)
    keep = pos < cap

    xs = jnp.repeat(x, k, axis=1)                       # (B, T, d) token copies
    xs = constrain(xs, ("batch", "moe_tokens", None))
    weights = top_p.reshape(b, s * k)

    def scatter_one(e_idx, c_idx, keep_b, xs_b):
        buf = jnp.zeros((e, cap, d), xs_b.dtype)
        return buf.at[e_idx, jnp.where(keep_b, c_idx, cap - 1)].add(
            xs_b * keep_b[:, None].astype(xs_b.dtype), mode="drop")

    # NOTE: mode='drop' + clamped index keeps dropped tokens out of the buf.
    expert_in = jax.vmap(scatter_one)(flat_i, pos, keep, xs)   # (B, E, cap, d)
    expert_in = constrain(expert_in, ("batch", "experts", None, None))

    h = jnp.einsum("becd,edf->becf", expert_in, p["w_in"].astype(x.dtype))
    g = jnp.einsum("becd,edf->becf", expert_in, p["w_gate"].astype(x.dtype))
    h = _act(g, act) * h
    y = jnp.einsum("becf,efd->becd", h, p["w_out"].astype(x.dtype))
    y = constrain(y, ("batch", "experts", None, None))

    # Gather each (token, choice) result back and combine with router weights.
    def gather_one(y_b, e_idx, c_idx):
        return y_b[e_idx, c_idx]                        # (T, d)

    out_tk = jax.vmap(gather_one)(y, flat_i, jnp.minimum(pos, cap - 1))
    out_tk = constrain(out_tk.astype(x.dtype), ("batch", "moe_tokens", None))
    out_tk = out_tk * (weights * keep.astype(x.dtype))[..., None]
    out = out_tk.reshape(b, s, k, d).sum(axis=2)

    if cfg.n_shared_experts:
        gate = jax.nn.sigmoid(dense(p["shared_gate"], x).astype(jnp.float32))
        out = out + mlp_apply(p["shared"], x, act) * gate.astype(x.dtype)

    if squeeze:
        out = out.reshape(s, 1, d)
    return out
