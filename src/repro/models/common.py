"""Shared model building blocks (pure-JAX, pytree params).

Everything here is a pair of functions: `init_*(rng, ...) -> params` and a
pure apply.  No flax/haiku — params are plain dicts so that sharding rules,
checkpointing and the WPK backend dispatch stay transparent.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain

Params = Dict[str, Any]


def dense_init(rng, d_in: int, d_out: int, scale: Optional[float] = None) -> Params:
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return {"w": jax.random.normal(rng, (d_in, d_out), jnp.float32) * scale}


def dense(p: Params, x: jnp.ndarray, role: Optional[str] = None,
          activation: Optional[str] = None) -> jnp.ndarray:
    """Projection `x @ w`, routable through the WPK plan's matmul lanes.

    `role` names the projection against the serve plan's stage matmul table
    ('qkv_proj' / 'mlp_up' / 'mlp_down' / 'lm_head'); inside an active
    `kernels.dispatch.matmul_dispatch` context the chosen lane (XLA vs tuned
    Pallas) runs instead of the plain dot.  `activation` is fused into the
    tuned kernel's epilogue (applied after the dot on the XLA lane — same
    numerics).  With no role/activation this is exactly `x @ w`."""
    w = p["w"].astype(x.dtype)
    if role is None and activation is None:
        return x @ w
    from repro.kernels.dispatch import dispatch_dense
    return dispatch_dense(role, x, w, activation=activation)


def norm_init(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rms_norm(p: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), -1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"]).astype(x.dtype)


def layer_norm_init(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def layer_norm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.var(xf, -1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


# ------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0) -> jnp.ndarray:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                               # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    angles = angles[..., None, :]                              # (..., S, 1, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions_3d: jnp.ndarray,
                sections: Tuple[int, int, int], theta: float = 10000.0) -> jnp.ndarray:
    """Multimodal RoPE (qwen2-vl): the head dim is partitioned into
    (temporal, height, width) sections, each rotated by its own position
    stream.  positions_3d: (..., S, 3).  sections are in *pairs* (sum = D/2).
    For text tokens all three streams are equal, reducing to standard RoPE.
    """
    d = x.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    freqs = rope_freqs(d, theta)                               # (D/2,)
    # select the position stream per frequency-pair index (static mapping)
    sec_ids = np.repeat(np.arange(3), np.array(sections))      # (D/2,)
    pos = positions_3d.astype(jnp.float32)[..., sec_ids]       # (..., S, D/2)
    angles = pos * freqs                                       # (..., S, D/2)
    angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def embed_init(rng, vocab: int, d: int) -> Params:
    return {"emb": jax.random.normal(rng, (vocab, d), jnp.float32) * 0.01}


def embed(p: Params, tokens: jnp.ndarray, dtype) -> jnp.ndarray:
    return jnp.take(p["emb"].astype(dtype), tokens, axis=0)


def unembed(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    logits = x @ p["emb"].astype(x.dtype).T
    return constrain(logits, ("batch", None, "vocab"))


def lm_head_logits(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    """LM head projection `x @ w` (w: (d_model, vocab)), role-tagged so the
    serve plan's `lm_head` stage choice dispatches it (see kernels.dispatch)."""
    return constrain(dense(p, x, role="lm_head"), ("batch", None, "vocab"))


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def chunked_softmax_xent(x: jnp.ndarray, w_lm: jnp.ndarray,
                         labels: jnp.ndarray,
                         mask: Optional[jnp.ndarray] = None,
                         chunk: int = 512) -> jnp.ndarray:
    """Cross-entropy over sequence chunks WITHOUT materialising the full
    (B, S, V) logits tensor: each chunk's logits are computed, reduced to a
    scalar, and (via jax.checkpoint) recomputed in backward.  This is the
    difference between a ~200 GiB and a ~1 GiB loss temp at
    (B=256, S=4096, V=152k) — see EXPERIMENTS.md §Perf."""
    from repro.models import runmode
    b, s, d = x.shape
    if s % chunk or s <= chunk:
        logits = constrain((x @ w_lm.astype(x.dtype)), ("batch", None, "vocab"))
        return cross_entropy(logits, labels, mask)
    nc = s // chunk
    xc = x.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, chunk).transpose(1, 0, 2)
    mc = (mask.reshape(b, nc, chunk).transpose(1, 0, 2)
          if mask is not None else jnp.ones_like(lc, jnp.float32))

    @jax.checkpoint
    def body(carry, inp):
        xb, lb, mb = inp
        logits = constrain((xb @ w_lm.astype(xb.dtype)),
                           ("batch", None, "vocab")).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, lb[..., None], -1)[..., 0]
        nll = (logz - gold) * mb
        return (carry[0] + jnp.sum(nll), carry[1] + jnp.sum(mb)), None

    (tot, cnt), _ = runmode.layer_scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)
