from repro.models.zoo import (
    DecoderLM,
    EncDecLM,
    HybridLM,
    MambaLM,
    build_model,
)

__all__ = ["DecoderLM", "EncDecLM", "HybridLM", "MambaLM", "build_model"]
