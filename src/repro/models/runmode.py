"""Lowering mode for the dry-run cost analysis.

XLA's `cost_analysis()` counts a while-loop body ONCE, so a scanned-layers
program under-reports FLOPs by ~n_layers x.  The dry-run therefore lowers
each cell twice:

  deploy program — scan-over-layers, chunked attention/loss, microbatched:
                   what actually runs; used for the compile proof,
                   memory_analysis and the HLO collective schedule (with
                   trip-count correction);
  cost program   — COST_MODE=True: layer scans fully unrolled, direct
                   (unchunked) attention and loss so every FLOP appears in
                   the top-level computation.  Compiled only for
                   cost_analysis; its buffers are never allocated.

The tiny SSD inter-chunk state scan stays rolled in both modes (its body is
a (h, p, n) elementwise update — negligible FLOPs; noted in EXPERIMENTS.md).
"""

from __future__ import annotations

import jax

COST_MODE = False


class cost_mode:
    def __init__(self, on: bool = True):
        self.on = on

    def __enter__(self):
        global COST_MODE
        self.prev = COST_MODE
        COST_MODE = self.on

    def __exit__(self, *exc):
        global COST_MODE
        COST_MODE = self.prev


def layer_scan(body, init, xs, length=None):
    """lax.scan that fully unrolls in cost mode."""
    return jax.lax.scan(body, init, xs, length=length,
                        unroll=True if COST_MODE else 1)
