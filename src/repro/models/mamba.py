"""Mamba2 (state-space duality) block — chunked SSD for train/prefill,
O(1)-state recurrence for decode.

Follows the minimal SSD reference of Dao & Gu (arXiv:2405.21060, Listing 1):
the sequence is split into chunks of Q tokens; within a chunk the output is
a (masked) quadratic form computed on the MXU, across chunks a tiny scan
propagates the (n_heads, head_dim, d_state) states.  This is the TPU-native
rendering of the paper['s] "SSM as matmuls" insight — every heavy op below
is an einsum.

Decode keeps two small carries per layer: the depthwise-conv window (last
`conv_width-1` inputs) and the SSM state h: h' = exp(dt*A) h + dt * B ⊗ x.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.common import Params, dense, dense_init, norm_init, rms_norm


def _dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    nheads = d_in // cfg.ssm_head_dim
    conv_dim = d_in + 2 * cfg.ssm_state
    return d_in, nheads, conv_dim


def mamba_init(rng, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    d_in, nh, conv_dim = _dims(cfg)
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    return {
        "in_proj": dense_init(k1, d, 2 * d_in + 2 * cfg.ssm_state + nh),
        "conv_w": jax.random.normal(k2, (cfg.conv_width, conv_dim), jnp.float32) * 0.2,
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(k3, (nh,), jnp.float32,
                                       np.log(1e-3), np.log(1e-1))))),
        "norm": norm_init(d_in),
        "out_proj": dense_init(k4, d_in, d),
    }


def mamba_logical_axes(cfg: ModelConfig) -> Params:
    return {
        "in_proj": {"w": ("embed", "conv_dim")},
        "conv_w": (None, "conv_dim"),
        "conv_b": ("conv_dim",),
        "A_log": ("ssm_heads",),
        "D": ("ssm_heads",),
        "dt_bias": ("ssm_heads",),
        "norm": {"scale": (None,)},
        "out_proj": {"w": ("conv_dim", "embed")},
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jnp.ndarray):
    d_in, nh, _ = _dims(cfg)
    n = cfg.ssm_state
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in : 2 * d_in + 2 * n]
    dt = zxbcdt[..., 2 * d_in + 2 * n :]
    return z, xbc, dt


def _causal_conv(w, b, xbc: jnp.ndarray, act=jax.nn.silu) -> jnp.ndarray:
    """Depthwise causal conv via static shifts (window is 4)."""
    width = w.shape[0]
    out = xbc * w[-1].astype(xbc.dtype)
    for i in range(1, width):
        shifted = jnp.pad(xbc, ((0, 0), (i, 0), (0, 0)))[:, : xbc.shape[1]]
        out = out + shifted * w[-1 - i].astype(xbc.dtype)
    return act(out + b.astype(xbc.dtype))


def _causal_conv_carry(w, b, xbc: jnp.ndarray, carry: jnp.ndarray,
                       act=jax.nn.silu) -> jnp.ndarray:
    """`_causal_conv` continued from a previous segment: the last `W-1`
    raw inputs of that segment (`carry`, (B, W-1, conv_dim)) stand in for
    the zero left-padding.  Same shift-and-accumulate order as
    `_causal_conv`, so a fresh (all-zero) carry is bitwise identical to the
    from-scratch conv — that equivalence is what lets chunked serve-time
    prefill reproduce `mamba_forward` exactly."""
    width = w.shape[0]
    s = xbc.shape[1]
    ext = jnp.concatenate([carry.astype(xbc.dtype), xbc], axis=1)
    out = xbc * w[-1].astype(xbc.dtype)
    for i in range(1, width):
        shifted = ext[:, width - 1 - i : width - 1 - i + s]
        out = out + shifted * w[-1 - i].astype(xbc.dtype)
    return act(out + b.astype(xbc.dtype))


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """(..., Q) -> (..., Q, Q) lower-triangular segment sums:
    out[i, j] = sum_{j < t <= i} x[t]; -inf above the diagonal."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, -1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int, h0=None):
    """SSD scan.  x: (b, s, h, p); dt: (b, s, h); A: (h,);
    B, C: (b, s, n).  Returns y: (b, s, h, p), final state (b, h, p, n).

    `h0` (optional, (b, h, p, n)) seeds the inter-chunk recurrence —
    serve-time chunked prefill threads the previous segment's state through
    it.  The default (None -> zeros) is the exact value the scan used
    before the parameter existed, so existing callers are bitwise
    unchanged."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q

    xc = x.reshape(b, nc, q, h, p)
    dtc = dt.reshape(b, nc, q, h)
    Bc = B.reshape(b, nc, q, n)
    Cc = C.reshape(b, nc, q, n)
    dA = dtc * A                                             # (b, nc, q, h)
    dA_cs = jnp.cumsum(dA, axis=2)

    # 1. intra-chunk (quadratic in q — all MXU work)
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))           # (b, nc, h, q, q)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)           # (b, nc, q, q)
    xdt = xc * dtc[..., None]                                # (b, nc, q, h, p)
    y_diag = jnp.einsum("bcqk,bchqk,bckhp->bcqhp", scores, L, xdt)
    # 2. chunk states
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)      # (b, nc, q, h)
    states = jnp.einsum("bckn,bckh,bckhp->bchpn", Bc, decay_states, xdt)

    # 3. inter-chunk recurrence over nc (tiny scan)
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])                # (b, nc, h)

    def step(h_prev, inp):
        decay, st = inp                                      # (b,h), (b,h,p,n)
        h_new = h_prev * decay[..., None, None] + st
        return h_new, h_prev

    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), x.dtype)
    h_final, h_prevs = jax.lax.scan(
        step, h0, (chunk_decay.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)               # (b, nc, h, p, n)

    # 4. contribution of previous-chunk states
    state_decay = jnp.exp(dA_cs)                             # (b, nc, q, h)
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", Cc, h_prevs, state_decay)

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, h_final


def mamba_forward(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                  return_state: bool = False):
    """x: (B, S, d) -> (B, S, d) (+ decode carries)."""
    d_in, nh, conv_dim = _dims(cfg)
    n, hd = cfg.ssm_state, cfg.ssm_head_dim
    b, s, _ = x.shape

    zxbcdt = dense(p["in_proj"], x)
    z, xbc_raw, dt = _split_proj(cfg, zxbcdt)
    xbc = _causal_conv(p["conv_w"], p["conv_b"], xbc_raw)
    xs = xbc[..., :d_in].reshape(b, s, nh, hd)
    Bmat = xbc[..., d_in : d_in + n]
    Cmat = xbc[..., d_in + n :]
    xs = constrain(xs, ("batch", None, "ssm_heads", None))

    A = -jnp.exp(p["A_log"]).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    y, h_final = ssd_chunked(xs.astype(jnp.float32), dt, A,
                             Bmat.astype(jnp.float32), Cmat.astype(jnp.float32),
                             cfg.ssm_chunk)
    y = y + xs.astype(jnp.float32) * p["D"][:, None]
    y = y.reshape(b, s, d_in).astype(x.dtype)
    y = rms_norm(p["norm"], y * jax.nn.silu(z))
    out = dense(p["out_proj"], y)
    if return_state:
        conv_cache = xbc_raw[:, -(cfg.conv_width - 1):, :]   # (B, W-1, conv_dim)
        return out, (conv_cache, h_final)
    return out


def mamba_chunk_forward(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                        conv_carry: jnp.ndarray, h0: jnp.ndarray,
                        seg_len: jnp.ndarray):
    """One serve-time prefill segment, resumable: `mamba_forward` over a
    fixed-width window `x` (B, C, d) of which only the first `seg_len`
    rows are real prompt, continuing from `conv_carry` (B, W-1, conv_dim)
    and SSM state `h0` (B, nh, hd, n).

    Bitwise contract (pinned by tests): feeding a prompt through this in
    `C`-token segments — zero carries on the first segment, each segment's
    returned carries into the next — reproduces `mamba_forward`'s outputs
    and final state EXACTLY, provided `C` is a multiple of `cfg.ssm_chunk`.
    Three mechanisms make that exact rather than approximate:

      * padding rows beyond `seg_len` get dt forced to 0.0 AFTER the
        softplus, which makes them exact identities in the SSD recurrence
        (`exp(0) = 1` state decay, `+0.0` state update) — no masking of x
        or B/C is needed;
      * the conv continues via `_causal_conv_carry`, whose accumulation
        order matches `_causal_conv` term for term;
      * the inter-chunk scan is seeded with `h0` through `ssd_chunked`'s
        initial-state parameter — the per-chunk step function is the one
        the full pass runs.

    Returns (y (B, C, d), new_conv_carry (B, W-1, conv_dim) f32,
    h_final (B, nh, hd, n) f32).  The new conv carry is read at offset
    `seg_len` of the carry-extended raw conv input, i.e. the last W-1 REAL
    rows even when the segment underfills the window."""
    d_in, nh, conv_dim = _dims(cfg)
    n, hd = cfg.ssm_state, cfg.ssm_head_dim
    b, s, _ = x.shape

    zxbcdt = dense(p["in_proj"], x, role="in_proj")
    z, xbc_raw, dt = _split_proj(cfg, zxbcdt)
    xbc = _causal_conv_carry(p["conv_w"], p["conv_b"], xbc_raw, conv_carry)
    xs = xbc[..., :d_in].reshape(b, s, nh, hd)
    Bmat = xbc[..., d_in : d_in + n]
    Cmat = xbc[..., d_in + n :]
    xs = constrain(xs, ("batch", None, "ssm_heads", None))

    A = -jnp.exp(p["A_log"]).astype(jnp.float32)
    seg_len = jnp.asarray(seg_len, jnp.int32)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    row = jnp.arange(s)[None, :, None]
    dtv = jnp.where(row < seg_len, dtv, 0.0)
    y, h_final = ssd_chunked(xs.astype(jnp.float32), dtv, A,
                             Bmat.astype(jnp.float32), Cmat.astype(jnp.float32),
                             cfg.ssm_chunk, h0=h0.astype(jnp.float32))
    y = y + xs.astype(jnp.float32) * p["D"][:, None]
    y = y.reshape(b, s, d_in).astype(x.dtype)
    y = rms_norm(p["norm"], y * jax.nn.silu(z))
    out = dense(p["out_proj"], y, role="out_proj")

    ext = jnp.concatenate([conv_carry.astype(xbc_raw.dtype), xbc_raw], axis=1)
    new_carry = jax.lax.dynamic_slice(
        ext, (jnp.int32(0), seg_len, jnp.int32(0)),
        (b, cfg.conv_width - 1, conv_dim)).astype(jnp.float32)
    return out, new_carry, h_final


def mamba_init_state(cfg: ModelConfig, batch: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    d_in, nh, conv_dim = _dims(cfg)
    conv = jnp.zeros((batch, cfg.conv_width - 1, conv_dim), jnp.float32)
    h = jnp.zeros((batch, nh, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)
    return conv, h


def mamba_decode(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                 conv_cache: jnp.ndarray, h: jnp.ndarray):
    """x: (B, 1, d); conv_cache: (B, W-1, conv_dim); h: (B, nh, hd, n)."""
    d_in, nh, conv_dim = _dims(cfg)
    n, hd = cfg.ssm_state, cfg.ssm_head_dim
    b = x.shape[0]

    zxbcdt = dense(p["in_proj"], x, role="in_proj")
    z, xbc_raw, dt = _split_proj(cfg, zxbcdt)
    window = jnp.concatenate([conv_cache.astype(xbc_raw.dtype), xbc_raw], axis=1)
    xbc = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", window, p["conv_w"].astype(window.dtype))
        + p["conv_b"].astype(window.dtype))[:, None, :]
    new_conv = window[:, 1:, :].astype(jnp.float32)

    xs = xbc[..., :d_in].reshape(b, nh, hd).astype(jnp.float32)
    Bm = xbc[:, 0, d_in : d_in + n].astype(jnp.float32)      # (B, n)
    Cm = xbc[:, 0, d_in + n :].astype(jnp.float32)
    A = -jnp.exp(p["A_log"]).astype(jnp.float32)
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B, nh)

    dA = jnp.exp(dtv * A)                                    # (B, nh)
    h = h * dA[..., None, None] + jnp.einsum(
        "bhp,bn,bh->bhpn", xs, Bm, dtv)
    y = jnp.einsum("bhpn,bn->bhp", h, Cm) + xs * p["D"][:, None]
    y = y.reshape(b, 1, d_in).astype(x.dtype)
    y = rms_norm(p["norm"], y * jax.nn.silu(z))
    return dense(p["out_proj"], y, role="out_proj"), new_conv, h
