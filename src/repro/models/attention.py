"""GQA attention with RoPE / M-RoPE / qk-norm, KV cache, cross-attention.

Three entry points sharing parameters:
  * `attn_forward`  — full-sequence (train / prefill); optionally returns the
    freshly built KV for cache initialisation;
  * `attn_decode`   — one new token against a (B, S_max, Hkv, D) cache,
    scatter-updating the cache at each sequence's current length;
  * cross-attention — pass `kv_override` (encoder K/V) to `attn_forward`.

The XLA einsum path is the default (it is what the multi-pod dry-run lowers);
`repro.kernels.ops.attention[_decode]` is the tuned-Pallas lane selected by
the WPK plan on TPU.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.common import (
    Params,
    apply_mrope,
    apply_rope,
    dense,
    dense_init,
    norm_init,
    rms_norm,
)


def attn_init(rng, cfg: ModelConfig, d_model: Optional[int] = None) -> Params:
    d = d_model or cfg.d_model
    hd = cfg.hd
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    p: Params = {
        "wq": dense_init(k1, d, cfg.n_heads * hd),
        "wk": dense_init(k2, d, cfg.n_kv_heads * hd),
        "wv": dense_init(k3, d, cfg.n_kv_heads * hd),
        "wo": dense_init(k4, cfg.n_heads * hd, d),
    }
    if cfg.qk_norm:
        p["q_norm"] = norm_init(hd)
        p["k_norm"] = norm_init(hd)
    return p


def attn_logical_axes(cfg: ModelConfig) -> Params:
    p = {
        "wq": {"w": ("embed", "heads")},
        "wk": {"w": ("embed", "kv_heads")},
        "wv": {"w": ("embed", "kv_heads")},
        "wo": {"w": ("heads", "embed")},
    }
    if cfg.qk_norm:
        p["q_norm"] = {"scale": (None,)}
        p["k_norm"] = {"scale": (None,)}
    return p


def _project_qkv(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                 positions: Optional[jnp.ndarray]):
    b, s, _ = x.shape
    hd = cfg.hd
    # role-tagged: the serve plan's stage `qkv_proj` choice dispatches these
    q = dense(p["wq"], x, role="qkv_proj").reshape(b, s, cfg.n_heads, hd)
    k = dense(p["wk"], x, role="qkv_proj").reshape(b, s, cfg.n_kv_heads, hd)
    v = dense(p["wv"], x, role="qkv_proj").reshape(b, s, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(p["q_norm"], q)
        k = rms_norm(p["k_norm"], k)
    if positions is not None:
        if cfg.mrope_sections:
            q = apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
            k = apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, ("batch", None, "heads", None))
    k = constrain(k, ("batch", None, "kv_heads", None))
    v = constrain(v, ("batch", None, "kv_heads", None))
    return q, k, v


_CHUNKED_KV_THRESHOLD = 1024
_KV_CHUNK = 1024


def _sdpa_direct(q, k, v, causal: bool, q_per_kv: int) -> jnp.ndarray:
    """einsum attention, GQA grouped so the KV is never repeated in HBM."""
    b, sq, h, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    scale = 1.0 / np.sqrt(d)
    qg = q.reshape(b, sq, hkv, q_per_kv, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(b, sq, h, d)


def _sdpa_chunked(q, k, v, causal: bool, q_per_kv: int,
                  kv_chunk: int = _KV_CHUNK) -> jnp.ndarray:
    """Online-softmax attention scanned over KV chunks — the XLA-path
    flash-attention equivalent.  Peak logits temp drops from O(Sq*Skv) to
    O(Sq*kv_chunk); `jax.checkpoint` on the chunk body keeps backward at the
    same footprint (recompute per chunk).  Numerics match `_sdpa_direct` to
    ~1e-6 (same f32 accumulation)."""
    b, sq, h, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    scale = 1.0 / np.sqrt(d)
    nc = skv // kv_chunk
    assert skv % kv_chunk == 0, (skv, kv_chunk)
    qg = q.reshape(b, sq, hkv, q_per_kv, d)
    kc = k.reshape(b, nc, kv_chunk, hkv, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nc, kv_chunk, hkv, d).transpose(1, 0, 2, 3, 4)
    qpos = jnp.arange(sq) + (skv - sq)  # query absolute positions

    @jax.checkpoint
    def body(carry, inp):
        m_prev, l_prev, acc = carry
        k_blk, v_blk, ci = inp
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_blk).astype(jnp.float32) * scale
        if causal:
            kpos = ci * kv_chunk + jnp.arange(kv_chunk)
            mask = qpos[:, None] >= kpos[None, :]
            s = jnp.where(mask[None, None, None], s, -1e30)
        m_cur = jnp.max(s, -1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, -1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(v_blk.dtype), v_blk)
        return (m_new, l_new, acc), None

    from repro.models import runmode
    m0 = jnp.full((b, hkv, q_per_kv, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hkv, q_per_kv, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, q_per_kv, sq, d), jnp.float32)
    (m, l, acc), _ = runmode.layer_scan(body, (m0, l0, a0),
                                        (kc, vc, jnp.arange(nc)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d).astype(q.dtype)


def _kv_chunk_for(skv: int, target: int = _KV_CHUNK) -> int:
    """Largest divisor of skv that is <= target (>= 64 to stay MXU-friendly)."""
    best = 0
    for c in range(min(target, skv), 63, -1):
        if skv % c == 0:
            best = c
            break
    return best


def _sdpa(q, k, v, causal: bool, q_per_kv: int) -> jnp.ndarray:
    skv = k.shape[1]
    if skv >= _CHUNKED_KV_THRESHOLD:
        chunk = _kv_chunk_for(skv)
        if chunk and skv // chunk > 1:
            return _sdpa_chunked(q, k, v, causal, q_per_kv, kv_chunk=chunk)
    return _sdpa_direct(q, k, v, causal, q_per_kv)


def attn_forward(
    p: Params,
    cfg: ModelConfig,
    x: jnp.ndarray,                         # (B, S, d)
    *,
    positions: Optional[jnp.ndarray] = None,
    causal: bool = True,
    kv_override: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
    return_kv: bool = False,
    backend: str = "xla",                   # 'pallas*' -> tuned flash kernel
    backend_config: Optional[Dict[str, Any]] = None,
    interpret: bool = True,
):
    b, s, _ = x.shape
    if kv_override is not None:             # cross-attention
        hd = cfg.hd
        q = dense(p["wq"], x).reshape(b, s, cfg.n_heads, hd)
        if cfg.qk_norm:
            q = rms_norm(p["q_norm"], q)
        k, v = kv_override
        causal = False
    else:
        q, k, v = _project_qkv(p, cfg, x, positions)
    if backend.startswith("pallas") and kv_override is None:
        from repro.kernels import ops as K
        out = K.attention(q, k, v, causal=causal, config=backend_config,
                          interpret=interpret)
    else:
        out = _sdpa(q, k, v, causal, cfg.q_per_kv)
    out = constrain(out, ("batch", None, "heads", None))
    y = dense(p["wo"], out.reshape(b, s, cfg.n_heads * cfg.hd))
    if return_kv:
        return y, (k, v)
    return y


def cross_kv(p: Params, cfg: ModelConfig, enc: jnp.ndarray):
    """Precompute encoder K/V once for all decoder steps."""
    b, s, _ = enc.shape
    hd = cfg.hd
    k = dense(p["wk"], enc).reshape(b, s, cfg.n_kv_heads, hd)
    v = dense(p["wv"], enc).reshape(b, s, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        k = rms_norm(p["k_norm"], k)
    return k, v


# ----------------------------------------------------------------- decode
def init_cache(cfg: ModelConfig, batch: int, max_seq: int, n_layers: int,
               dtype=jnp.bfloat16) -> Dict[str, Any]:
    shape = (n_layers, batch, max_seq, cfg.n_kv_heads, cfg.hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def cache_logical_axes() -> Dict[str, Any]:
    ax = ("layers", "batch", "kv_seq", "kv_heads", None)
    return {"k": ax, "v": ax}


def attn_decode(
    p: Params,
    cfg: ModelConfig,
    x: jnp.ndarray,                 # (B, 1, d)
    k_cache: jnp.ndarray,           # (B, S_max, Hkv, hd)
    v_cache: jnp.ndarray,
    lengths: jnp.ndarray,           # (B,) current lengths (position of new tok)
):
    b = x.shape[0]
    q, k_new, v_new = _project_qkv(p, cfg, x, lengths[:, None])
    if cfg.mrope_sections:
        # decode positions for M-RoPE: all three streams equal (text token)
        pos3 = jnp.broadcast_to(lengths[:, None, None], (b, 1, 3))
        q, k_new, v_new = _project_qkv(p, cfg, x, pos3)
    bidx = jnp.arange(b)
    k_cache = k_cache.at[bidx, lengths].set(k_new[:, 0])
    v_cache = v_cache.at[bidx, lengths].set(v_new[:, 0])

    scale = 1.0 / np.sqrt(cfg.hd)
    hkv, g = cfg.n_kv_heads, cfg.q_per_kv
    qg = q.reshape(b, hkv, g, cfg.hd)
    logits = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache).astype(jnp.float32) * scale
    pos = jnp.arange(k_cache.shape[1])[None, None, None, :]
    logits = jnp.where(pos <= lengths[:, None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bhgk,bkhd->bhgd", probs, v_cache)
    y = dense(p["wo"], out.reshape(b, 1, cfg.n_heads * cfg.hd))
    return y, k_cache, v_cache


# ------------------------------- paged (block-table) segment-packed prefill
def packed_row_map(seg_info, c: int):
    """Per-row segment assignment for a packed prompt chunk.

    `seg_info` is the (S, 3) int32 descriptor array [row_offset, seg_len,
    kv_start]: segment s occupies the contiguous chunk rows
    [row_offset, row_offset + seg_len) and its first row sits at absolute
    position `kv_start` of its own request (segments are packed from row 0
    in order; idle descriptor rows carry seg_len 0 with row_offset at the
    fill level, so offsets stay monotone).  Returns

        sid   (C,) int32 — each chunk row's segment index (clamped),
        pos   (C,) int32 — the row's absolute position in its OWN request,
        valid (C,) bool  — whether the row carries a real prompt token.

    All of it is arithmetic on traced data: packing geometry never changes
    the compiled program."""
    info = jnp.asarray(seg_info, jnp.int32)
    ns = info.shape[0]
    q0, qn, kv0 = info[:, 0], info[:, 1], info[:, 2]
    r = jnp.arange(c, dtype=jnp.int32)
    seg_end = q0 + qn
    sid = jnp.minimum(jnp.sum((r[:, None] >= seg_end[None, :]).astype(jnp.int32),
                              axis=1), ns - 1)
    valid = (r >= q0[sid]) & (r < seg_end[sid])
    pos = kv0[sid] + (r - q0[sid])
    return sid, jnp.where(valid, pos, 0), valid


def attn_prefill_packed(
    p: Params,
    cfg: ModelConfig,
    x: jnp.ndarray,                 # (1, C, d) — packed prompt segments
    k_pool: jnp.ndarray,            # (num_blocks, block_size, Hkv, hd)
    v_pool: jnp.ndarray,
    seg_tables: jnp.ndarray,        # (S, nbt) per-segment physical block ids
    positions: jnp.ndarray,         # (1, C[, 3]) absolute RoPE positions
    seg_info: jnp.ndarray,          # (S, 3) [row_offset, seg_len, kv_start]
    *,
    backend: str = "xla",
    backend_config=None,
    interpret: bool = True,
):
    """Segment-packed prefill attention against the *paged* KV pool.

    The chunk's K/V rows are scattered straight into each row's OWN
    request's blocks (row r of segment s lands at absolute position
    `kv_start_s + r - row_offset_s`; padding rows beyond the packed fill
    divert to the reserved null-sink block), then each query row attends
    causally to every committed row of its request — earlier chunks
    included, co-packed neighbours excluded — either through a per-row XLA
    gather of the row's own table or through the segment-aware Pallas
    kernel (`backend='pallas_attention'`,
    `kernels.ops.attention_prefill_packed`).  Packing geometry is carried
    by the traced descriptor array, so every packing of every step reuses
    one program — and because the XLA lane gathers the SAME full-width
    table view per row regardless of how rows are grouped into segments,
    packed and unpacked schedules compute identical float programs per
    row (byte-identical token streams)."""
    b, c, _ = x.shape
    block_size = k_pool.shape[1]
    ns, nbt = seg_tables.shape
    q, k_new, v_new = _project_qkv(p, cfg, x, positions)

    # incremental commit: each row scatters into its own segment's blocks
    sid, pos, valid = packed_row_map(seg_info, c)
    row_tables = jnp.asarray(seg_tables, jnp.int32)[sid]          # (C, nbt)
    blk = row_tables[jnp.arange(c), jnp.clip(pos // block_size, 0, nbt - 1)]
    blk = jnp.where(valid, blk, 0)                  # padding -> null sink
    off = pos % block_size
    k_pool = k_pool.at[blk, off].set(k_new[0].astype(k_pool.dtype))
    v_pool = v_pool.at[blk, off].set(v_new[0].astype(v_pool.dtype))

    hkv, g = cfg.n_kv_heads, cfg.q_per_kv
    if backend.startswith("pallas"):
        from repro.kernels import ops as K
        out = K.attention_prefill_packed(
            q, k_pool, v_pool, seg_tables, seg_info,
            config=backend_config, interpret=interpret)
    else:
        # XLA lane: gather each ROW's logical cache view from the pool via
        # its segment's table.  The gather width is always the full table
        # (nbt * block_size) and the mask is purely positional, so the
        # per-row float program is identical for every chunk split AND for
        # every packing — chunked, unchunked, packed and single-segment
        # prefill all agree bitwise on this lane.
        k_ctx = k_pool[row_tables].reshape(b, c, nbt * block_size, hkv, cfg.hd)
        v_ctx = v_pool[row_tables].reshape(b, c, nbt * block_size, hkv, cfg.hd)
        scale = 1.0 / np.sqrt(cfg.hd)
        qg = q.reshape(b, c, hkv, g, cfg.hd)
        logits = jnp.einsum("bqhgd,bqkhd->bhgqk", qg,
                            k_ctx).astype(jnp.float32) * scale
        kpos = jnp.arange(nbt * block_size)[None, None, None, None, :]
        logits = jnp.where(kpos <= pos[None, None, None, :, None],
                           logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(v_ctx.dtype)
        out = jnp.einsum("bhgqk,bqkhd->bqhgd", probs,
                         v_ctx).reshape(b, c, cfg.n_heads, cfg.hd)
    y = dense(p["wo"], out.reshape(b, c, cfg.n_heads * cfg.hd))
    return y, k_pool, v_pool


# ---------------------------------------------------- paged (block-table) decode
def attn_decode_paged(
    p: Params,
    cfg: ModelConfig,
    x: jnp.ndarray,                 # (B, 1, d) — B is the slot count
    k_pool: jnp.ndarray,            # (num_blocks, block_size, Hkv, hd)
    v_pool: jnp.ndarray,
    block_tables: jnp.ndarray,      # (B, nbt) physical block ids per slot
    lengths: jnp.ndarray,           # (B,) current context lengths (new token pos)
    *,
    backend: str = "xla",
    interpret: bool = True,         # False compiles the kernel on real TPU
):
    """Decode attention against the *paged* KV pool.

    The new token's K/V rows are scattered into each slot's current block
    (inactive slots carry all-null tables and write harmlessly into the
    reserved sink block 0), then attention runs either as an XLA
    gather+einsum over the slot's logical view of the pool, or through the
    block-table-aware Pallas kernel (`backend='pallas_attention'`) that
    indirects via scalar-prefetched tables without gathering."""
    b = x.shape[0]
    block_size = k_pool.shape[1]
    q, k_new, v_new = _project_qkv(p, cfg, x, lengths[:, None])
    if cfg.mrope_sections:
        pos3 = jnp.broadcast_to(lengths[:, None, None], (b, 1, 3))
        q, k_new, v_new = _project_qkv(p, cfg, x, pos3)

    bidx = jnp.arange(b)
    blk = block_tables[bidx, lengths // block_size]     # (B,) physical block
    off = lengths % block_size
    k_pool = k_pool.at[blk, off].set(k_new[:, 0].astype(k_pool.dtype))
    v_pool = v_pool.at[blk, off].set(v_new[:, 0].astype(v_pool.dtype))

    hkv, g = cfg.n_kv_heads, cfg.q_per_kv
    if backend.startswith("pallas"):
        from repro.kernels import ops as K
        out = K.attention_decode_paged(
            q.reshape(b, cfg.n_heads, cfg.hd), k_pool, v_pool,
            lengths + 1, block_tables, interpret=interpret)
        out = out.reshape(b, hkv, g, cfg.hd)
    else:
        # XLA lane: gather each slot's logical cache view from the pool.
        nbt = block_tables.shape[1]
        k_ctx = k_pool[block_tables].reshape(b, nbt * block_size, hkv, cfg.hd)
        v_ctx = v_pool[block_tables].reshape(b, nbt * block_size, hkv, cfg.hd)
        scale = 1.0 / np.sqrt(cfg.hd)
        qg = q.reshape(b, hkv, g, cfg.hd)
        logits = jnp.einsum("bhgd,bkhd->bhgk", qg,
                            k_ctx).astype(jnp.float32) * scale
        pos = jnp.arange(nbt * block_size)[None, None, None, :]
        logits = jnp.where(pos <= lengths[:, None, None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(v_ctx.dtype)
        out = jnp.einsum("bhgk,bkhd->bhgd", probs, v_ctx)
    y = dense(p["wo"], out.reshape(b, 1, cfg.n_heads * cfg.hd))
    return y, k_pool, v_pool
