"""Model assembly: one `Model` API over six architecture families.

  dense / moe / vlm : decoder-only transformer (scan-over-layers)
  ssm               : Mamba2 (SSD)
  hybrid            : Zamba2 (Mamba2 backbone + one shared attention block)
  encdec            : Whisper (encoder + cross-attending decoder)

All params are plain pytrees with a parallel `logical_axes()` tree consumed
by `repro.distributed.sharding`.  `forward` is the training/prefill path
(scan over stacked layer params, remat-policy aware); `prefill`/`decode_step`
are the serving path with explicit caches.  Modality frontends (vision
patches, audio frames) are STUBS per the assignment: `input_specs` provides
pre-computed embeddings.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import attention as A
from repro.models import mamba as M
from repro.models import mlp as F
from repro.models import runmode
from repro.models.common import (
    Params,
    chunked_softmax_xent,
    cross_entropy,
    embed,
    embed_init,
    layer_norm,
    layer_norm_init,
    norm_init,
    lm_head_logits,
    rms_norm,
    dense,
    dense_init,
)

Batch = Dict[str, jnp.ndarray]


def _norm_init(cfg: ModelConfig, d: int):
    return layer_norm_init(d) if cfg.norm == "layer" else norm_init(d)


def _norm(cfg: ModelConfig, p, x):
    return layer_norm(p, x) if cfg.norm == "layer" else rms_norm(p, x)


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)


# ===================================================================== blocks
def block_init(rng, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(rng)
    p = {
        "attn_norm": _norm_init(cfg, cfg.d_model),
        "attn": A.attn_init(k1, cfg),
        "mlp_norm": _norm_init(cfg, cfg.d_model),
    }
    if cfg.family == "moe":
        p["moe"] = F.moe_init(k2, cfg)
    else:
        p["mlp"] = F.mlp_init(k2, cfg.d_model, cfg.d_ff, gated=cfg.act == "silu")
    return p


def block_logical_axes(cfg: ModelConfig) -> Params:
    norm_ax = {"scale": (None,)} if cfg.norm == "rms" else {"scale": (None,), "bias": (None,)}
    p = {
        "attn_norm": dict(norm_ax),
        "attn": A.attn_logical_axes(cfg),
        "mlp_norm": dict(norm_ax),
    }
    if cfg.family == "moe":
        p["moe"] = F.moe_logical_axes(cfg)
    else:
        p["mlp"] = F.mlp_logical_axes(gated=cfg.act == "silu")
    return p


def block_forward(p: Params, cfg: ModelConfig, x, positions, causal=True):
    h = _norm(cfg, p["attn_norm"], x)
    x = x + A.attn_forward(p["attn"], cfg, h, positions=positions, causal=causal)
    h = _norm(cfg, p["mlp_norm"], x)
    if cfg.family == "moe":
        x = x + F.moe_apply(p["moe"], cfg, h, cfg.act)
    else:
        x = x + F.mlp_apply(p["mlp"], h, cfg.act)
    return constrain(x, ("batch", "seq", "embed"))


def block_decode(p: Params, cfg: ModelConfig, x, kc, vc, lengths):
    h = _norm(cfg, p["attn_norm"], x)
    y, kc, vc = A.attn_decode(p["attn"], cfg, h, kc, vc, lengths)
    x = x + y
    h = _norm(cfg, p["mlp_norm"], x)
    if cfg.family == "moe":
        x = x + F.moe_apply(p["moe"], cfg, h, cfg.act)
    else:
        x = x + F.mlp_apply(p["mlp"], h, cfg.act)
    return x, kc, vc


# ============================================================= decoder-only LM
class DecoderLM:
    """dense / moe / vlm families."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ----------------------------------------------------------------- init
    def init(self, rng) -> Params:
        cfg = self.cfg
        k_emb, k_blocks, k_final = jax.random.split(rng, 3)
        block_keys = jax.random.split(k_blocks, cfg.n_layers)
        blocks = jax.vmap(lambda k: block_init(k, cfg))(block_keys)
        return {
            "embed": embed_init(k_emb, cfg.vocab, cfg.d_model),
            "blocks": blocks,
            "final_norm": _norm_init(cfg, cfg.d_model),
            "lm_head": dense_init(k_final, cfg.d_model, cfg.vocab),
        }

    def logical_axes(self) -> Params:
        cfg = self.cfg
        blocks = jax.tree.map(
            lambda ax: ("layers",) + ax,
            block_logical_axes(cfg),
            is_leaf=lambda x: isinstance(x, tuple) and all(
                a is None or isinstance(a, str) for a in x))
        norm_ax = {"scale": (None,)} if cfg.norm == "rms" else {"scale": (None,), "bias": (None,)}
        return {
            "embed": {"emb": ("vocab", "embed_tbl")},
            "blocks": blocks,
            "final_norm": norm_ax,
            "lm_head": {"w": ("embed_vec", "vocab")},
        }

    # ------------------------------------------------------------ positions
    def _positions(self, batch: Batch, b: int, s: int):
        return self._position_ids(b, jnp.arange(s))

    def _position_ids(self, b: int, idx: jnp.ndarray):
        """RoPE position ids for sequence indices `idx` (any offset — the
        chunked-prefill path passes `chunk_start + arange(C)`, so a chunk's
        rows encode the same positions the full prompt would)."""
        cfg = self.cfg
        s = idx.shape[0]
        if not cfg.mrope_sections:
            return jnp.broadcast_to(idx, (b, s))
        # M-RoPE (qwen2-vl): vision tokens index a (t=0, h, w) grid; text
        # tokens use (t, t, t) offset past the vision span.
        nv = cfg.n_vision_tokens
        grid = max(1, int(np.sqrt(nv)))
        vis_h = (idx // grid).clip(0, grid - 1)
        vis_w = (idx % grid)
        t_text = jnp.maximum(idx - nv, 0) + grid  # text clock starts after grid
        is_vis = idx < nv
        pt = jnp.where(is_vis, 0, t_text)
        ph = jnp.where(is_vis, vis_h, t_text)
        pw = jnp.where(is_vis, vis_w, t_text)
        pos3 = jnp.stack([pt, ph, pw], -1)          # (S, 3)
        return jnp.broadcast_to(pos3, (b, s, 3))

    def _embed_inputs(self, params: Params, batch: Batch) -> jnp.ndarray:
        cfg = self.cfg
        x = embed(params["embed"], batch["tokens"], jnp.dtype(cfg.dtype))
        if cfg.family == "vlm" and "vision_embeds" in batch:
            nv = batch["vision_embeds"].shape[1]
            vis = batch["vision_embeds"].astype(x.dtype)
            x = jnp.concatenate([vis, x[:, nv:]], axis=1)
        return constrain(x, ("batch", "seq", "embed"))

    # -------------------------------------------------------------- forward
    def hidden(self, params: Params, batch: Batch) -> jnp.ndarray:
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        b, s, _ = x.shape
        positions = self._positions(batch, b, s)

        body = _remat(cfg, functools.partial(self._scan_body, cfg, positions))
        x, _ = runmode.layer_scan(body, x, params["blocks"])
        return _norm(cfg, params["final_norm"], x)

    def forward(self, params: Params, batch: Batch) -> jnp.ndarray:
        return lm_head_logits(params["lm_head"],
                              self.hidden(params, batch))

    @staticmethod
    def _scan_body(cfg, positions, x, bp):
        return block_forward(bp, cfg, x, positions), None

    def loss(self, params: Params, batch: Batch):
        x = self.hidden(params, batch)
        l = chunked_softmax_xent(x, params["lm_head"]["w"],
                                 batch["labels"], batch.get("mask"))
        return l, {"loss": l}

    # -------------------------------------------------------------- serving
    def init_cache(self, batch: int, max_seq: int) -> Dict[str, Any]:
        cfg = self.cfg
        cache = A.init_cache(cfg, batch, max_seq, cfg.n_layers,
                             jnp.dtype(cfg.dtype))
        cache["lengths"] = jnp.zeros((batch,), jnp.int32)
        return cache

    def cache_logical_axes(self):
        ax = A.cache_logical_axes()
        ax["lengths"] = ("batch",)
        return ax

    def prefill(self, params: Params, batch: Batch, max_seq: int):
        """Run the full prompt, build the cache, return last-position logits."""
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        b, s, _ = x.shape
        positions = self._positions(batch, b, s)

        def body(x, bp):
            h = _norm(cfg, bp["attn_norm"], x)
            y, (k, v) = A.attn_forward(bp["attn"], cfg, h, positions=positions,
                                       causal=True, return_kv=True)
            x = x + y
            h = _norm(cfg, bp["mlp_norm"], x)
            if cfg.family == "moe":
                x = x + F.moe_apply(bp["moe"], cfg, h, cfg.act)
            else:
                x = x + F.mlp_apply(bp["mlp"], h, cfg.act)
            return x, (k, v)

        x, (ks, vs) = runmode.layer_scan(_remat(cfg, body), x, params["blocks"])
        x = _norm(cfg, params["final_norm"], x)
        logits = lm_head_logits(params["lm_head"], x[:, -1:])

        cache = self.init_cache(b, max_seq)
        cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], ks.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
        cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], vs.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
        cache["lengths"] = jnp.full((b,), s, jnp.int32)
        return logits, cache

    def decode_step(self, params: Params, cache: Dict[str, Any],
                    tokens: jnp.ndarray):
        """tokens: (B, 1) -> logits (B, 1, V), updated cache."""
        cfg = self.cfg
        x = embed(params["embed"], tokens, jnp.dtype(cfg.dtype))
        lengths = cache["lengths"]

        def body(x, layer):
            bp, kc, vc = layer
            x, kc, vc = block_decode(bp, cfg, x, kc, vc, lengths)
            return x, (kc, vc)

        x, (ks, vs) = runmode.layer_scan(body, x, (params["blocks"], cache["k"], cache["v"]))
        x = _norm(cfg, params["final_norm"], x)
        logits = lm_head_logits(params["lm_head"], x)
        new_cache = dict(cache, k=ks, v=vs, lengths=lengths + 1)
        return logits, new_cache

    # ------------------------------------------------- paged serving path
    def decode_step_paged(self, params: Params, k_pool: jnp.ndarray,
                          v_pool: jnp.ndarray, block_tables: jnp.ndarray,
                          lengths: jnp.ndarray, tokens: jnp.ndarray,
                          *, attn_backend: str = "xla",
                          attn_interpret: bool = True):
        """One decode step over the slot batch against the paged KV pool.

        k_pool/v_pool: (L, num_blocks, block_size, Hkv, hd); tokens: (B, 1).
        Block tables and lengths have static shapes in the slot count, so
        admitting a request into the in-flight batch is a pure data update —
        the jitted program is reused as-is."""
        cfg = self.cfg
        x = embed(params["embed"], tokens, jnp.dtype(cfg.dtype))

        def body(x, layer):
            bp, kp, vp = layer
            h = _norm(cfg, bp["attn_norm"], x)
            y, kp, vp = A.attn_decode_paged(
                bp["attn"], cfg, h, kp, vp, block_tables, lengths,
                backend=attn_backend, interpret=attn_interpret)
            x = x + y
            h = _norm(cfg, bp["mlp_norm"], x)
            if cfg.family == "moe":
                x = x + F.moe_apply(bp["moe"], cfg, h, cfg.act)
            else:
                x = x + F.mlp_apply(bp["mlp"], h, cfg.act)
            return x, (kp, vp)

        x, (ks, vs) = runmode.layer_scan(body, x, (params["blocks"], k_pool, v_pool))
        x = _norm(cfg, params["final_norm"], x)
        logits = lm_head_logits(params["lm_head"], x)
        return logits, ks, vs

    def prefill_packed_paged(self, params: Params, k_pool: jnp.ndarray,
                             v_pool: jnp.ndarray, seg_tables: jnp.ndarray,
                             tokens: jnp.ndarray, seg_info: jnp.ndarray,
                             *, attn_backend: str = "xla",
                             attn_config: Optional[Dict[str, Any]] = None,
                             attn_interpret: bool = True):
        """A segment-packed prompt chunk against the paged KV pool — the
        prefill lane of the unified serving step.

        tokens: (1, C) carrying contiguous prompt segments from up to S
        requests; `seg_info` is the (S, 3) descriptor array [row_offset,
        seg_len, kv_start] and `seg_tables` (S, nbt) each segment's block
        table (idle descriptor rows: seg_len 0, all-null table).  Each
        layer scatters every row's K/V into its OWN segment's blocks
        (padding rows divert to the null sink) and attends causally over
        everything its request committed so far — never a co-packed
        neighbour — so a prompt split across steps or packed beside others
        computes exactly the single-shot prefill.  The descriptors are
        traced data: every packing of every step is a pure data update to
        ONE compiled program — admission never compiles.

        Returns (logits (1, S, V) at each segment's last real row — the
        first sampled token of every segment that completes its prompt
        this step — ks, vs)."""
        cfg = self.cfg
        x = embed(params["embed"], tokens, jnp.dtype(cfg.dtype))
        b, c, _ = x.shape
        _, pos, _ = A.packed_row_map(seg_info, c)   # pos zeroed on padding
        positions = self._position_ids(b, pos)

        def body(x, layer):
            bp, kp, vp = layer
            h = _norm(cfg, bp["attn_norm"], x)
            y, kp, vp = A.attn_prefill_packed(
                bp["attn"], cfg, h, kp, vp, seg_tables, positions,
                seg_info, backend=attn_backend,
                backend_config=attn_config, interpret=attn_interpret)
            x = x + y
            h = _norm(cfg, bp["mlp_norm"], x)
            if cfg.family == "moe":
                x = x + F.moe_apply(bp["moe"], cfg, h, cfg.act)
            else:
                x = x + F.mlp_apply(bp["mlp"], h, cfg.act)
            return x, (kp, vp)

        x, (ks, vs) = runmode.layer_scan(body, x,
                                         (params["blocks"], k_pool, v_pool))
        x = _norm(cfg, params["final_norm"], x)
        info = jnp.asarray(seg_info, jnp.int32)
        last = jnp.clip(info[:, 0] + info[:, 1] - 1, 0, c - 1)   # (S,)
        x_last = x[:, last]                                      # (1, S, d)
        logits = lm_head_logits(params["lm_head"], x_last)
        return logits, ks, vs

    @staticmethod
    def paged_cache_logical_axes():
        ax = ("layers", None, None, "kv_heads", None)
        return {"k": ax, "v": ax}


# ===================================================================== Mamba2
class MambaLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init(self, rng) -> Params:
        cfg = self.cfg
        k_emb, k_blocks, k_final = jax.random.split(rng, 3)
        keys = jax.random.split(k_blocks, cfg.n_layers)

        def one(k):
            return {"norm": _norm_init(cfg, cfg.d_model),
                    "mamba": M.mamba_init(k, cfg)}

        return {
            "embed": embed_init(k_emb, cfg.vocab, cfg.d_model),
            "blocks": jax.vmap(one)(keys),
            "final_norm": _norm_init(cfg, cfg.d_model),
            "lm_head": dense_init(k_final, cfg.d_model, cfg.vocab),
        }

    def logical_axes(self) -> Params:
        cfg = self.cfg
        block = {"norm": {"scale": (None,)},
                 "mamba": M.mamba_logical_axes(cfg)}
        blocks = jax.tree.map(
            lambda ax: ("layers",) + ax, block,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                a is None or isinstance(a, str) for a in x))
        return {
            "embed": {"emb": ("vocab", "embed_tbl")},
            "blocks": blocks,
            "final_norm": {"scale": (None,)},
            "lm_head": {"w": ("embed_vec", "vocab")},
        }

    def hidden(self, params: Params, batch: Batch) -> jnp.ndarray:
        cfg = self.cfg
        x = embed(params["embed"], batch["tokens"], jnp.dtype(cfg.dtype))
        x = constrain(x, ("batch", "seq", "embed"))

        def body(x, bp):
            h = _norm(cfg, bp["norm"], x)
            return x + M.mamba_forward(bp["mamba"], cfg, h), None

        x, _ = runmode.layer_scan(_remat(cfg, body), x, params["blocks"])
        return _norm(cfg, params["final_norm"], x)

    def forward(self, params: Params, batch: Batch) -> jnp.ndarray:
        return lm_head_logits(params["lm_head"],
                              self.hidden(params, batch))

    def loss(self, params, batch):
        x = self.hidden(params, batch)
        l = chunked_softmax_xent(x, params["lm_head"]["w"],
                                 batch["labels"], batch.get("mask"))
        return l, {"loss": l}

    def init_cache(self, batch: int, max_seq: int) -> Dict[str, Any]:
        cfg = self.cfg
        conv, h = M.mamba_init_state(cfg, batch)
        L = cfg.n_layers
        return {
            "conv": jnp.broadcast_to(conv, (L,) + conv.shape),
            "ssm": jnp.broadcast_to(h, (L,) + h.shape),
            "lengths": jnp.zeros((batch,), jnp.int32),
        }

    def cache_logical_axes(self):
        return {"conv": ("layers", "batch", None, "conv_dim"),
                "ssm": ("layers", "batch", "ssm_heads", None, "ssm_state"),
                "lengths": ("batch",)}

    def prefill(self, params: Params, batch: Batch, max_seq: int):
        cfg = self.cfg
        x = embed(params["embed"], batch["tokens"], jnp.dtype(cfg.dtype))
        b, s, _ = x.shape

        def body(x, bp):
            h = _norm(cfg, bp["norm"], x)
            y, (conv, hstate) = M.mamba_forward(bp["mamba"], cfg, h,
                                                return_state=True)
            return x + y, (conv.astype(jnp.float32), hstate)

        x, (convs, hs) = runmode.layer_scan(body, x, params["blocks"])
        x = _norm(cfg, params["final_norm"], x)
        logits = lm_head_logits(params["lm_head"], x[:, -1:])
        cache = {"conv": convs, "ssm": hs,
                 "lengths": jnp.full((b,), s, jnp.int32)}
        return logits, cache

    def decode_step(self, params: Params, cache, tokens):
        cfg = self.cfg
        x = embed(params["embed"], tokens, jnp.dtype(cfg.dtype))

        def body(x, layer):
            bp, conv, h = layer
            hin = _norm(cfg, bp["norm"], x)
            y, conv, h = M.mamba_decode(bp["mamba"], cfg, hin, conv, h)
            return x + y, (conv, h)

        x, (convs, hs) = runmode.layer_scan(
            body, x, (params["blocks"], cache["conv"], cache["ssm"]))
        x = _norm(cfg, params["final_norm"], x)
        logits = lm_head_logits(params["lm_head"], x)
        return logits, dict(cache, conv=convs, ssm=hs,
                            lengths=cache["lengths"] + 1)

    # ------------------------------------------------- continuous serving
    # Slot-pooled counterparts of DecoderLM's `prefill_packed_paged` /
    # `decode_step_paged`: the per-request state (conv window + SSM state)
    # is FIXED-SIZE, so instead of paged block tables each request owns one
    # row of a (layers, num_slots, ...) pool and every index below is
    # traced data — admission never compiles (see serve/statecache.py).

    def prefill_chunk_slots(self, params: Params, conv_pool: jnp.ndarray,
                            ssm_pool: jnp.ndarray, state_idx: jnp.ndarray,
                            tokens: jnp.ndarray, seg_len: jnp.ndarray,
                            seg_start: jnp.ndarray):
        """One prompt segment against the slot-pooled state cache — the
        prefill lane of the ssm unified serving step.

        tokens: (1, C) holding the segment's rows at offset 0 (rows past
        `seg_len` are padding — `mamba_chunk_forward` makes them exact
        identities); `state_idx` the request's pool row; `seg_start` the
        prompt offset of row 0.  seg_start == 0 selects ZERO carries
        in-program instead of the pool row, so a freshly claimed slot needs
        no zeroing pass (and no second executable).  Chunking a prompt in
        C-token segments reproduces `prefill` bitwise provided C is a
        multiple of `cfg.ssm_chunk` (the serve runtime rounds its chunk
        width up to guarantee that).

        Returns (logits (1, 1, V) at the segment's last real row, conv_pool,
        ssm_pool)."""
        cfg = self.cfg
        x = embed(params["embed"], tokens, jnp.dtype(cfg.dtype))
        state_idx = jnp.asarray(state_idx, jnp.int32)
        seg_len = jnp.asarray(seg_len, jnp.int32)
        fresh = jnp.asarray(seg_start, jnp.int32) == 0

        conv_c = conv_pool[:, state_idx]                 # (L, W-1, conv_dim)
        ssm_c = ssm_pool[:, state_idx]                   # (L, nh, hd, n)
        conv_c = jnp.where(fresh, jnp.zeros_like(conv_c), conv_c)
        ssm_c = jnp.where(fresh, jnp.zeros_like(ssm_c), ssm_c)

        def body(x, layer):
            bp, cc, hc = layer
            hin = _norm(cfg, bp["norm"], x)
            y, cc, hc = M.mamba_chunk_forward(bp["mamba"], cfg, hin,
                                              cc[None], hc[None], seg_len)
            return x + y, (cc[0], hc[0])

        x, (convs, hs) = runmode.layer_scan(
            body, x, (params["blocks"], conv_c, ssm_c))
        conv_pool = conv_pool.at[:, state_idx].set(convs)
        ssm_pool = ssm_pool.at[:, state_idx].set(hs)
        x = _norm(cfg, params["final_norm"], x)
        last = jnp.clip(seg_len - 1, 0, x.shape[1] - 1)
        x_last = jax.lax.dynamic_slice_in_dim(x, last, 1, axis=1)
        logits = lm_head_logits(params["lm_head"], x_last)
        return logits, conv_pool, ssm_pool

    def decode_step_slots(self, params: Params, conv_pool: jnp.ndarray,
                          ssm_pool: jnp.ndarray, state_idx: jnp.ndarray,
                          tokens: jnp.ndarray):
        """One decode token for every serving slot against the slot-pooled
        state cache.  state_idx: (B,) pool rows — idle/prefilling slots
        point at the NULL row 0, whose reads and colliding write-backs are
        garbage by construction and never reach a real request's row.
        tokens: (B, 1).  Returns (logits (B, 1, V), conv_pool, ssm_pool)."""
        cfg = self.cfg
        x = embed(params["embed"], tokens, jnp.dtype(cfg.dtype))
        state_idx = jnp.asarray(state_idx, jnp.int32)
        conv = conv_pool[:, state_idx]                   # (L, B, W-1, conv)
        ssm = ssm_pool[:, state_idx]                     # (L, B, nh, hd, n)

        def body(x, layer):
            bp, cv, h = layer
            hin = _norm(cfg, bp["norm"], x)
            y, cv, h = M.mamba_decode(bp["mamba"], cfg, hin, cv, h)
            return x + y, (cv, h)

        x, (convs, hs) = runmode.layer_scan(
            body, x, (params["blocks"], conv, ssm))
        conv_pool = conv_pool.at[:, state_idx].set(convs)
        ssm_pool = ssm_pool.at[:, state_idx].set(hs)
        x = _norm(cfg, params["final_norm"], x)
        logits = lm_head_logits(params["lm_head"], x)
        return logits, conv_pool, ssm_pool

    @staticmethod
    def slot_state_logical_axes():
        return {"conv": ("layers", None, None, "conv_dim"),
                "ssm": ("layers", None, "ssm_heads", None, "ssm_state")}


# ===================================================================== Zamba2
class HybridLM:
    """Mamba2 backbone with ONE shared attention block applied every
    `attn_every` layers (Zamba2's parameter-shared attention; the shared
    block sees concat(hidden, original_embeddings) through a down-projection).
    """

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.n_shared_uses = cfg.n_layers // cfg.attn_every

    def _group_sizes(self):
        cfg = self.cfg
        sizes = [cfg.attn_every] * (cfg.n_layers // cfg.attn_every)
        rem = cfg.n_layers % cfg.attn_every
        if rem:
            sizes.append(rem)
        return sizes

    def init(self, rng) -> Params:
        cfg = self.cfg
        k_emb, k_blocks, k_sh, k_final, k_proj = jax.random.split(rng, 5)
        keys = jax.random.split(k_blocks, cfg.n_layers)

        def one(k):
            return {"norm": _norm_init(cfg, cfg.d_model),
                    "mamba": M.mamba_init(k, cfg)}

        shared = {
            "in_proj": dense_init(k_proj, 2 * cfg.d_model, cfg.d_model),
            "attn_norm": _norm_init(cfg, cfg.d_model),
            "attn": A.attn_init(k_sh, cfg),
            "mlp_norm": _norm_init(cfg, cfg.d_model),
            "mlp": F.mlp_init(k_sh, cfg.d_model, cfg.d_ff),
        }
        return {
            "embed": embed_init(k_emb, cfg.vocab, cfg.d_model),
            "blocks": jax.vmap(one)(keys),
            "shared": shared,
            "final_norm": _norm_init(cfg, cfg.d_model),
            "lm_head": dense_init(k_final, cfg.d_model, cfg.vocab),
        }

    def logical_axes(self) -> Params:
        cfg = self.cfg
        block = {"norm": {"scale": (None,)}, "mamba": M.mamba_logical_axes(cfg)}
        blocks = jax.tree.map(
            lambda ax: ("layers",) + ax, block,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                a is None or isinstance(a, str) for a in x))
        return {
            "embed": {"emb": ("vocab", "embed_tbl")},
            "blocks": blocks,
            "shared": {
                "in_proj": {"w": ("embed", "embed")},
                "attn_norm": {"scale": (None,)},
                "attn": A.attn_logical_axes(cfg),
                "mlp_norm": {"scale": (None,)},
                "mlp": F.mlp_logical_axes(),
            },
            "final_norm": {"scale": (None,)},
            "lm_head": {"w": ("embed_vec", "vocab")},
        }

    def _shared_apply(self, sp, x, x0, positions):
        cfg = self.cfg
        xin = dense(sp["in_proj"], jnp.concatenate([x, x0], -1))
        h = _norm(cfg, sp["attn_norm"], xin)
        y = A.attn_forward(sp["attn"], cfg, h, positions=positions, causal=True)
        xin = xin + y
        h = _norm(cfg, sp["mlp_norm"], xin)
        return x + xin + F.mlp_apply(sp["mlp"], h, cfg.act)

    def hidden(self, params: Params, batch: Batch) -> jnp.ndarray:
        cfg = self.cfg
        x = embed(params["embed"], batch["tokens"], jnp.dtype(cfg.dtype))
        x0 = x
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))

        def mamba_body(x, bp):
            h = _norm(cfg, bp["norm"], x)
            return x + M.mamba_forward(bp["mamba"], cfg, h), None

        off = 0
        for gsize in self._group_sizes():
            group = jax.tree.map(lambda a: a[off:off + gsize], params["blocks"])
            x, _ = runmode.layer_scan(_remat(cfg, mamba_body), x, group)
            off += gsize
            if gsize == cfg.attn_every:   # full group -> shared attention
                x = self._shared_apply(params["shared"], x, x0, positions)
        return _norm(cfg, params["final_norm"], x)

    def forward(self, params: Params, batch: Batch) -> jnp.ndarray:
        return lm_head_logits(params["lm_head"],
                              self.hidden(params, batch))

    def loss(self, params, batch):
        x = self.hidden(params, batch)
        l = chunked_softmax_xent(x, params["lm_head"]["w"],
                                 batch["labels"], batch.get("mask"))
        return l, {"loss": l}

    # Serving: mamba states per layer + KV cache per shared-block use.
    def init_cache(self, batch: int, max_seq: int):
        cfg = self.cfg
        conv, h = M.mamba_init_state(cfg, batch)
        L = cfg.n_layers
        kv = A.init_cache(cfg, batch, max_seq, self.n_shared_uses,
                          jnp.dtype(cfg.dtype))
        return {
            "conv": jnp.broadcast_to(conv, (L,) + conv.shape),
            "ssm": jnp.broadcast_to(h, (L,) + h.shape),
            "k": kv["k"], "v": kv["v"],
            "lengths": jnp.zeros((batch,), jnp.int32),
        }

    def cache_logical_axes(self):
        return {"conv": ("layers", "batch", None, "conv_dim"),
                "ssm": ("layers", "batch", "ssm_heads", None, "ssm_state"),
                "k": ("layers", "batch", "kv_seq", "kv_heads", None),
                "v": ("layers", "batch", "kv_seq", "kv_heads", None),
                "lengths": ("batch",)}

    def prefill(self, params: Params, batch: Batch, max_seq: int):
        cfg = self.cfg
        # Prefill runs the forward path while accumulating every cache.
        x = embed(params["embed"], batch["tokens"], jnp.dtype(cfg.dtype))
        x0 = x
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        cache = self.init_cache(b, max_seq)

        def mamba_body(x, bp):
            h = _norm(cfg, bp["norm"], x)
            y, (conv, hstate) = M.mamba_forward(bp["mamba"], cfg, h,
                                                return_state=True)
            return x + y, (conv.astype(jnp.float32), hstate)

        convs, ssms, use = [], [], 0
        off = 0
        for gsize in self._group_sizes():
            group = jax.tree.map(lambda a: a[off:off + gsize], params["blocks"])
            x, (cv, hs) = runmode.layer_scan(mamba_body, x, group)
            convs.append(cv)
            ssms.append(hs)
            off += gsize
            if gsize == cfg.attn_every:
                sp = params["shared"]
                xin = dense(sp["in_proj"], jnp.concatenate([x, x0], -1))
                h = _norm(cfg, sp["attn_norm"], xin)
                y, (k, v) = A.attn_forward(sp["attn"], cfg, h,
                                           positions=positions, causal=True,
                                           return_kv=True)
                xin = xin + y
                h = _norm(cfg, sp["mlp_norm"], xin)
                x = x + xin + F.mlp_apply(sp["mlp"], h, cfg.act)
                cache["k"] = cache["k"].at[use, :, :s].set(k.astype(cache["k"].dtype))
                cache["v"] = cache["v"].at[use, :, :s].set(v.astype(cache["v"].dtype))
                use += 1
        x = _norm(cfg, params["final_norm"], x)
        logits = lm_head_logits(params["lm_head"], x[:, -1:])
        cache["conv"] = jnp.concatenate(convs, 0)
        cache["ssm"] = jnp.concatenate(ssms, 0)
        cache["lengths"] = jnp.full((b,), s, jnp.int32)
        return logits, cache

    def decode_step(self, params: Params, cache, tokens):
        cfg = self.cfg
        x = embed(params["embed"], tokens, jnp.dtype(cfg.dtype))
        x0 = x
        lengths = cache["lengths"]

        def mamba_body(x, layer):
            bp, conv, h = layer
            hin = _norm(cfg, bp["norm"], x)
            y, conv, h = M.mamba_decode(bp["mamba"], cfg, hin, conv, h)
            return x + y, (conv, h)

        convs, ssms, use = [], [], 0
        off = 0
        new_k, new_v = cache["k"], cache["v"]
        for gsize in self._group_sizes():
            layer = (jax.tree.map(lambda a: a[off:off + gsize], params["blocks"]),
                     cache["conv"][off:off + gsize],
                     cache["ssm"][off:off + gsize])
            x, (cv, hs) = runmode.layer_scan(mamba_body, x, layer)
            convs.append(cv)
            ssms.append(hs)
            off += gsize
            if gsize == cfg.attn_every:
                sp = params["shared"]
                xin = dense(sp["in_proj"], jnp.concatenate([x, x0], -1))
                h = _norm(cfg, sp["attn_norm"], xin)
                y, kc, vc = A.attn_decode(sp["attn"], cfg, h,
                                          new_k[use], new_v[use], lengths)
                new_k = new_k.at[use].set(kc)
                new_v = new_v.at[use].set(vc)
                xin = xin + y
                h = _norm(cfg, sp["mlp_norm"], xin)
                x = x + xin + F.mlp_apply(sp["mlp"], h, cfg.act)
                use += 1
        x = _norm(cfg, params["final_norm"], x)
        logits = lm_head_logits(params["lm_head"], x)
        return logits, dict(cache, conv=jnp.concatenate(convs, 0),
                            ssm=jnp.concatenate(ssms, 0), k=new_k, v=new_v,
                            lengths=lengths + 1)


# ===================================================================== Whisper
class EncDecLM:
    """Whisper-style encoder-decoder.  The audio conv frontend is a stub:
    `batch['audio_embeds']` carries pre-computed frame embeddings."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def _enc_block_init(self, k):
        cfg = self.cfg
        return {
            "attn_norm": _norm_init(cfg, cfg.d_model),
            "attn": A.attn_init(k, cfg),
            "mlp_norm": _norm_init(cfg, cfg.d_model),
            "mlp": F.mlp_init(k, cfg.d_model, cfg.d_ff, gated=False),
        }

    def _dec_block_init(self, k):
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "self_norm": _norm_init(cfg, cfg.d_model),
            "self_attn": A.attn_init(k1, cfg),
            "cross_norm": _norm_init(cfg, cfg.d_model),
            "cross_attn": A.attn_init(k2, cfg),
            "mlp_norm": _norm_init(cfg, cfg.d_model),
            "mlp": F.mlp_init(k3, cfg.d_model, cfg.d_ff, gated=False),
        }

    def init(self, rng) -> Params:
        cfg = self.cfg
        ke, kenc, kdec, kf = jax.random.split(rng, 4)
        enc_keys = jax.random.split(kenc, cfg.n_enc_layers)
        dec_keys = jax.random.split(kdec, cfg.n_layers)
        return {
            "embed": embed_init(ke, cfg.vocab, cfg.d_model),
            "enc_pos": jax.random.normal(ke, (cfg.enc_seq, cfg.d_model),
                                         jnp.float32) * 0.01,
            "encoder": jax.vmap(self._enc_block_init)(enc_keys),
            "enc_norm": _norm_init(cfg, cfg.d_model),
            "decoder": jax.vmap(self._dec_block_init)(dec_keys),
            "final_norm": _norm_init(cfg, cfg.d_model),
            "lm_head": dense_init(kf, cfg.d_model, cfg.vocab),
        }

    def logical_axes(self) -> Params:
        cfg = self.cfg
        norm_ax = {"scale": (None,)} if cfg.norm == "rms" else {"scale": (None,), "bias": (None,)}
        enc_block = {
            "attn_norm": dict(norm_ax), "attn": A.attn_logical_axes(cfg),
            "mlp_norm": dict(norm_ax), "mlp": F.mlp_logical_axes(gated=False),
        }
        dec_block = {
            "self_norm": dict(norm_ax), "self_attn": A.attn_logical_axes(cfg),
            "cross_norm": dict(norm_ax), "cross_attn": A.attn_logical_axes(cfg),
            "mlp_norm": dict(norm_ax), "mlp": F.mlp_logical_axes(gated=False),
        }
        is_ax = lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x)
        return {
            "embed": {"emb": ("vocab", "embed_tbl")},
            "enc_pos": (None, "embed"),
            "encoder": jax.tree.map(lambda ax: ("layers",) + ax, enc_block, is_leaf=is_ax),
            "enc_norm": dict(norm_ax),
            "decoder": jax.tree.map(lambda ax: ("layers",) + ax, dec_block, is_leaf=is_ax),
            "final_norm": dict(norm_ax),
            "lm_head": {"w": ("embed_vec", "vocab")},
        }

    def encode(self, params: Params, audio_embeds: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        x = audio_embeds.astype(jnp.dtype(cfg.dtype))
        x = x + params["enc_pos"].astype(x.dtype)[None, : x.shape[1]]

        def body(x, bp):
            h = _norm(cfg, bp["attn_norm"], x)
            x = x + A.attn_forward(bp["attn"], cfg, h, positions=None, causal=False)
            h = _norm(cfg, bp["mlp_norm"], x)
            return x + F.mlp_apply(bp["mlp"], h, "gelu"), None

        x, _ = runmode.layer_scan(_remat(cfg, body), x, params["encoder"])
        return _norm(cfg, params["enc_norm"], x)

    def _dec_body(self, cfg, positions, enc_kv_l, x, bp_and_kv):
        bp, (ek, ev) = bp_and_kv
        h = _norm(cfg, bp["self_norm"], x)
        x = x + A.attn_forward(bp["self_attn"], cfg, h, positions=positions,
                               causal=True)
        h = _norm(cfg, bp["cross_norm"], x)
        x = x + A.attn_forward(bp["cross_attn"], cfg, h, kv_override=(ek, ev))
        h = _norm(cfg, bp["mlp_norm"], x)
        return x + F.mlp_apply(bp["mlp"], h, "gelu"), None

    def hidden(self, params: Params, batch: Batch) -> jnp.ndarray:
        cfg = self.cfg
        enc = self.encode(params, batch["audio_embeds"])
        x = embed(params["embed"], batch["tokens"], jnp.dtype(cfg.dtype))
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        # precompute per-layer cross KV (scan over decoder layers)
        enc_kv = jax.vmap(lambda bp: A.cross_kv(bp["cross_attn"], cfg, enc))(
            params["decoder"])
        body = _remat(cfg, functools.partial(self._dec_body, cfg, positions, None))
        x, _ = runmode.layer_scan(body, x, (params["decoder"], enc_kv))
        return _norm(cfg, params["final_norm"], x)

    def forward(self, params: Params, batch: Batch) -> jnp.ndarray:
        return lm_head_logits(params["lm_head"],
                              self.hidden(params, batch))

    def loss(self, params, batch):
        x = self.hidden(params, batch)
        l = chunked_softmax_xent(x, params["lm_head"]["w"],
                                 batch["labels"], batch.get("mask"))
        return l, {"loss": l}

    def init_cache(self, batch: int, max_seq: int):
        cfg = self.cfg
        kv = A.init_cache(cfg, batch, max_seq, cfg.n_layers, jnp.dtype(cfg.dtype))
        enc_shape = (cfg.n_layers, batch, cfg.enc_seq, cfg.n_kv_heads, cfg.hd)
        return {
            "k": kv["k"], "v": kv["v"],
            "ek": jnp.zeros(enc_shape, jnp.dtype(cfg.dtype)),
            "ev": jnp.zeros(enc_shape, jnp.dtype(cfg.dtype)),
            "lengths": jnp.zeros((batch,), jnp.int32),
        }

    def cache_logical_axes(self):
        ax = ("layers", "batch", "kv_seq", "kv_heads", None)
        return {"k": ax, "v": ax, "ek": ax, "ev": ax, "lengths": ("batch",)}

    def prefill(self, params: Params, batch: Batch, max_seq: int):
        cfg = self.cfg
        enc = self.encode(params, batch["audio_embeds"])
        x = embed(params["embed"], batch["tokens"], jnp.dtype(cfg.dtype))
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        enc_kv = jax.vmap(lambda bp: A.cross_kv(bp["cross_attn"], cfg, enc))(
            params["decoder"])

        def body(x, bp_and_kv):
            bp, (ek, ev) = bp_and_kv
            h = _norm(cfg, bp["self_norm"], x)
            y, (k, v) = A.attn_forward(bp["self_attn"], cfg, h,
                                       positions=positions, causal=True,
                                       return_kv=True)
            x = x + y
            h = _norm(cfg, bp["cross_norm"], x)
            x = x + A.attn_forward(bp["cross_attn"], cfg, h, kv_override=(ek, ev))
            h = _norm(cfg, bp["mlp_norm"], x)
            return x + F.mlp_apply(bp["mlp"], h, "gelu"), (k, v)

        x, (ks, vs) = runmode.layer_scan(body, x, (params["decoder"], enc_kv))
        x = _norm(cfg, params["final_norm"], x)
        logits = lm_head_logits(params["lm_head"], x[:, -1:])
        cache = self.init_cache(b, max_seq)
        cache["k"] = cache["k"].at[:, :, :s].set(ks.astype(cache["k"].dtype))
        cache["v"] = cache["v"].at[:, :, :s].set(vs.astype(cache["v"].dtype))
        cache["ek"] = enc_kv[0].astype(cache["ek"].dtype)
        cache["ev"] = enc_kv[1].astype(cache["ev"].dtype)
        cache["lengths"] = jnp.full((b,), s, jnp.int32)
        return logits, cache

    def decode_step(self, params: Params, cache, tokens):
        cfg = self.cfg
        x = embed(params["embed"], tokens, jnp.dtype(cfg.dtype))
        lengths = cache["lengths"]

        def body(x, layer):
            bp, kc, vc, ek, ev = layer
            h = _norm(cfg, bp["self_norm"], x)
            y, kc, vc = A.attn_decode(bp["self_attn"], cfg, h, kc, vc, lengths)
            x = x + y
            h = _norm(cfg, bp["cross_norm"], x)
            x = x + A.attn_forward(bp["cross_attn"], cfg, h, kv_override=(ek, ev))
            h = _norm(cfg, bp["mlp_norm"], x)
            return x + F.mlp_apply(bp["mlp"], h, "gelu"), (kc, vc)

        x, (ks, vs) = runmode.layer_scan(
            body, x, (params["decoder"], cache["k"], cache["v"],
                      cache["ek"], cache["ev"]))
        x = _norm(cfg, params["final_norm"], x)
        logits = lm_head_logits(params["lm_head"], x)
        return logits, dict(cache, k=ks, v=vs, lengths=lengths + 1)


# ===================================================================== factory
def build_model(cfg: ModelConfig):
    if cfg.family in ("dense", "moe", "vlm"):
        return DecoderLM(cfg)
    if cfg.family == "ssm":
        return MambaLM(cfg)
    if cfg.family == "hybrid":
        return HybridLM(cfg)
    if cfg.family == "encdec":
        return EncDecLM(cfg)
    raise ValueError(f"unknown family {cfg.family}")
