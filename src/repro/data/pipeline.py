"""Deterministic, index-based synthetic LM data pipeline.

Fault-tolerance contract: the pipeline is STATELESS given the step index —
`batch(step)` is a pure function, so restoring a job means restoring one
integer.  Sharding contract: `batch(step, shard, n_shards)` returns only this
host's rows, identical to slicing the global batch — elastic restarts with a
different host count re-shard without skipping or repeating data.

The token stream is a counter-based hash (splitmix-style), which is both
reproducible and cheap; a next-token structure (label = cyclic function of
token) gives training a learnable signal so convergence tests are
meaningful.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    learnable: bool = True   # labels follow a fixed next-token rule


def _splitmix(x: np.ndarray) -> np.ndarray:
    x = (x + np.uint64(0x9E3779B97F4A7C15)) & np.uint64(0xFFFFFFFFFFFFFFFF)
    z = x
    z = ((z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)) & np.uint64(0xFFFFFFFFFFFFFFFF)
    z = ((z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)) & np.uint64(0xFFFFFFFFFFFFFFFF)
    return z ^ (z >> np.uint64(31))


class SyntheticLMData:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch(self, step: int, shard: int = 0, n_shards: int = 1) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        assert cfg.global_batch % n_shards == 0
        rows = cfg.global_batch // n_shards
        row0 = shard * rows
        # counter grid: (row, position) -> token
        r = (np.arange(rows) + row0 + step * cfg.global_batch).astype(np.uint64)
        p = np.arange(cfg.seq_len + 1, dtype=np.uint64)
        ctr = r[:, None] * np.uint64(1_000_003) + p[None, :] + np.uint64(cfg.seed) * np.uint64(7_919)
        toks = (_splitmix(ctr) % np.uint64(cfg.vocab)).astype(np.int64)
        if cfg.learnable:
            # next token is a fixed affine function of the current one:
            # perfectly learnable structure -> loss must fall.
            base = toks[:, :1]
            offs = np.arange(cfg.seq_len + 1, dtype=np.int64)
            toks = (base + offs * 17) % cfg.vocab
        tokens = toks[:, :-1].astype(np.int32)
        labels = toks[:, 1:].astype(np.int32)
        return {"tokens": tokens, "labels": labels}

    def state_dict(self, step: int) -> Dict[str, int]:
        return {"step": int(step), "seed": self.cfg.seed}

    @staticmethod
    def restore_step(state: Dict[str, int]) -> int:
        return int(state["step"])
