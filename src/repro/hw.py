"""Hardware model for the target accelerator (TPU v5e-class chip).

Every hardware-aware decision in WPK (search fitness, constraint checking,
roofline analysis, backend selection) reads from this single module so that
re-targeting (e.g. v5p, Trainium) is a one-file change.

Numbers are the ones mandated for the roofline analysis:
  * 197 TFLOP/s bf16 per chip (MXU peak)
  * 819 GB/s HBM bandwidth per chip
  * ~50 GB/s per ICI link
plus micro-architectural facts needed by the kernel schedule templates:
  * VMEM is ~128 MiB per core; a kernel's working set (all live BlockSpec
    blocks, double-buffered) must fit.
  * The MXU is a 128x128 systolic array; sublane tiling is (8, 128) for f32
    and (16, 128) for bf16 — block dims should be multiples of these.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Chip:
    """One accelerator chip."""

    name: str = "tpu_v5e"
    # Compute
    peak_bf16_flops: float = 197e12  # FLOP/s
    peak_f32_flops: float = 49.25e12  # MXU f32 is ~1/4 of bf16 on v5e-class
    # Memory
    hbm_bytes: int = 16 * 1024**3
    hbm_bw: float = 819e9  # B/s
    vmem_bytes: int = 128 * 1024**2
    # Interconnect
    ici_link_bw: float = 50e9  # B/s per link per direction
    ici_links_per_axis: int = 1  # conservative: 1 usable link per mesh axis
    dcn_bw: float = 25e9  # B/s per host, pod-to-pod (data-centre network)
    # MXU / VPU geometry
    mxu_dim: int = 128
    lane: int = 128  # minor-most register dim
    sublane_f32: int = 8
    sublane_bf16: int = 16
    vpu_flops: float = 4e12  # elementwise throughput ceiling

    def sublane(self, dtype) -> int:
        itemsize = np.dtype(dtype).itemsize
        if itemsize >= 4:
            return self.sublane_f32
        if itemsize == 2:
            return self.sublane_bf16
        return 32  # int8/fp8

    def peak_flops(self, dtype) -> float:
        itemsize = np.dtype(dtype).itemsize
        if itemsize >= 4:
            return self.peak_f32_flops
        return self.peak_bf16_flops


TPU_V5E = Chip()

# Secondary target kept to demonstrate the hardware-aware search re-targets:
# same search code, different constants -> different best configs.
TPU_V5P = Chip(
    name="tpu_v5p",
    peak_bf16_flops=459e12,
    peak_f32_flops=114.75e12,
    hbm_bytes=95 * 1024**3,
    hbm_bw=2765e9,
    vmem_bytes=128 * 1024**2,
    ici_link_bw=100e9,
)

CHIPS = {"tpu_v5e": TPU_V5E, "tpu_v5p": TPU_V5P}


def align_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def mxu_padded_dims(m: int, n: int, k: int, chip: Chip, dtype) -> Tuple[int, int, int]:
    """Dims as the MXU actually sees them (padded to tile granularity)."""
    s = chip.sublane(dtype)
    return align_up(m, s), align_up(n, chip.lane), align_up(k, chip.lane)


def matmul_flops(m: int, n: int, k: int) -> float:
    return 2.0 * m * n * k


def bytes_of(shape, dtype) -> int:
    return int(np.prod(shape)) * np.dtype(dtype).itemsize
