"""Sharded, atomic, reshard-on-restore checkpointing.

Fault-tolerance properties:
  * atomic commit — a checkpoint is written to `step_N.tmp/` and renamed to
    `step_N/` only after every leaf and the metadata have fsync'd; a job
    killed mid-save never corrupts the latest valid checkpoint;
  * auto-resume — `latest_step` scans for the newest committed step;
  * reshard-on-restore — leaves are saved as full (host-gathered) arrays with
    their pytree paths; `restore(..., shardings=...)` device_puts each leaf
    with the *target* sharding, so a job may restart on a different mesh
    (elastic scale-up/down) or host count;
  * bounded disk — `keep` newest checkpoints are retained;
  * async — `save_async` runs serialisation in a worker thread so the train
    loop only blocks on the previous save (one-deep pipeline).

Storage is one .npz per checkpoint (flat path->array) plus meta.json; at
real scale the same layout maps onto per-shard tensorstore files — the
manager API is the stable seam.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def _unflatten_into(template, flat: Dict[str, np.ndarray], shardings=None):
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(paths))
    leaves = []
    for (path, leaf), sh in zip(paths, shard_leaves):
        key = "/".join(_path_str(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        if sh is not None:
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        m = _STEP_RE.match(d)
        if m and os.path.exists(os.path.join(ckpt_dir, d, "meta.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


class CheckpointManager:
    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.dir = ckpt_dir
        self.keep = keep
        os.makedirs(ckpt_dir, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._last_error: Optional[BaseException] = None

    # ----------------------------------------------------------------- save
    def save(self, step: int, state: Dict[str, Any],
             extra_meta: Optional[Dict[str, Any]] = None) -> str:
        final = os.path.join(self.dir, f"step_{step}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        host_state = jax.tree.map(np.asarray, jax.device_get(state))
        flat = _flatten(host_state)
        np.savez(os.path.join(tmp, "state.npz"), **flat)
        meta = {"step": step, "n_leaves": len(flat)}
        meta.update(extra_meta or {})
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic commit
        self._gc()
        return final

    def save_async(self, step: int, state: Dict[str, Any],
                   extra_meta: Optional[Dict[str, Any]] = None) -> None:
        self.wait()
        # Snapshot to host *before* returning so the trainer can mutate state.
        host_state = jax.tree.map(np.asarray, jax.device_get(state))

        def work():
            try:
                self.save(step, host_state, extra_meta)
            except BaseException as e:  # surfaced on next wait()
                self._last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._last_error is not None:
            err, self._last_error = self._last_error, None
            raise err

    # -------------------------------------------------------------- restore
    def restore(self, step: int, template, shardings=None):
        path = os.path.join(self.dir, f"step_{step}", "state.npz")
        with np.load(path) as z:
            flat = {k: z[k] for k in z.files}
        return _unflatten_into(template, flat, shardings)

    def restore_latest(self, template, shardings=None):
        step = latest_step(self.dir)
        if step is None:
            return None, None
        return step, self.restore(step, template, shardings)

    def meta(self, step: int) -> Dict[str, Any]:
        with open(os.path.join(self.dir, f"step_{step}", "meta.json")) as f:
            return json.load(f)

    def _gc(self) -> None:
        steps = sorted(
            int(_STEP_RE.match(d).group(1))
            for d in os.listdir(self.dir) if _STEP_RE.match(d))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)
