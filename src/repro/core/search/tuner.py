"""Tuner: orchestrates the searches for one operator.

Mirrors the paper's end-to-end usage (§3): "given an operator, we used both
genetic search and RL-search to identify optimal code generation
configurations and single out the best for use", with the §3.3 cache checked
first.  Multi-threaded candidate evaluation is supported the way the paper
uses multi-threading for compilation (useful with WallClockFitness; the
analytical fitness is too cheap to benefit).
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

from repro import hw
from repro.core.costmodel import Fitness, ModelFitness
from repro.core.schedules import OpDesc, Template, templates_for
from repro.core.search.base import SearchResult, SearchTask
from repro.core.search.cache import MODEL_FITNESS, SearchCache
from repro.core.search.genetic import GeneticSearch
from repro.core.search.random_search import random_search
from repro.core.search.rl_search import RLSearch


class Tuner:
    def __init__(
        self,
        chip: hw.Chip = hw.TPU_V5E,
        fitness: Optional[Fitness] = None,
        cache: Optional[SearchCache] = None,
        methods: Sequence[str] = ("genetic", "rl"),
        genetic: Optional[GeneticSearch] = None,
        rl: Optional[RLSearch] = None,
        random_budget: int = 64,
        seed: int = 0,
    ):
        self.chip = chip
        self.fitness = fitness
        self.cache = cache if cache is not None else SearchCache()
        self.methods = tuple(methods)
        self.genetic = genetic or GeneticSearch()
        self.rl = rl or RLSearch(seed=seed)
        self.random_budget = random_budget
        self.seed = seed
        self.log: List[SearchResult] = []

    @property
    def fitness_kind(self) -> str:
        """Cache-key tag of the active fitness ('model' when defaulted)."""
        return getattr(self.fitness, "kind", MODEL_FITNESS) \
            if self.fitness is not None else MODEL_FITNESS

    def _make_task(self, op: OpDesc, template: Template) -> SearchTask:
        fitness = self.fitness or ModelFitness(self.chip)
        return SearchTask(op, template, fitness, self.chip, seed=self.seed)

    def tune(self, op: OpDesc, template: Optional[Template] = None,
             use_cache: bool = True) -> SearchResult:
        """Best configuration for `op` under `template` (default: the
        kind-appropriate template)."""
        template = template or templates_for(op)[0]

        if use_cache:
            hit = self.cache.get(self.chip.name, op, template.name,
                                 fitness=self.fitness_kind)
            if hit is not None:
                return SearchResult(op, template.name, hit["config"],
                                    hit["runtime_s"], 0, 0.0,
                                    hit["method"] + "+cache")

        results: List[SearchResult] = []
        for method in self.methods:
            task = self._make_task(op, template)
            if method == "genetic":
                results.append(self.genetic.run(task))
            elif method == "rl":
                results.append(self.rl.run(task))
            elif method == "random":
                results.append(random_search(task, self.random_budget))
            else:
                raise ValueError(method)

        best = min(results, key=lambda r: r.runtime_s)
        self.log.extend(results)
        self.cache.put(self.chip.name, op, template.name,
                       best.config, best.runtime_s, best.method,
                       fitness=self.fitness_kind)
        return best
