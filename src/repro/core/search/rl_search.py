"""RL-search (paper §2.4): schedule-parameter tuning as an RL problem.

State (the paper's 17-d O_conv): operator dims + current schedule-parameter
values + the runtime moving average alpha_t.  For a conv that is exactly

  O_conv = (N, C_in, C_out, K_h, K_w, H, W, Stride, Padding,
            T_x, T_y, T_z, Tile_x, Tile_y, Tile_z, Tile_rz, alpha_t)

with the CUDA thread/tile slots replaced by our TPU tunables (bm, bn, bk,
order, k_unroll, row_block, …) — the TPU schedule has the same cardinality of
"how work is carved up" knobs, so the observation stays 17-dimensional for
convs and is zero-padded for ops with fewer dims.

Action: discrete; "an action updates one parameter at a time" — action
(i, ±1) moves tunable i one step along its ordered choice list.  Multiple
rounds of predictions perform multiple parameter updates (paper).

Reward (Eq. 4):  r_t = alpha_{t-1} - min(beta_t, 2 * alpha_{t-1}), with the
moving average updated per Eq. 3: alpha_t = (alpha_{t-1} * 0.8 + beta_t) / t.
Runtimes are expressed in microseconds so rewards are well-conditioned.
"""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core.search.base import SearchResult, SearchTask
from repro.core.search.ppo import PPOAgent, PPOConfig

OBS_DIM = 17
_US = 1e6  # seconds -> microseconds


def _obs(task: SearchTask, cfg, alpha_us: float) -> np.ndarray:
    d = task.op.d
    if task.op.kind == "conv2d":
        dims = [d["n"], d["cin"], d["cout"], d["kh"], d["kw"], d["h"], d["w"],
                d["stride"], d["pad"]]
    elif task.op.kind == "matmul":
        dims = [d["m"], d["n"], d["k"], 0, 0, 0, 0, 0, 0]
    else:  # attention
        dims = [d["b"], d["q"], d["kv"], d["h"], d["d"], 0, 0, 0, 0]
    axes = task.template.axes(task.op)
    vals = []
    for name, choices in axes:
        v = cfg[name]
        vals.append(float(v) if isinstance(v, (int, float)) else float(choices.index(v)))
    vals = (vals + [0.0] * 7)[:7]
    obs = np.array([*dims, *vals, alpha_us], np.float32)
    return np.sign(obs) * np.log1p(np.abs(obs))  # log-scale conditioning


class RLSearch:
    def __init__(self, episodes: int = 6, steps_per_episode: int = 24,
                 ppo: PPOConfig = PPOConfig(), seed: int = 0):
        self.episodes = episodes
        self.steps = steps_per_episode
        self.ppo_cfg = ppo
        self.seed = seed

    def run(self, task: SearchTask) -> SearchResult:
        t0 = time.perf_counter()
        axes = task.template.axes(task.op)
        n_actions = 2 * len(axes)
        agent = PPOAgent(OBS_DIM, n_actions, self.ppo_cfg, seed=self.seed)

        for ep in range(self.episodes):
            cfg = task.random_config()
            beta0 = task.evaluate(cfg) * _US
            alpha, t_step = beta0, 1
            obs_l: List[np.ndarray] = []
            act_l: List[int] = []
            logp_l: List[float] = []
            rew_l: List[float] = []
            ob = _obs(task, cfg, alpha)

            for _ in range(self.steps):
                a, logp = agent.act(ob)
                pi, direction = divmod(a, 2)
                name, choices = axes[pi]
                idx = choices.index(cfg[name])
                nidx = int(np.clip(idx + (1 if direction else -1), 0, len(choices) - 1))
                new_cfg = dict(cfg)
                new_cfg[name] = choices[nidx]

                if task.template.validate(task.op, new_cfg, task.chip):
                    cfg = new_cfg
                    beta = task.evaluate(cfg) * _US
                else:  # invalid move: clamp to the worst-case penalty runtime
                    beta = 2.0 * alpha
                r = alpha - min(beta, 2.0 * alpha)        # Eq. 4
                t_step += 1
                alpha = (alpha * 0.8 + beta) / t_step     # Eq. 3

                obs_l.append(ob)
                act_l.append(a)
                logp_l.append(logp)
                rew_l.append(r)
                ob = _obs(task, cfg, alpha)

            agent.update(obs_l, act_l, logp_l, rew_l, ob)

        return task.result("rl", time.perf_counter() - t0)


def rl_search(task: SearchTask, **kw) -> SearchResult:
    return RLSearch(**kw).run(task)
