from repro.core.search.base import SearchResult, SearchTask
from repro.core.search.random_search import random_search
from repro.core.search.genetic import GeneticSearch, genetic_search
from repro.core.search.rl_search import RLSearch, rl_search
from repro.core.search.cache import SearchCache
from repro.core.search.tuner import Tuner

__all__ = [
    "SearchResult",
    "SearchTask",
    "random_search",
    "GeneticSearch",
    "genetic_search",
    "RLSearch",
    "rl_search",
    "SearchCache",
    "Tuner",
]
