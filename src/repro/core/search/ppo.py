"""Pure-JAX PPO, matching the paper's §2.4 description.

Policy network (verbatim from the paper): four fully-connected layers with
hidden sizes 512, 1024, 1024, 512 and activations tanh, tanh, selu, selu,
followed by a dropout layer with keep probability 15%, and a final linear FC
layer.  The output feeds a multinomial (categorical) distribution over the
discrete action space.  The value function V(s) is a separate small MLP.

Loss (Eq. 7):  L_t = E[ L_clip - c1 * L_VF + c2 * S[pi] ],  c1 = 0.15,
c2 = 20 (paper's values), maximised by Adam ascent (we minimise -L).
Advantages use the generalized advantage estimator (Eq. 5-6).

RLlib is replaced by this ~200-line implementation because the stack here is
JAX-only; the algorithmic content (clipped surrogate, GAE, minibatch epochs)
is identical.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

POLICY_WIDTHS = (512, 1024, 1024, 512)
POLICY_ACTS = ("tanh", "tanh", "selu", "selu")
DROPOUT_KEEP = 0.15
VALUE_WIDTHS = (256, 256)


def _act(x, kind):
    return {"tanh": jnp.tanh, "selu": jax.nn.selu}[kind](x)


def _init_mlp(rng, sizes):
    params = []
    keys = jax.random.split(rng, len(sizes) - 1)
    for k, (fan_in, fan_out) in zip(keys, zip(sizes[:-1], sizes[1:])):
        w = jax.random.normal(k, (fan_in, fan_out)) * jnp.sqrt(2.0 / fan_in)
        params.append({"w": w, "b": jnp.zeros(fan_out)})
    return params


def init_params(rng, obs_dim: int, n_actions: int):
    k1, k2 = jax.random.split(rng)
    return {
        "policy": _init_mlp(k1, (obs_dim,) + POLICY_WIDTHS + (n_actions,)),
        "value": _init_mlp(k2, (obs_dim,) + VALUE_WIDTHS + (1,)),
    }


def policy_logits(params, obs, *, dropout_rng=None):
    x = obs
    layers = params["policy"]
    for i, layer in enumerate(layers[:-1]):
        x = _act(x @ layer["w"] + layer["b"], POLICY_ACTS[i])
    if dropout_rng is not None:  # train-time dropout, keep prob 15% (paper)
        mask = jax.random.bernoulli(dropout_rng, DROPOUT_KEEP, x.shape)
        x = jnp.where(mask, x / DROPOUT_KEEP, 0.0)
    last = layers[-1]
    return x @ last["w"] + last["b"]


def value_fn(params, obs):
    x = obs
    for layer in params["value"][:-1]:
        x = jnp.tanh(x @ layer["w"] + layer["b"])
    last = params["value"][-1]
    return (x @ last["w"] + last["b"])[..., 0]


class Batch(NamedTuple):
    obs: jnp.ndarray       # (T, obs_dim)
    actions: jnp.ndarray   # (T,)
    logp_old: jnp.ndarray  # (T,)
    advantages: jnp.ndarray
    returns: jnp.ndarray


def gae(rewards: np.ndarray, values: np.ndarray, gamma: float, lam: float) -> Tuple[np.ndarray, np.ndarray]:
    """Eq. 5-6: delta_t = r_t + gamma*V(s_{t+1}) - V(s_t);
    A_t = sum (gamma*lam)^l delta_{t+l}.  `values` has length T+1."""
    T = len(rewards)
    adv = np.zeros(T, np.float32)
    acc = 0.0
    for t in reversed(range(T)):
        delta = rewards[t] + gamma * values[t + 1] - values[t]
        acc = delta + gamma * lam * acc
        adv[t] = acc
    returns = adv + values[:-1]
    return adv, returns


@dataclasses.dataclass
class PPOConfig:
    clip_eps: float = 0.2
    c1: float = 0.15      # value-loss coefficient (paper)
    c2: float = 20.0      # entropy coefficient (paper)
    gamma: float = 0.99
    lam: float = 0.95     # the paper's mu
    lr: float = 3e-4
    epochs: int = 4
    minibatch: int = 64


def ppo_loss(params, batch: Batch, cfg: PPOConfig, dropout_rng):
    logits = policy_logits(params, batch.obs, dropout_rng=dropout_rng)
    logp_all = jax.nn.log_softmax(logits, -1)
    logp = jnp.take_along_axis(logp_all, batch.actions[:, None], -1)[:, 0]
    ratio = jnp.exp(logp - batch.logp_old)
    adv = batch.advantages
    adv = (adv - adv.mean()) / (adv.std() + 1e-8)
    l_clip = jnp.minimum(
        ratio * adv, jnp.clip(ratio, 1 - cfg.clip_eps, 1 + cfg.clip_eps) * adv
    ).mean()
    v = value_fn(params, batch.obs)
    l_vf = jnp.mean((v - batch.returns) ** 2)
    entropy = -jnp.sum(jnp.exp(logp_all) * logp_all, -1).mean()
    # Eq. 7 (maximised) -> minimise the negation.
    return -(l_clip - cfg.c1 * l_vf + cfg.c2 * 1e-3 * entropy)


# ---- minimal Adam (self-contained so core.search has no deps on repro.optim)
def adam_init(params):
    z = jax.tree.map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree.map(lambda m_: m_ / (1 - b1 ** t), m)
    vh = jax.tree.map(lambda v_: v_ / (1 - b2 ** t), v)
    new = jax.tree.map(lambda p, m_, v_: p - lr * m_ / (jnp.sqrt(v_) + eps), params, mh, vh)
    return new, {"m": m, "v": v, "t": t}


def make_update_step(cfg: PPOConfig):
    @jax.jit
    def step(params, opt_state, batch: Batch, rng):
        loss, grads = jax.value_and_grad(ppo_loss)(params, batch, cfg, rng)
        params, opt_state = adam_update(params, grads, opt_state, cfg.lr)
        return params, opt_state, loss

    return step


class PPOAgent:
    """Thin stateful wrapper used by the RL-search driver."""

    def __init__(self, obs_dim: int, n_actions: int, cfg: PPOConfig = PPOConfig(), seed: int = 0):
        self.cfg = cfg
        self.n_actions = n_actions
        self.rng = jax.random.PRNGKey(seed)
        self.rng, k = jax.random.split(self.rng)
        self.params = init_params(k, obs_dim, n_actions)
        self.opt_state = adam_init(self.params)
        self._update = make_update_step(cfg)
        self._logits = jax.jit(lambda p, o: policy_logits(p, o))
        self._value = jax.jit(value_fn)

    def act(self, obs: np.ndarray) -> Tuple[int, float]:
        self.rng, k = jax.random.split(self.rng)
        logits = self._logits(self.params, jnp.asarray(obs)[None])[0]
        a = int(jax.random.categorical(k, logits))
        logp = float(jax.nn.log_softmax(logits)[a])
        return a, logp

    def values(self, obs: np.ndarray) -> np.ndarray:
        return np.asarray(self._value(self.params, jnp.asarray(obs)))

    def update(self, obs, actions, logp_old, rewards, last_obs) -> float:
        obs = np.asarray(obs, np.float32)
        values = self.values(np.concatenate([obs, np.asarray(last_obs, np.float32)[None]], 0))
        adv, rets = gae(np.asarray(rewards, np.float32), values, self.cfg.gamma, self.cfg.lam)
        batch_np = Batch(obs, np.asarray(actions, np.int32),
                         np.asarray(logp_old, np.float32), adv, rets)
        T = len(actions)
        losses = []
        for _ in range(self.cfg.epochs):
            self.rng, kperm, kdrop = jax.random.split(self.rng, 3)
            perm = np.asarray(jax.random.permutation(kperm, T))
            for s in range(0, T, self.cfg.minibatch):
                idx = perm[s : s + self.cfg.minibatch]
                mb = Batch(*(jnp.asarray(x[idx]) for x in batch_np))
                self.params, self.opt_state, loss = self._update(
                    self.params, self.opt_state, mb, kdrop
                )
                losses.append(float(loss))
        return float(np.mean(losses)) if losses else 0.0
