"""Random search — the weakest baseline in the paper's Figure 3a."""

from __future__ import annotations

import time

from repro.core.search.base import SearchResult, SearchTask


def random_search(task: SearchTask, budget: int = 64) -> SearchResult:
    t0 = time.perf_counter()
    for _ in range(budget):
        task.evaluate(task.random_config())
    return task.result("random", time.perf_counter() - t0)
