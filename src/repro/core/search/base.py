"""Shared search machinery: a task couples (op, template, fitness, chip)."""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import numpy as np

from repro import hw
from repro.core.costmodel import Fitness, ModelFitness
from repro.core.schedules import Config, OpDesc, Template


@dataclasses.dataclass
class SearchResult:
    op: OpDesc
    template: str
    config: Config
    runtime_s: float          # best fitness value found (modeled or measured)
    evals: int                # number of fitness evaluations spent
    wall_s: float             # search wall-clock
    method: str
    history: List[float] = dataclasses.field(default_factory=list)

    def to_json(self) -> Dict[str, Any]:
        return {
            "op": self.op.signature(),
            "label": self.op.label,
            "template": self.template,
            "config": self.config,
            "runtime_s": self.runtime_s,
            "evals": self.evals,
            "wall_s": self.wall_s,
            "method": self.method,
        }


class SearchTask:
    """One (operator, schedule-template) tuning problem."""

    def __init__(self, op: OpDesc, template: Template,
                 fitness: Optional[Fitness] = None,
                 chip: hw.Chip = hw.TPU_V5E, seed: int = 0):
        self.op = op
        self.template = template
        self.fitness = fitness or ModelFitness(chip)
        self.chip = chip
        self.rng = np.random.default_rng(seed)
        self.evals = 0
        self._best: Optional[Config] = None
        self._best_time = float("inf")
        self.history: List[float] = []

    def evaluate(self, cfg: Config) -> float:
        """Runtime of one candidate; tracks global best (the paper keeps the
        best configuration ever seen, not just the final population)."""
        if not self.template.validate(self.op, cfg, self.chip):
            return float("inf")
        t = self.fitness(self.op, cfg)
        self.evals += 1
        if t < self._best_time:
            self._best_time = t
            self._best = dict(cfg)
        self.history.append(self._best_time)
        return t

    def random_config(self) -> Config:
        return self.template.random_config(self.op, self.rng, self.chip)

    def result(self, method: str, wall_s: float) -> SearchResult:
        assert self._best is not None, "no valid configuration evaluated"
        return SearchResult(self.op, self.template.name, self._best,
                            self._best_time, self.evals, wall_s, method,
                            list(self.history))


def timed(fn):
    def wrapper(*a, **kw):
        t0 = time.perf_counter()
        out = fn(*a, **kw)
        return out, time.perf_counter() - t0
    return wrapper
