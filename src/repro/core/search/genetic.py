"""Genetic search (paper §2.3), implemented exactly as described.

Chromosome = the parameter vector s = {c_0 … c_{n-1}} (indices into each
tunable's finite choice list).  The four steps:

  Step1  initialise a random population; every random configuration is
         *verified first* against hardware constraints (VMEM-fit here; the
         paper's example is the <=1024-threads-per-block CUDA rule);
  Step2  fitness f(a_i) = a function of measured runtime — we use
         f = 1/runtime so faster individuals are "healthier";
  Step3  selection probability p(a_i) = f(a_i) / Σ f (Eq. 1); sort
         descending; top-k ELITES always survive; remaining |a'|-k children
         are bred by roulette-wheel parent selection using cumulative
         probabilities P(a_i) (Eq. 2) with inverse-transform sampling
         (P(a_{i-1}) < v <= P(a_i) selects individual i), then crossover +
         mutation;
  Step4  stop when the runtimes of all individuals in the generation are
         close enough (relative spread < `converge_rtol`), or at
         `max_generations`.  Population size may vary across generations
         (the paper notes theirs does) — we support a schedule.
"""

from __future__ import annotations

import time
from typing import List, Optional, Sequence

import numpy as np

from repro.core.search.base import SearchResult, SearchTask


class GeneticSearch:
    def __init__(
        self,
        population: int = 24,
        elites: int = 4,
        mutation_rate: float = 0.15,
        crossover_rate: float = 0.9,
        max_generations: int = 12,
        converge_rtol: float = 0.02,
        population_schedule: Optional[Sequence[int]] = None,
    ):
        self.population = population
        self.elites = elites
        self.mutation_rate = mutation_rate
        self.crossover_rate = crossover_rate
        self.max_generations = max_generations
        self.converge_rtol = converge_rtol
        self.population_schedule = population_schedule

    # ------------------------------------------------------------------
    def _roulette_pick(self, rng, cum_p: np.ndarray) -> int:
        """Inverse-transform sampling over cumulative selection probs."""
        v = rng.uniform(0.0, cum_p[-1])
        return int(np.searchsorted(cum_p, v, side="left"))

    def _crossover(self, rng, a: List[int], b: List[int]) -> List[int]:
        """Uniform gene-wise crossover."""
        return [ai if rng.random() < 0.5 else bi for ai, bi in zip(a, b)]

    def _mutate(self, task: SearchTask, rng, vec: List[int]) -> List[int]:
        axes = task.template.axes(task.op)
        out = list(vec)
        for i, (_, choices) in enumerate(axes):
            if rng.random() < self.mutation_rate:
                out[i] = int(rng.integers(len(choices)))
        return out

    def _valid_vec(self, task: SearchTask, vec: List[int]) -> bool:
        cfg = task.template.decode(task.op, vec)
        return task.template.validate(task.op, cfg, task.chip)

    # ------------------------------------------------------------------
    def run(self, task: SearchTask) -> SearchResult:
        t0 = time.perf_counter()
        rng = task.rng
        tmpl, op = task.template, task.op

        # Step1: verified random init.
        pop = [tmpl.encode(op, task.random_config()) for _ in range(self.population)]

        for gen in range(self.max_generations):
            # Step2: fitness = 1/runtime.
            runtimes = np.array([task.evaluate(tmpl.decode(op, v)) for v in pop])
            finite = np.isfinite(runtimes)
            if not finite.any():
                pop = [tmpl.encode(op, task.random_config()) for _ in range(len(pop))]
                continue
            fit = np.where(finite, 1.0 / np.maximum(runtimes, 1e-12), 0.0)

            # Step4: convergence — all runtimes in this generation are close.
            rt = runtimes[finite]
            if len(rt) == len(pop) and (rt.max() - rt.min()) <= self.converge_rtol * rt.min():
                break

            # Step3: Eq.1 selection probabilities, sorted descending.
            p = fit / fit.sum()
            order = np.argsort(-p)
            pop_sorted = [pop[i] for i in order]
            p_sorted = p[order]

            next_size = (
                self.population_schedule[min(gen, len(self.population_schedule) - 1)]
                if self.population_schedule
                else len(pop)
            )
            k = min(self.elites, next_size)
            new_pop = [list(v) for v in pop_sorted[:k]]  # elites always pass

            # Eq.2 cumulative probabilities over the m crossover participants.
            m = len(pop_sorted)
            cum_p = np.cumsum(p_sorted[:m])
            tries = 0
            while len(new_pop) < next_size and tries < 50 * next_size:
                tries += 1
                i = self._roulette_pick(rng, cum_p)
                j = self._roulette_pick(rng, cum_p)
                child = (
                    self._crossover(rng, pop_sorted[i], pop_sorted[j])
                    if rng.random() < self.crossover_rate
                    else list(pop_sorted[i])
                )
                child = self._mutate(task, rng, child)
                if self._valid_vec(task, child):
                    new_pop.append(child)
            while len(new_pop) < next_size:  # top-up with fresh random valids
                new_pop.append(tmpl.encode(op, task.random_config()))
            pop = new_pop

        return task.result("genetic", time.perf_counter() - t0)


def genetic_search(task: SearchTask, **kw) -> SearchResult:
    return GeneticSearch(**kw).run(task)
