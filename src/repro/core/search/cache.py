"""Search-result cache (paper §3.3): "a caching mechanism to reuse search
results ... can further expedite the search process for a family of models
composed from the same backbone".

Keyed on (chip name, template, FITNESS KIND, operator signature) — the
paper's computational-identity criterion (same shapes, filter size, stride,
padding) is exactly what `OpDesc.signature()` encodes.  The fitness kind
('model' analytical vs 'wallclock' measured) is part of the key because the
cached `runtime_s` is only meaningful under the fitness that produced it: a
cache populated under the analytical model must MISS for a wall-clock tuner
(and vice versa) instead of feeding stale configs and bogus runtimes into
plan selection.  Legacy entries persisted before the tag existed are served
as model-fitness.  Persisted as JSON so offline tuning databases ship with
the inference binary.
"""

from __future__ import annotations

import json
import os
import threading
from typing import Any, Dict, Optional

from repro.core.schedules import OpDesc

# Fitness kind of entries written before the key carried a tag, and the
# default when a caller doesn't say (matches Tuner's default ModelFitness).
MODEL_FITNESS = "model"


class SearchCache:
    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._lock = threading.Lock()
        self._store: Dict[str, Dict[str, Any]] = {}
        self.hits = 0
        self.misses = 0
        if path and os.path.exists(path):
            with open(path) as f:
                self._store = json.load(f)

    @staticmethod
    def key(chip_name: str, op: OpDesc, template: str,
            fitness: str = MODEL_FITNESS) -> str:
        return f"{chip_name}|{template}|{fitness}|{op.signature()}"

    @staticmethod
    def _legacy_key(chip_name: str, op: OpDesc, template: str) -> str:
        """Pre-fitness-tag key format (treated as model-fitness entries)."""
        return f"{chip_name}|{template}|{op.signature()}"

    def get(self, chip_name: str, op: OpDesc, template: str,
            fitness: str = MODEL_FITNESS) -> Optional[Dict[str, Any]]:
        with self._lock:
            entry = self._store.get(self.key(chip_name, op, template, fitness))
            if entry is None and fitness == MODEL_FITNESS:
                # back-compat: untagged legacy entries are model-fitness
                entry = self._store.get(self._legacy_key(chip_name, op, template))
        if entry is None:
            self.misses += 1
        else:
            self.hits += 1
        return entry

    def put(self, chip_name: str, op: OpDesc, template: str,
            config: Dict[str, Any], runtime_s: float, method: str,
            fitness: str = MODEL_FITNESS) -> None:
        with self._lock:
            self._store[self.key(chip_name, op, template, fitness)] = {
                "config": config,
                "runtime_s": runtime_s,
                "method": method,
            }

    def save(self, path: Optional[str] = None) -> None:
        path = path or self.path
        if not path:
            return
        tmp = path + ".tmp"
        with self._lock:
            with open(tmp, "w") as f:
                json.dump(self._store, f, indent=1, sort_keys=True)
        os.replace(tmp, path)  # atomic commit

    def __len__(self) -> int:
        return len(self._store)
