"""Graph-optimization passes (paper §2.1).

`optimize_graph` is the standard pipeline: simplify → constant folding →
layout transformation → fusion → simplify.  Each pass is a pure function
Graph -> Graph and is individually tested against the reference executor.
"""

from repro.core.passes.simplify import (
    remove_identities,
    dead_code_elimination,
    common_subexpression_elimination,
)
from repro.core.passes.constant_folding import constant_folding
from repro.core.passes.fusion import fuse_operators
from repro.core.passes.layout import transform_layout

__all__ = [
    "remove_identities",
    "dead_code_elimination",
    "common_subexpression_elimination",
    "constant_folding",
    "fuse_operators",
    "transform_layout",
    "optimize_graph",
]


def optimize_graph(graph, *, layout: str | None = "NHWC", fuse: bool = True):
    """The full §2.1 pipeline.  Returns a new Graph."""
    g = remove_identities(graph)
    g = common_subexpression_elimination(g)
    g = constant_folding(g)
    if fuse:  # fuse before layout so conv+bn+act chains are adjacent
        g = fuse_operators(g)
    if layout is not None:
        g = transform_layout(g, target=layout)
        g = constant_folding(g)  # fold the constant-side transposes we inserted
    if fuse:
        g = fuse_operators(g)    # fuse residual elementwise chains post-layout
    g = dead_code_elimination(g)
    g.validate()
    return g
