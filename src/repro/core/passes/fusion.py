"""Operator fusion (paper §2.1).

"Operator fusion aims to compress the computation within a sub-graph into one
equivalent novel operator in order to reduce the communication overhead
between operators ... as well as improve hardware usage efficiency due to the
increase of compute intensiveness within the novel operator."

Patterns implemented (all classic inference patterns on CNN/transformer
graphs, and all of them have a single-kernel Pallas implementation in
`repro.kernels.fused`):

  conv2d  -> batch_norm                      => fused_conv2d (BN folded into
                                                weights/bias — constants only)
  conv2d  -> bias_add -> [activation]        => fused_conv2d
  matmul  -> bias_add/add -> [activation]    => fused_matmul
  elementwise chain (unary / binary-with-1-producer) => fused_elementwise

A tensor is fusable only if it has exactly one consumer and is not a graph
output — fusing it away must not change the graph's observable behaviour.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.graph import (
    ELEMENTWISE_BINARY,
    ELEMENTWISE_UNARY,
    Graph,
    Node,
)

_ACTS = ("relu", "gelu", "silu", "tanh", "sigmoid")


def _sole_consumer(g: Graph, tensor: str) -> Optional[Node]:
    if tensor in g.outputs:
        return None
    consumers = g.consumers(tensor)
    return consumers[0] if len(consumers) == 1 else None


def _fold_bn_into_conv(g: Graph, conv: Node, bn: Node) -> bool:
    """conv2d -> batch_norm with constant scale/shift: fold into weights."""
    w_name = conv.inputs[1]
    scale_n, shift_n = bn.inputs[1], bn.inputs[2]
    if w_name not in g.constants or scale_n not in g.constants or shift_n not in g.constants:
        return False
    w = g.constants[w_name]
    scale = g.constants[scale_n]
    shift = g.constants[shift_n]
    layout = conv.attrs.get("layout", "NCHW")
    if layout == "NCHW":  # w: (O, I, Kh, Kw)
        w2 = w * scale.reshape(-1, 1, 1, 1)
    else:  # w: (Kh, Kw, I, O)
        w2 = w * scale.reshape(1, 1, 1, -1)
    new_w = g.add_constant(g.fresh("wfold"), w2.astype(w.dtype))
    bias = shift.astype(np.float32)
    if len(conv.inputs) > 2 and conv.inputs[2] in g.constants:
        bias = bias + g.constants[conv.inputs[2]] * scale
    new_b = g.add_constant(g.fresh("bfold"), bias.astype(np.float32))
    conv.op = "fused_conv2d"
    conv.inputs = [conv.inputs[0], new_w, new_b]
    g.rewire(bn.outputs[0], conv.outputs[0])
    g.remove_node(bn)
    return True


def fuse_operators(graph: Graph) -> Graph:
    g = graph.copy()
    changed = True
    while changed:
        changed = False

        for node in list(g.nodes):
            if node not in g.nodes:
                continue

            # --- conv2d -> batch_norm -----------------------------------
            if node.op in ("conv2d", "fused_conv2d") and not node.attrs.get("activation"):
                nxt = _sole_consumer(g, node.outputs[0])
                if nxt is not None and nxt.op == "batch_norm" and nxt.inputs[0] == node.outputs[0]:
                    if _fold_bn_into_conv(g, node, nxt):
                        changed = True
                        continue

            # --- conv2d/matmul -> bias_add ------------------------------
            if node.op in ("conv2d", "matmul", "fused_conv2d", "fused_matmul") and len(node.inputs) == 2:
                nxt = _sole_consumer(g, node.outputs[0])
                is_bias = nxt is not None and (
                    nxt.op == "bias_add"
                    or (nxt.op == "add" and nxt.inputs[0] == node.outputs[0]
                        and g.tensors[nxt.inputs[1]].shape
                        == (g.tensors[node.outputs[0]].shape[-1],))
                )
                if is_bias and nxt.inputs[0] == node.outputs[0]:
                    node.op = "fused_conv2d" if "conv" in node.op else "fused_matmul"
                    node.inputs = list(node.inputs) + [nxt.inputs[1]]
                    g.rewire(nxt.outputs[0], node.outputs[0])
                    g.remove_node(nxt)
                    changed = True
                    continue

            # --- fused compute -> activation ----------------------------
            if node.op in ("conv2d", "matmul", "fused_conv2d", "fused_matmul") and not node.attrs.get("activation"):
                nxt = _sole_consumer(g, node.outputs[0])
                if nxt is not None and nxt.op in _ACTS:
                    node.op = "fused_conv2d" if "conv" in node.op else "fused_matmul"
                    node.attrs["activation"] = nxt.op
                    g.rewire(nxt.outputs[0], node.outputs[0])
                    g.remove_node(nxt)
                    changed = True
                    continue

            # --- elementwise chains --------------------------------------
            if node.op in ELEMENTWISE_UNARY + ELEMENTWISE_BINARY or node.op == "fused_elementwise":
                nxt = _sole_consumer(g, node.outputs[0])
                if nxt is None or nxt.inputs[0] != node.outputs[0]:
                    continue
                if nxt.op not in ELEMENTWISE_UNARY + ELEMENTWISE_BINARY:
                    continue
                # shape-preserving only (no broadcasting surprises)
                if g.tensors[nxt.outputs[0]].shape != g.tensors[node.outputs[0]].shape:
                    continue
                # e.g. add(t, t): t feeds nxt twice — fusing would dangle it
                if node.outputs[0] in nxt.inputs[1:]:
                    continue
                if node.op == "fused_elementwise":
                    chain = list(node.attrs["chain"])
                    extra = list(node.inputs[1:])
                else:
                    chain = [{"op": node.op}]
                    extra = list(node.inputs[1:])
                chain.append({"op": nxt.op})
                extra += list(nxt.inputs[1:])
                node.op = "fused_elementwise"
                node.attrs = {"chain": chain}
                node.inputs = [node.inputs[0]] + extra
                g.rewire(nxt.outputs[0], node.outputs[0])
                g.remove_node(nxt)
                changed = True
                continue

    g.prune_tensors()
    return g
