"""Constant folding (paper §2.1): "applies to sub-graphs whose output values
can be computed statically beforehand"."""

from __future__ import annotations

import numpy as np

from repro.core import ref_ops
from repro.core.graph import Graph


def constant_folding(graph: Graph, max_fold_bytes: int = 256 * 1024 * 1024) -> Graph:
    """Evaluate every node whose inputs are all constants and replace it with
    a constant tensor.  `max_fold_bytes` guards against materialising folded
    tensors larger than what we would ever want in the inference binary."""
    g = graph.copy()
    changed = True
    while changed:
        changed = False
        for node in list(g.nodes):
            if not all(i in g.constants for i in node.inputs):
                continue
            out_spec = g.tensors[node.outputs[0]]
            if out_spec.nbytes() > max_fold_bytes:
                continue
            vals = [g.constants[i] for i in node.inputs]
            out = np.asarray(ref_ops.run_op(node.op, vals, node.attrs))
            out_name = node.outputs[0]
            g.constants[out_name] = out
            g.tensors[out_name].shape = tuple(out.shape)
            g.tensors[out_name].dtype = str(out.dtype)
            g.remove_node(node)
            changed = True
    g.prune_tensors()
    return g
