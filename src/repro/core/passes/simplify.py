"""Identity/dropout removal, dead-code elimination, CSE.

The paper explicitly lists "removing redundant operations (e.g. identity and
dropout)" as a graph optimization (§1); CSE/DCE are the standard companions
that keep the graph canonical between passes.
"""

from __future__ import annotations

from repro.core.graph import Graph


def remove_identities(graph: Graph) -> Graph:
    """Drop `identity` and inference-mode `dropout` nodes by rewiring their
    consumers directly to the producer tensor."""
    g = graph.copy()
    changed = True
    while changed:
        changed = False
        for node in list(g.nodes):
            if node.op in ("identity", "dropout"):
                src, dst = node.inputs[0], node.outputs[0]
                g.rewire(dst, src)
                g.remove_node(node)
                changed = True
    g.prune_tensors()
    return g


def dead_code_elimination(graph: Graph) -> Graph:
    """Remove nodes whose outputs can never reach a graph output."""
    g = graph.copy()
    live = set(g.outputs)
    # Walk nodes in reverse topological order, marking live inputs.
    order = g.toposort()
    keep = []
    for node in reversed(order):
        if any(o in live for o in node.outputs):
            keep.append(node)
            live.update(node.inputs)
    keep.reverse()
    g.nodes = keep
    g.prune_tensors()
    return g


def common_subexpression_elimination(graph: Graph) -> Graph:
    """Merge nodes with identical (op, inputs, attrs)."""
    g = graph.copy()
    changed = True
    while changed:
        changed = False
        seen = {}
        for node in list(g.nodes):
            key = (node.op, tuple(node.inputs), node.signature(g))
            if key in seen:
                canonical = seen[key]
                for old, new in zip(node.outputs, canonical.outputs):
                    g.rewire(old, new)
                g.remove_node(node)
                changed = True
            else:
                seen[key] = node
    g.prune_tensors()
    return g
