"""Data-layout transformation (paper §2.1) — hardware-adapted for TPU.

The paper transforms layouts "to get faster execution on the target
hardware"; its ResNet-18 input is NCHW (Caffe).  On TPU the vector lanes are
the minor-most 128 elements, so convolutions want NHWC (channels minor).
This pass rewrites every conv/pool subgraph from NCHW to NHWC:

  * graph/activation edges: insert `transpose` at the NCHW->NHWC boundary and
    back at the NHWC->NCHW boundary, then cancel adjacent inverse pairs;
  * constant weights: transpose OIHW -> HWIO (folded immediately since they
    are constants);
  * conv/pool node attrs: layout="NHWC".

Adjacent transpose-transpose cancellation means an all-conv pipeline pays for
exactly one transpose at the graph input and one at the output.
"""

from __future__ import annotations

import numpy as np

from repro.core.graph import Graph, Node

_NCHW2NHWC = (0, 2, 3, 1)
_NHWC2NCHW = (0, 3, 1, 2)
_LAYOUT_OPS = ("conv2d", "fused_conv2d", "max_pool", "avg_pool",
               "global_avg_pool", "batch_norm", "bias_add")


def _perm_shape(shape, perm):
    return tuple(shape[p] for p in perm)


def transform_layout(graph: Graph, target: str = "NHWC") -> Graph:
    if target != "NHWC":
        raise ValueError("only NHWC target is supported on TPU")
    g = graph.copy()

    for node in list(g.nodes):
        if node.op not in _LAYOUT_OPS:
            continue
        if node.attrs.get("layout", "NCHW") != "NCHW":
            continue
        x_name = node.inputs[0]
        x_spec = g.tensors[x_name]
        if len(x_spec.shape) != 4 and node.op != "global_avg_pool":
            continue

        # -- input side: NCHW -> NHWC ------------------------------------
        if len(x_spec.shape) == 4:
            t_in = g.fresh("nhwc")
            g.tensors[t_in] = type(x_spec)(t_in, _perm_shape(x_spec.shape, _NCHW2NHWC), x_spec.dtype)
            g.nodes.insert(
                g.nodes.index(node),
                Node("transpose", f"to_nhwc_{t_in}", [x_name], [t_in], {"perm": list(_NCHW2NHWC)}),
            )
            node.inputs[0] = t_in

        # -- weights: OIHW -> HWIO (constants fold; activations transpose)
        if node.op in ("conv2d", "fused_conv2d"):
            w_name = node.inputs[1]
            if w_name in g.constants:
                w = g.constants[w_name]
                new_w = g.add_constant(g.fresh("w_hwio"), np.transpose(w, (2, 3, 1, 0)))
                node.inputs[1] = new_w
            else:
                w_spec = g.tensors[w_name]
                t_w = g.fresh("w_hwio")
                g.tensors[t_w] = type(w_spec)(t_w, _perm_shape(w_spec.shape, (2, 3, 1, 0)), w_spec.dtype)
                g.nodes.insert(
                    g.nodes.index(node),
                    Node("transpose", f"w_to_hwio_{t_w}", [w_name], [t_w], {"perm": [2, 3, 1, 0]}),
                )
                node.inputs[1] = t_w

        node.attrs["layout"] = "NHWC"

        # -- output side: NHWC -> NCHW back-transpose ---------------------
        out_name = node.outputs[0]
        out_spec = g.tensors[out_name]
        if len(out_spec.shape) == 4:
            nhwc_out = g.fresh("o_nhwc")
            g.tensors[nhwc_out] = type(out_spec)(nhwc_out, _perm_shape(out_spec.shape, _NCHW2NHWC), out_spec.dtype)
            back = Node("transpose", f"to_nchw_{nhwc_out}", [nhwc_out], [out_name], {"perm": list(_NHWC2NCHW)})
            node.outputs = [nhwc_out]
            g.nodes.insert(g.nodes.index(node) + 1, back)

    g = _cancel_transposes(g)
    g.prune_tensors()
    return g


def _cancel_transposes(g: Graph) -> Graph:
    """Remove transpose pairs that compose to the identity permutation."""
    changed = True
    while changed:
        changed = False
        for node in list(g.nodes):
            if node.op != "transpose":
                continue
            producer = g.producer(node.inputs[0])
            if producer is None or producer.op != "transpose":
                continue
            if len(g.consumers(producer.outputs[0])) != 1 or producer.outputs[0] in g.outputs:
                continue
            p1 = producer.attrs["perm"]
            p2 = node.attrs["perm"]
            composed = [p1[i] for i in p2]
            if composed == list(range(len(composed))):
                g.rewire(node.outputs[0], producer.inputs[0])
                g.remove_node(node)
                g.remove_node(producer)
                changed = True
    return g
