"""WPK core: the paper's contribution as a composable JAX library.

Typical usage (the paper's Figure 1a pipeline):

    from repro.core import Graph, optimize_graph, select, Engine

    g = build_graph(...)                      # model import
    g_opt = optimize_graph(g)                 # §2.1 graph optimization
    plan = select(g_opt, tuner=Tuner(...))    # §2.2-2.5 search + selection
    engine = Engine(g_opt, plan, default_registry())
    outputs = engine(*inputs)                 # runtime engine
"""

from repro.core.graph import Graph, Node, TensorSpec
from repro.core.passes import optimize_graph
from repro.core.schedules import OpDesc, TEMPLATES, templates_for
from repro.core.costmodel import (
    ModelFitness,
    WallClockFitness,
    pallas_time,
    xla_time,
    roofline_bound,
)
from repro.core.search import (
    GeneticSearch,
    RLSearch,
    SearchCache,
    SearchTask,
    Tuner,
    genetic_search,
    random_search,
    rl_search,
)
from repro.core.selection import select, op_desc_of
from repro.core.plan import InferencePlan, OpChoice
from repro.core.engine import Engine, default_registry

__all__ = [
    "Graph", "Node", "TensorSpec", "optimize_graph",
    "OpDesc", "TEMPLATES", "templates_for",
    "ModelFitness", "WallClockFitness", "pallas_time", "xla_time", "roofline_bound",
    "GeneticSearch", "RLSearch", "SearchCache", "SearchTask", "Tuner",
    "genetic_search", "random_search", "rl_search",
    "select", "op_desc_of", "InferencePlan", "OpChoice",
    "Engine", "default_registry",
]
