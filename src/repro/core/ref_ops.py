"""Pure-jnp reference semantics for every graph op.

This is simultaneously:
  * the "vendor library" backend (the cuDNN analogue — XLA's own lowering),
  * the oracle that every tuned Pallas backend is tested against,
  * the evaluator used for constant folding.

Every function takes (list-of-input-arrays, attrs-dict) -> output array, so
the engine can dispatch uniformly.
"""

from __future__ import annotations

from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np


def _act(x, kind: str):
    if kind in (None, "none", "identity", "dropout"):
        return x
    return {
        "relu": jax.nn.relu,
        "gelu": jax.nn.gelu,
        "silu": jax.nn.silu,
        "tanh": jnp.tanh,
        "sigmoid": jax.nn.sigmoid,
        "neg": lambda v: -v,
        "exp": jnp.exp,
    }[kind](x)


def conv2d(inputs: List[jnp.ndarray], attrs: Dict[str, Any]) -> jnp.ndarray:
    """2-D convolution.  attrs: stride, padding ('SAME'|'VALID'), layout
    ('NCHW'|'NHWC').  Weights are (O, I, Kh, Kw) for NCHW and
    (Kh, Kw, I, O) for NHWC."""
    x, w = inputs[0], inputs[1]
    layout = attrs.get("layout", "NCHW")
    stride = attrs.get("stride", 1)
    strides = (stride, stride) if isinstance(stride, int) else tuple(stride)
    padding = attrs.get("padding", "SAME")
    if layout == "NCHW":
        dn = jax.lax.conv_dimension_numbers(x.shape, w.shape, ("NCHW", "OIHW", "NCHW"))
    else:
        dn = jax.lax.conv_dimension_numbers(x.shape, w.shape, ("NHWC", "HWIO", "NHWC"))
    out = jax.lax.conv_general_dilated(x, w, strides, padding, dimension_numbers=dn)
    if len(inputs) > 2:  # fused bias
        b = inputs[2]
        out = out + (b.reshape(1, -1, 1, 1) if layout == "NCHW" else b.reshape(1, 1, 1, -1))
    return _act(out, attrs.get("activation"))


def matmul(inputs: List[jnp.ndarray], attrs: Dict[str, Any]) -> jnp.ndarray:
    x, w = inputs[0], inputs[1]
    out = jnp.matmul(x, w, preferred_element_type=attrs.get("accum_dtype", jnp.float32))
    out = out.astype(x.dtype)
    if len(inputs) > 2:
        out = out + inputs[2]
    return _act(out, attrs.get("activation"))


def attention(inputs: List[jnp.ndarray], attrs: Dict[str, Any]) -> jnp.ndarray:
    q, k, v = inputs[0], inputs[1], inputs[2]
    causal = attrs.get("causal", True)
    scale = attrs.get("scale") or (1.0 / np.sqrt(q.shape[-1]))
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        qlen, klen = q.shape[1], k.shape[1]
        mask = jnp.tril(jnp.ones((qlen, klen), bool), k=klen - qlen)
        logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


def bias_add(inputs, attrs):
    x, b = inputs
    if x.ndim == 4 and attrs.get("layout", "NCHW") == "NCHW":
        return x + b.reshape(1, -1, 1, 1)
    return x + b


def batch_norm(inputs, attrs):
    """Inference batch norm: pre-folded scale/shift per channel."""
    x, scale, shift = inputs
    layout = attrs.get("layout", "NCHW")
    if x.ndim == 4 and layout == "NCHW":
        return x * scale.reshape(1, -1, 1, 1) + shift.reshape(1, -1, 1, 1)
    return x * scale + shift


def layer_norm(inputs, attrs):
    x = inputs[0]
    eps = attrs.get("eps", 1e-5)
    mu = jnp.mean(x, -1, keepdims=True)
    var = jnp.var(x, -1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    if len(inputs) > 1:
        y = y * inputs[1]
    if len(inputs) > 2:
        y = y + inputs[2]
    return y


def softmax(inputs, attrs):
    return jax.nn.softmax(inputs[0], axis=attrs.get("axis", -1))


def _pool(x, attrs, reducer, init):
    layout = attrs.get("layout", "NCHW")
    k = attrs.get("kernel", 2)
    s = attrs.get("stride", k)
    pad = attrs.get("padding", "VALID")
    if layout == "NCHW":
        dims, strides = (1, 1, k, k), (1, 1, s, s)
    else:
        dims, strides = (1, k, k, 1), (1, s, s, 1)
    return jax.lax.reduce_window(x, init, reducer, dims, strides, pad)


def max_pool(inputs, attrs):
    return _pool(inputs[0], attrs, jax.lax.max, -jnp.inf)


def avg_pool(inputs, attrs):
    k = attrs.get("kernel", 2)
    return _pool(inputs[0], attrs, jax.lax.add, 0.0) / (k * k)


def global_avg_pool(inputs, attrs):
    x = inputs[0]
    axes = (2, 3) if attrs.get("layout", "NCHW") == "NCHW" else (1, 2)
    return jnp.mean(x, axis=axes)


def reshape(inputs, attrs):
    return jnp.reshape(inputs[0], attrs["shape"])


def transpose(inputs, attrs):
    return jnp.transpose(inputs[0], attrs["perm"])


def flatten(inputs, attrs):
    x = inputs[0]
    return x.reshape(x.shape[0], -1)


def concat(inputs, attrs):
    return jnp.concatenate(inputs, axis=attrs.get("axis", -1))


def fused_elementwise(inputs, attrs):
    """A chain of elementwise ops produced by the fusion pass.

    attrs['chain'] is a list of {op, const_inputs} stages; stage i consumes the
    running value plus any extra inputs (taken in order from `inputs[1:]`).
    """
    x = inputs[0]
    extra = list(inputs[1:])
    for stage in attrs["chain"]:
        op = stage["op"]
        if op in ("add", "mul", "sub", "div"):
            rhs = extra.pop(0)
            x = {"add": jnp.add, "mul": jnp.multiply, "sub": jnp.subtract, "div": jnp.divide}[op](x, rhs)
        else:
            x = _act(x, op)
    return x


def _unary(kind):
    return lambda inputs, attrs: _act(inputs[0], kind)


def _binary(fn):
    return lambda inputs, attrs: fn(inputs[0], inputs[1])


REF_OPS = {
    "conv2d": conv2d,
    "fused_conv2d": conv2d,
    "matmul": matmul,
    "fused_matmul": matmul,
    "attention": attention,
    "bias_add": bias_add,
    "batch_norm": batch_norm,
    "layer_norm": layer_norm,
    "softmax": softmax,
    "max_pool": max_pool,
    "avg_pool": avg_pool,
    "global_avg_pool": global_avg_pool,
    "reshape": reshape,
    "transpose": transpose,
    "flatten": flatten,
    "concat": concat,
    "fused_elementwise": fused_elementwise,
    "add": _binary(jnp.add),
    "mul": _binary(jnp.multiply),
    "sub": _binary(jnp.subtract),
    "div": _binary(jnp.divide),
    "relu": _unary("relu"),
    "gelu": _unary("gelu"),
    "silu": _unary("silu"),
    "tanh": _unary("tanh"),
    "sigmoid": _unary("sigmoid"),
    "identity": _unary("identity"),
    "dropout": _unary("dropout"),
    "neg": _unary("neg"),
    "exp": _unary("exp"),
}


def run_op(op: str, inputs, attrs):
    return REF_OPS[op](inputs, attrs)
