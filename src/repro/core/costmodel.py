"""Hardware-aware cost model — the search fitness on a CPU-only box.

On real hardware WPK's fitness is measured wall-time (§2.3 Step2: "compile
the generated codes just-in-time ... execute them to get the runtime").
This container has no TPU, so the default fitness is an *analytical* model of
TPU v5e kernel time with the same interface; `WallClockFitness` (execute +
time, via Pallas interpret mode) is provided for laptop-scale ops and is what
a real deployment would plug in.

The model is a three-term roofline over one kernel invocation:

  t = max(t_mxu, t_hbm) + t_launch + grid_steps * t_step

with the texture that makes the search non-trivial:
  * edge-tile waste:   ceil(M/bm)*bm etc. — compute on padded tiles;
  * MXU alignment:     dims below the (sublane, lane) tile are padded up;
  * HBM traffic:       A reloaded ceil(N/bn)x, B reloaded ceil(M/bm)x — big
                       blocks amortise traffic, VMEM caps block size;
  * DMA efficiency:    blocks whose minor-dim rows are < 512 B waste DMA
                       bandwidth (short burst transfers);
  * revisit penalty:   'nm' vs 'mn' order decides which operand is streamed.

XLA ("vendor library", the cuDNN analogue) is modelled with shape-dependent
efficiency: excellent on large aligned GEMMs, poor on small-channel convs
(e.g. the C_in=3 stem of ResNet) — mirroring the paper's observation that
"neither WPK nor TVM is always superior to cuDNN".
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Dict, Optional

import numpy as np

from repro import hw
from repro.core.schedules import Config, OpDesc

LAUNCH_OVERHEAD_S = 1.5e-6
GRID_STEP_OVERHEAD_S = 2e-8  # DMA issue cost; mostly hidden by pipelining
VMEM_RESIDENT_FRACTION = 0.3  # operand may stay VMEM-resident below this


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _pad(x: int, m: int) -> int:
    return _ceil_div(x, m) * m


def _dma_eff(minor_bytes: int) -> float:
    """Short burst transfers under-utilise HBM bandwidth."""
    return min(1.0, minor_bytes / 512.0) * 0.92 + 0.08 * min(1.0, minor_bytes / 128.0)


@dataclasses.dataclass
class CostBreakdown:
    compute_s: float
    memory_s: float
    overhead_s: float

    @property
    def total_s(self) -> float:
        return max(self.compute_s, self.memory_s) + self.overhead_s


def _gemm_compute_s(m, n, k, bm, bn, bk, cfg, op, chip) -> float:
    sub = chip.sublane(op.dtype)
    mt, nt, kt = _ceil_div(m, bm), _ceil_div(n, bn), _ceil_div(k, bk)
    # Edge tiles compute the full padded block.
    eff_m, eff_n, eff_k = mt * _pad(bm, sub), nt * _pad(bn, chip.lane), kt * _pad(bk, chip.lane)
    flops = 2.0 * eff_m * eff_n * eff_k
    # MXU pipelines best with >= 2 k-steps in flight; tiny bk stalls it.
    mxu_eff = 0.95 * min(1.0, bk / 256.0) ** 0.25
    if cfg.get("k_unroll", 1) >= 2:
        mxu_eff = min(0.97, mxu_eff * 1.03)
    return flops / (chip.peak_flops(op.dtype) * mxu_eff)


def matmul_cost(op: OpDesc, cfg: Config, chip: hw.Chip = hw.TPU_V5E) -> CostBreakdown:
    m, n, k = op.gemm_view()
    item = np.dtype(op.dtype).itemsize
    bm, bn, bk = cfg["bm"], cfg["bn"], cfg["bk"]
    mt, nt, kt = _ceil_div(m, bm), _ceil_div(n, bn), _ceil_div(k, bk)

    compute_s = _gemm_compute_s(m, n, k, bm, bn, bk, cfg, op, chip)

    # HBM traffic.  Whole-operand VMEM residency: a tuned schedule keeps an
    # operand resident when it fits in a VMEM slice — the shape-specific
    # advantage one-size-fits-all vendor kernels don't exploit.
    resident_budget = VMEM_RESIDENT_FRACTION * chip.vmem_bytes
    a_bytes, b_bytes = m * k * item, k * n * item
    if b_bytes <= resident_budget:
        b_loads = k * n
    elif cfg.get("order", "mn") == "nm":
        b_loads = nt * kt * bk * bn            # B streamed once
    else:
        b_loads = nt * kt * bk * bn * mt       # B re-fetched per m-block
    if a_bytes <= resident_budget:
        a_loads = m * k
    elif cfg.get("order", "mn") == "mn":
        a_loads = mt * kt * bm * bk            # A streamed once
    else:
        a_loads = mt * kt * bm * bk * nt
    c_stores = mt * nt * bm * bn
    a_eff = _dma_eff(min(bk, k) * item)
    b_eff = _dma_eff(min(bn, n) * item)
    traffic_s = (
        (a_loads * item) / (chip.hbm_bw * a_eff)
        + (b_loads * item) / (chip.hbm_bw * b_eff)
        + (c_stores * item) / (chip.hbm_bw * 0.95)
    )

    overhead = LAUNCH_OVERHEAD_S + mt * nt * kt * GRID_STEP_OVERHEAD_S
    return CostBreakdown(compute_s, traffic_s, overhead)


def conv2d_cost(op: OpDesc, cfg: Config, chip: hw.Chip = hw.TPU_V5E) -> CostBreakdown:
    """Implicit GEMM (in-kernel im2col): input is read ~once (+halo), never
    materialised as the M x K patch matrix."""
    d = op.d
    m, n, k = op.gemm_view()
    item = np.dtype(op.dtype).itemsize
    bm, bn, bk = cfg["bm"], cfg["bn"], cfg["bk"]
    mt, nt, kt = _ceil_div(m, bm), _ceil_div(n, bn), _ceil_div(k, bk)

    compute_s = _gemm_compute_s(m, n, k, bm, bn, bk, cfg, op, chip)

    # Input: one pass + halo rows shared across `row_block` output rows.
    rb = cfg.get("row_block", 1)
    halo = 1.0 + (d["kh"] - 1) / max(1.0, rb * d["stride"])
    in_elems = d["n"] * d["h"] * d["w"] * d["cin"] * halo
    # Weights: resident if small, else re-fetched per m-block.
    w_elems = d["kh"] * d["kw"] * d["cin"] * d["cout"]
    if w_elems * item > VMEM_RESIDENT_FRACTION * chip.vmem_bytes:
        w_elems *= mt
    out_elems = mt * nt * bm * bn
    in_eff = _dma_eff(min(d["w"] * d["cin"], 4096) * item)
    traffic_s = (
        (in_elems * item) / (chip.hbm_bw * in_eff)
        + (w_elems * item) / (chip.hbm_bw * 0.9)
        + (out_elems * item) / (chip.hbm_bw * 0.95)
    )

    overhead = LAUNCH_OVERHEAD_S + mt * nt * kt * GRID_STEP_OVERHEAD_S
    return CostBreakdown(compute_s, traffic_s, overhead)


def attention_cost(op: OpDesc, cfg: Config, chip: hw.Chip = hw.TPU_V5E) -> CostBreakdown:
    d = op.d
    item = np.dtype(op.dtype).itemsize
    bq, bkv = cfg["block_q"], cfg["block_kv"]
    qt, kt = _ceil_div(d["q"], bq), _ceil_div(d["kv"], bkv)
    hd = _pad(d["d"], chip.lane)
    grid = d["b"] * d["h"] * qt * kt
    flops = 4.0 * d["b"] * d["h"] * (qt * bq) * (kt * bkv) * hd
    # softmax/VPU work limits small-head attention
    vpu_s = (2.0 * d["b"] * d["h"] * qt * bq * kt * bkv) / chip.vpu_flops
    compute_s = flops / (chip.peak_flops(op.dtype) * 0.85) + vpu_s
    traffic = item * d["b"] * d["h"] * (
        qt * bq * hd                      # q once
        + 2 * kt * bkv * hd * qt          # k,v per q block
        + qt * bq * hd                    # out
    )
    mem_s = traffic / (chip.hbm_bw * _dma_eff(hd * item))
    launch, steps = LAUNCH_OVERHEAD_S, grid * GRID_STEP_OVERHEAD_S
    ns = int(cfg.get("max_segments") or 0)
    if ns >= 1:
        # Segment-packed chunk attention (the serve graph's prefill_chunk
        # stage): one packed invocation commits up to `ns` requests'
        # prompt segments, replacing `ns` single-segment launches — so the
        # launch overhead amortizes across the packing width — while the
        # kernel's segment grid axis multiplies its (mostly skipped, but
        # still issued) grid steps by `ns`.  The trade-off gives the race
        # a real, deterministic optimum instead of a tie broken by search
        # order: small widths pay a full launch per request stream, large
        # widths drown in grid-step issue cost.
        launch = LAUNCH_OVERHEAD_S / ns
        steps *= ns
    return CostBreakdown(compute_s, mem_s, launch + steps)


_KIND_COST = {"matmul": matmul_cost, "conv2d": conv2d_cost, "attention": attention_cost}


def pallas_time(op: OpDesc, cfg: Config, chip: hw.Chip = hw.TPU_V5E) -> float:
    return _KIND_COST[op.kind](op, cfg, chip).total_s


# --------------------------------------------------------------------------
# Vendor-library (XLA) model — the cuDNN analogue in the backend race.
# --------------------------------------------------------------------------

def xla_time(op: OpDesc, chip: hw.Chip = hw.TPU_V5E) -> float:
    m, n, k = op.gemm_view()
    item = np.dtype(op.dtype).itemsize
    sub = chip.sublane(op.dtype)

    if op.kind == "matmul":
        eff = 0.88
        # Vendor kernels are tuned for large aligned shapes...
        for dim, al in ((m, sub), (n, chip.lane), (k, chip.lane)):
            if dim % al:
                eff *= 0.72   # ...and pad ungracefully otherwise.
            if dim < al:
                eff *= max(0.25, dim / al)
        flops = 2.0 * _pad(m, sub) * _pad(n, chip.lane) * _pad(k, chip.lane)
        compute = flops / (chip.peak_flops(op.dtype) * eff)
        mem = op.io_bytes() / (chip.hbm_bw * 0.85)
        return max(compute, mem) + LAUNCH_OVERHEAD_S

    if op.kind == "conv2d":
        d = op.d
        eff = 0.68
        kdim = d["kh"] * d["kw"] * d["cin"]
        # cuDNN-like behaviour: poor on tiny-channel stems and stride-2
        if d["cin"] < 32:
            eff *= max(0.32, d["cin"] / 40.0)
        if d["stride"] > 1:
            eff *= 0.8
        if d["cout"] % chip.lane:
            eff *= 0.7
        flops = 2.0 * (d["n"] * d["oh"] * d["ow"]) * _pad(d["cout"], chip.lane) * _pad(kdim, chip.lane)
        compute = flops / (chip.peak_flops(op.dtype) * eff)
        mem = op.io_bytes() / (chip.hbm_bw * 0.7)
        return max(compute, mem) + LAUNCH_OVERHEAD_S

    if op.kind == "attention":
        # Unfused attention: materialises b·h·q·kv logits through HBM.
        d = op.d
        logits_bytes = 4.0 * d["b"] * d["h"] * d["q"] * d["kv"]
        mem = (op.io_bytes() + 2 * logits_bytes) / (chip.hbm_bw * 0.8)
        compute = op.flops() / (chip.peak_flops(op.dtype) * 0.75)
        return max(compute, mem) + 3 * LAUNCH_OVERHEAD_S

    raise ValueError(op.kind)


# --------------------------------------------------------------------------
# Layout (tensor-parallel) pricing — the serve plan's second race axis.
#
# A matmul stage may run replicated (every device computes the full GEMM)
# or model-parallel over `tp` devices.  Which GEMM dim shards, and which
# collective the layout implies, is a property of the op's ROLE in the
# block, not of its shape:
#
#   column-parallel ('n' shards): the output is already partitioned along
#     the very dim the NEXT sharded op consumes (qkv -> per-head attention,
#     mlp_up -> per-column activation) — no collective on the hot path;
#   row-parallel ('k' shards): each device holds a partial sum of the full
#     output — one all-reduce per invocation (mlp_down, out_proj close the
#     Megatron pair their column-parallel partner opened);
#   lm_head shards the vocab dim and the sampler needs the full
#     distribution — one all-gather of the logits.
#
# Attention itself shards over heads ('h'): per-head programs are
# independent, the collectives ride the projections around it.
# --------------------------------------------------------------------------

# role -> (sharded gemm dim, implied collective on the output)
MATMUL_LAYOUT_ROLES: Dict[str, tuple] = {
    "qkv_proj": ("n", None),
    "mlp_up": ("n", None),
    "mlp_down": ("k", "all_reduce"),
    "lm_head": ("n", "all_gather"),
    # ssm family (repro.models.mamba): in_proj/out_proj are the Megatron
    # pair over the conv/state inner dim
    "in_proj": ("n", None),
    "out_proj": ("k", "all_reduce"),
    "attention": ("h", None),
}


def collective_time(nbytes: float, tp: int, chip: hw.Chip = hw.TPU_V5E,
                    kind: str = "all_reduce") -> float:
    """Ring-collective time over the model axis of a `tp`-device mesh.

    Ring all-reduce moves 2*(tp-1)/tp of the buffer per device (reduce-
    scatter + all-gather phases); all-gather moves (tp-1)/tp.  Bandwidth is
    the per-axis ICI budget; each phase hop pays a launch."""
    if tp <= 1:
        return 0.0
    bw = chip.ici_link_bw * chip.ici_links_per_axis
    phases = {"all_reduce": 2.0, "all_gather": 1.0, "reduce_scatter": 1.0}
    moved = phases[kind] * (tp - 1) / tp * nbytes
    return moved / bw + (tp - 1) * LAUNCH_OVERHEAD_S


def sharded_op_desc(op: OpDesc, role: str, tp: int) -> Optional[OpDesc]:
    """The per-device OpDesc of `op` under role's model-parallel layout, or
    None when the sharded dim doesn't divide `tp` (the layout is then not
    a legal candidate — mirroring `launch.steps.rules_for_shape`)."""
    if tp <= 1 or role not in MATMUL_LAYOUT_ROLES:
        return None
    dim, _ = MATMUL_LAYOUT_ROLES[role]
    d = op.d
    if op.kind == "matmul" and dim in ("n", "k"):
        if d[dim] % tp:
            return None
        m, n, k = d["m"], d["n"], d["k"]
        if dim == "n":
            n //= tp
        else:
            k //= tp
        return OpDesc.matmul(m, n, k, dtype=op.dtype,
                             activation=op.activation, label=op.label)
    if op.kind == "attention" and dim == "h":
        if d["h"] % tp:
            return None
        return OpDesc.attention(d["b"], d["q"], d["kv"], d["h"] // tp,
                                d["d"], dtype=op.dtype, label=op.label)
    return None


def layout_collective_time(op: OpDesc, role: str, tp: int,
                           chip: hw.Chip = hw.TPU_V5E) -> float:
    """Time of the collective the model-parallel layout implies for this
    op (0.0 for column-parallel roles and attention)."""
    _, coll = MATMUL_LAYOUT_ROLES[role]
    if coll is None or tp <= 1:
        return 0.0
    m, n, _ = op.gemm_view()
    out_bytes = m * n * np.dtype(op.dtype).itemsize
    return collective_time(out_bytes, tp, chip, coll)


def xla_elementwise_time(nbytes: int, chip: hw.Chip = hw.TPU_V5E) -> float:
    """Un-fused elementwise op: read + write through HBM + one launch.
    This is the traffic that operator fusion (paper §2.1) eliminates."""
    return (2.0 * nbytes) / (chip.hbm_bw * 0.9) + LAUNCH_OVERHEAD_S


def roofline_bound(op: OpDesc, chip: hw.Chip = hw.TPU_V5E) -> float:
    """The un-beatable lower bound for this op on this chip."""
    return max(op.flops() / chip.peak_flops(op.dtype), op.io_bytes() / chip.hbm_bw)


# --------------------------------------------------------------------------
# Fitness interfaces used by the searches.
# --------------------------------------------------------------------------

class Fitness:
    """Maps a candidate config to a runtime (lower is better).  The genetic
    search turns this into the paper's fitness f(a_i) = 1/runtime.

    `kind` tags what the returned number *is* (analytical model vs measured
    wall time) — the search cache keys on it, because a runtime_s measured
    under one fitness is meaningless under another."""

    kind: str = "model"

    def __call__(self, op: OpDesc, cfg: Config) -> float:
        raise NotImplementedError


class ModelFitness(Fitness):
    kind = "model"

    def __init__(self, chip: hw.Chip = hw.TPU_V5E):
        self.chip = chip
        self.evals = 0

    def __call__(self, op: OpDesc, cfg: Config) -> float:
        self.evals += 1
        return pallas_time(op, cfg, self.chip)


class WallClockFitness(Fitness):
    """Measured fitness: compile+run the actual kernel and time it.

    On-device this times the TPU kernel; in this container it times the
    Pallas interpret-mode execution on CPU (laptop-scale ops only).  Matches
    the paper's Step2 semantics exactly (JIT compile, execute, use runtime).
    """

    kind = "wallclock"

    def __init__(self, runner, repeats: int = 3):
        self.runner = runner  # (op, cfg) -> callable()
        self.repeats = repeats
        self.evals = 0

    def __call__(self, op: OpDesc, cfg: Config) -> float:
        self.evals += 1
        fn = self.runner(op, cfg)
        fn()  # warm-up / compile
        best = math.inf
        for _ in range(self.repeats):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best
