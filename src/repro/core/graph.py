"""Computational-graph IR for the WPK inference compiler.

The paper abstracts a DNN as "a computational graph with operators as nodes
and tensors representing data movement as edges" (§1).  This module is that
IR: a small, explicit, serialisable graph that the optimization passes
(`repro.core.passes`), the automated searches (`repro.core.search`), the
system-level backend selection (`repro.core.selection`) and the runtime
engine (`repro.core.engine`) all operate on.

Design notes
------------
* Tensors are identified by string names; `Node`s consume/produce names.
* Constants (weights after training — invariant during inference, which is
  exactly the property the paper exploits) live in `Graph.constants`.
* The op vocabulary is deliberately small and inference-oriented; every op
  has a pure-jnp reference implementation in `repro.core.ref_ops` used for
  constant folding and as the correctness oracle for every optimized plan.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

# Op vocabulary.  Fused ops are produced by the fusion pass.
ELEMENTWISE_UNARY = (
    "relu",
    "gelu",
    "silu",
    "tanh",
    "sigmoid",
    "identity",
    "dropout",  # inference: identity (paper lists dropout removal explicitly)
    "neg",
    "exp",
)
ELEMENTWISE_BINARY = ("add", "mul", "sub", "div")
COMPUTE_OPS = ("conv2d", "matmul", "attention")
FUSED_OPS = (
    "fused_conv2d",      # conv2d (+bias) (+activation)
    "fused_matmul",      # matmul (+bias) (+activation)
    "fused_elementwise", # chain of elementwise ops
)
OTHER_OPS = (
    "bias_add",
    "batch_norm",   # inference form: y = x * scale + shift (folded stats)
    "layer_norm",
    "softmax",
    "max_pool",
    "avg_pool",
    "global_avg_pool",
    "reshape",
    "transpose",
    "flatten",
    "concat",
    "constant",
)
ALL_OPS = ELEMENTWISE_UNARY + ELEMENTWISE_BINARY + COMPUTE_OPS + FUSED_OPS + OTHER_OPS


@dataclasses.dataclass
class TensorSpec:
    """Shape/dtype metadata for one edge of the graph."""

    name: str
    shape: Tuple[int, ...]
    dtype: str = "float32"

    def nbytes(self) -> int:
        return int(np.prod(self.shape)) * np.dtype(self.dtype).itemsize

    def to_json(self) -> Dict[str, Any]:
        return {"name": self.name, "shape": list(self.shape), "dtype": self.dtype}

    @staticmethod
    def from_json(d: Dict[str, Any]) -> "TensorSpec":
        return TensorSpec(d["name"], tuple(d["shape"]), d["dtype"])


@dataclasses.dataclass
class Node:
    """One operator instance."""

    op: str
    name: str
    inputs: List[str]
    outputs: List[str]
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def signature(self, graph: "Graph") -> str:
        """Hardware-relevant identity of this node, used as the search-cache
        key (§3.3: "two convolution operators are considered computationally
        identical if they have the same input/output shape, filter matrix
        size, stride and padding")."""
        in_specs = [
            (tuple(graph.tensors[t].shape), graph.tensors[t].dtype) for t in self.inputs
        ]
        attrs = {k: v for k, v in sorted(self.attrs.items()) if k != "label"}
        return json.dumps([self.op, in_specs, attrs], sort_keys=True, default=str)

    def to_json(self) -> Dict[str, Any]:
        return {
            "op": self.op,
            "name": self.name,
            "inputs": self.inputs,
            "outputs": self.outputs,
            "attrs": _jsonable(self.attrs),
        }


def _jsonable(x):
    if isinstance(x, dict):
        return {k: _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    if isinstance(x, np.ndarray):
        return {"__ndarray__": x.tolist(), "dtype": str(x.dtype)}
    if isinstance(x, (np.integer,)):
        return int(x)
    if isinstance(x, (np.floating,)):
        return float(x)
    return x


class Graph:
    """A DAG of `Node`s over named tensors."""

    def __init__(self, name: str = "graph"):
        self.name = name
        self.nodes: List[Node] = []
        self.tensors: Dict[str, TensorSpec] = {}
        self.inputs: List[str] = []
        self.outputs: List[str] = []
        self.constants: Dict[str, np.ndarray] = {}
        self._ctr = 0

    # ------------------------------------------------------------------ build
    def fresh(self, prefix: str = "t") -> str:
        self._ctr += 1
        return f"{prefix}_{self._ctr}"

    def add_input(self, name: str, shape: Sequence[int], dtype: str = "float32") -> str:
        self.tensors[name] = TensorSpec(name, tuple(shape), dtype)
        self.inputs.append(name)
        return name

    def add_constant(self, name: str, value: np.ndarray) -> str:
        value = np.asarray(value)
        self.tensors[name] = TensorSpec(name, tuple(value.shape), str(value.dtype))
        self.constants[name] = value
        return name

    def add_node(
        self,
        op: str,
        inputs: Sequence[str],
        out_shape: Sequence[int],
        attrs: Optional[Dict[str, Any]] = None,
        out_dtype: str = "float32",
        name: Optional[str] = None,
    ) -> str:
        assert op in ALL_OPS, f"unknown op {op}"
        for t in inputs:
            assert t in self.tensors, f"unknown tensor {t} feeding {op}"
        name = name or f"{op}_{self.fresh('n')}"
        out = self.fresh(op)
        self.tensors[out] = TensorSpec(out, tuple(out_shape), out_dtype)
        self.nodes.append(Node(op, name, list(inputs), [out], dict(attrs or {})))
        return out

    def set_outputs(self, names: Sequence[str]) -> None:
        self.outputs = list(names)

    # -------------------------------------------------------------- structure
    def producer(self, tensor: str) -> Optional[Node]:
        for n in self.nodes:
            if tensor in n.outputs:
                return n
        return None

    def consumers(self, tensor: str) -> List[Node]:
        return [n for n in self.nodes if tensor in n.inputs]

    def toposort(self) -> List[Node]:
        """Kahn toposort; raises on cycles."""
        ready = set(self.inputs) | set(self.constants)
        remaining = list(self.nodes)
        order: List[Node] = []
        while remaining:
            progress = False
            nxt = []
            for n in remaining:
                if all(i in ready for i in n.inputs):
                    order.append(n)
                    ready.update(n.outputs)
                    progress = True
                else:
                    nxt.append(n)
            if not progress:
                raise ValueError(
                    f"graph {self.name} has a cycle or dangling input: "
                    f"{[n.name for n in nxt]}"
                )
            remaining = nxt
        return order

    def validate(self) -> None:
        self.toposort()
        for o in self.outputs:
            assert o in self.tensors, f"output {o} not defined"

    def copy(self) -> "Graph":
        g = Graph(self.name)
        g.nodes = [
            Node(n.op, n.name, list(n.inputs), list(n.outputs), dict(n.attrs))
            for n in self.nodes
        ]
        g.tensors = {k: TensorSpec(v.name, v.shape, v.dtype) for k, v in self.tensors.items()}
        g.inputs = list(self.inputs)
        g.outputs = list(self.outputs)
        g.constants = dict(self.constants)
        g._ctr = self._ctr
        return g

    def remove_node(self, node: Node) -> None:
        self.nodes.remove(node)

    def rewire(self, old_tensor: str, new_tensor: str) -> None:
        """Point every consumer of `old_tensor` at `new_tensor`."""
        for n in self.nodes:
            n.inputs = [new_tensor if i == old_tensor else i for i in n.inputs]
        self.outputs = [new_tensor if o == old_tensor else o for o in self.outputs]

    def prune_tensors(self) -> None:
        """Drop tensor specs/constants no longer referenced."""
        live = set(self.inputs) | set(self.outputs)
        for n in self.nodes:
            live.update(n.inputs)
            live.update(n.outputs)
        self.tensors = {k: v for k, v in self.tensors.items() if k in live}
        self.constants = {k: v for k, v in self.constants.items() if k in live}

    # ------------------------------------------------------------------ stats
    def op_histogram(self) -> Dict[str, int]:
        h: Dict[str, int] = {}
        for n in self.nodes:
            h[n.op] = h.get(n.op, 0) + 1
        return h

    def to_json(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "nodes": [n.to_json() for n in self.nodes],
            "tensors": {k: v.to_json() for k, v in self.tensors.items()},
            "inputs": self.inputs,
            "outputs": self.outputs,
            "constants": {k: v.shape for k, v in self.constants.items()},
        }

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"Graph({self.name}: {len(self.nodes)} nodes, "
            f"{len(self.inputs)} in, {len(self.outputs)} out, "
            f"hist={self.op_histogram()})"
        )
