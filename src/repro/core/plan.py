"""InferencePlan: the output of WPK's optimization — per-operator backend
choice + tuned configuration + modeled runtime (paper: "to create an
optimized inference plan, WPK systematically explores high-speed operator
implementations from third-party libraries besides our automatically
generated codes and singles out the best implementation per operator")."""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional


@dataclasses.dataclass
class OpChoice:
    backend: str                       # 'xla' | 'pallas_matmul' | ...
    config: Dict[str, Any]             # tuned schedule config ({} for xla)
    modeled_time_s: float
    candidates: Dict[str, float] = dataclasses.field(default_factory=dict)
    # the layout dimension of the race (tensor-parallel serving): which
    # sharding this op's weights/activations run under, and the modeled
    # time of every layout raced.  Plans tuned before the layout axis
    # existed load as 'replicated' with no candidates — the single-device
    # semantics they were tuned under.
    layout: str = "replicated"         # 'replicated' | 'model_parallel'
    layout_candidates: Dict[str, float] = dataclasses.field(
        default_factory=dict)

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class InferencePlan:
    graph_name: str
    chip: str
    choices: Dict[str, OpChoice] = dataclasses.field(default_factory=dict)

    def total_modeled_time_s(self) -> float:
        return sum(c.modeled_time_s for c in self.choices.values())

    def backend_histogram(self) -> Dict[str, int]:
        h: Dict[str, int] = {}
        for c in self.choices.values():
            h[c.backend] = h.get(c.backend, 0) + 1
        return h

    def to_json(self) -> Dict[str, Any]:
        return {
            "graph": self.graph_name,
            "chip": self.chip,
            "total_modeled_time_s": self.total_modeled_time_s(),
            "choices": {k: v.to_json() for k, v in self.choices.items()},
        }

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)

    @staticmethod
    def load(path: str) -> "InferencePlan":
        with open(path) as f:
            d = json.load(f)
        plan = InferencePlan(d["graph"], d["chip"])
        for k, v in d["choices"].items():
            plan.choices[k] = OpChoice(v["backend"], v["config"],
                                       v["modeled_time_s"], v.get("candidates", {}),
                                       v.get("layout", "replicated"),
                                       v.get("layout_candidates", {}))
        return plan

    def choice(self, node_name: str) -> Optional[OpChoice]:
        return self.choices.get(node_name)
