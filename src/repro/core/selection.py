"""System-level exploration (paper §2.5).

For every compute operator in an optimized graph, race the available
implementations — the "vendor library" (XLA lowering, the cuDNN analogue)
and every applicable tuned Pallas template (the WPK-generated-code analogue)
— and single out the fastest for the inference plan.  The paper stresses
this is what distinguishes WPK from XLA/TVM/nGraph: it is not married to its
own codegen.

`select` also honours `third_party=False` to reproduce the paper's §3.4
ablation ("excluding these TensorRT operators incorporated only results in
very marginal performance loss of 2%") — here 'third-party' means the
non-WPK backend (XLA).
"""

from __future__ import annotations

from typing import Dict, Optional

from repro import hw
from repro.core import costmodel
from repro.core.graph import Graph, Node
from repro.core.plan import InferencePlan, OpChoice
from repro.core.schedules import OpDesc, templates_for
from repro.core.search.tuner import Tuner

TUNABLE_OPS = ("conv2d", "fused_conv2d", "matmul", "fused_matmul", "attention")


def op_desc_of(graph: Graph, node: Node, dtype: str = "bfloat16") -> Optional[OpDesc]:
    """Lift a graph node into the hardware-relevant OpDesc."""
    if node.op in ("conv2d", "fused_conv2d"):
        x = graph.tensors[node.inputs[0]].shape
        w = graph.tensors[node.inputs[1]].shape
        layout = node.attrs.get("layout", "NCHW")
        if layout == "NCHW":
            n, cin, h, wd = x
            cout, _, kh, kw = w
        else:
            n, h, wd, cin = x
            kh, kw, _, cout = w
        return OpDesc.conv2d(n, h, wd, cin, cout, kh, kw,
                             stride=node.attrs.get("stride", 1),
                             padding=node.attrs.get("padding", "SAME"),
                             dtype=dtype, activation=node.attrs.get("activation"),
                             label=node.name)
    if node.op in ("matmul", "fused_matmul"):
        x = graph.tensors[node.inputs[0]].shape
        w = graph.tensors[node.inputs[1]].shape
        m = 1
        for s in x[:-1]:
            m *= s
        return OpDesc.matmul(m, w[-1], x[-1], dtype=dtype,
                             activation=node.attrs.get("activation"), label=node.name)
    if node.op == "attention":
        q = graph.tensors[node.inputs[0]].shape
        k = graph.tensors[node.inputs[1]].shape
        b, qlen, heads, hd = q
        return OpDesc.attention(b, qlen, k[1], heads, hd, dtype=dtype, label=node.name)
    return None


def _race_backends(op: OpDesc, tuner: Tuner, chip: hw.Chip,
                   third_party: bool):
    """Race the vendor (XLA) lane against every applicable tuned Pallas
    template for ONE op shape; -> (backend, cfg, time, candidates)."""
    candidates: Dict[str, float] = {}
    best_backend, best_cfg, best_t = None, {}, float("inf")
    if third_party:  # the vendor/third-party lane of the race
        t_xla = costmodel.xla_time(op, chip)
        candidates["xla"] = t_xla
        best_backend, best_cfg, best_t = "xla", {}, t_xla
    for template in templates_for(op):
        res = tuner.tune(op, template)
        candidates[template.name] = res.runtime_s
        if res.runtime_s < best_t:
            best_backend, best_cfg, best_t = (template.name, res.config,
                                              res.runtime_s)
    return best_backend, best_cfg, best_t, candidates


def select(
    graph: Graph,
    tuner: Optional[Tuner] = None,
    chip: hw.Chip = hw.TPU_V5E,
    dtype: str = "bfloat16",
    third_party: bool = True,
    model_parallel: int = 1,
) -> InferencePlan:
    """Build the inference plan for `graph`.

    `model_parallel` > 1 opens the LAYOUT axis of the race: nodes whose
    stage-qualified role appears in `costmodel.MATMUL_LAYOUT_ROLES` are
    additionally raced model-parallel over that many devices — the
    backend race re-run at the per-device shard shape, plus the price of
    the collective the layout implies (all-reduce for row-parallel roles,
    logits all-gather for lm_head, none for column-parallel) — and the
    winning layout lands on the choice's `layout` field next to the
    backend.  Shard dims that don't divide `model_parallel` keep the
    replicated layout (no illegal candidate is ever raced)."""
    tuner = tuner or Tuner(chip=chip)
    plan = InferencePlan(graph.name, chip.name)

    for node in graph.toposort():
        if node.op not in TUNABLE_OPS:
            continue
        op = op_desc_of(graph, node, dtype)
        if op is None:
            continue

        best_backend, best_cfg, best_t, candidates = _race_backends(
            op, tuner, chip, third_party)
        assert best_backend is not None, f"no backend for {node.name}"
        choice = OpChoice(best_backend, best_cfg, best_t, candidates)

        role = node.name.rsplit(".", 1)[-1]
        sharded = costmodel.sharded_op_desc(op, role, model_parallel)
        if sharded is not None:
            mp_backend, mp_cfg, mp_t, mp_cands = _race_backends(
                sharded, tuner, chip, third_party)
            mp_t += costmodel.layout_collective_time(op, role,
                                                     model_parallel, chip)
            choice.layout_candidates = {"replicated": best_t,
                                        "model_parallel": mp_t}
            if mp_backend is not None and mp_t < best_t:
                choice = OpChoice(mp_backend, mp_cfg, mp_t, mp_cands,
                                  layout="model_parallel",
                                  layout_candidates=choice.layout_candidates)
        plan.choices[node.name] = choice

    return plan
