"""Schedule templates (paper §2.2) — the TPU analogue of Halide schedules.

The paper's semi-automatic approach: "pre-defines one or more schedule
templates for a given algorithm, then exposes a set of tunable
hyper-parameters ... and finally exploits automated search in the tunable
parameter space".  A template here is a parameterized Pallas kernel: the
tunables are BlockSpec tile sizes, grid iteration order and unroll factors —
the TPU equivalents of the paper's CUDA thread-block dims (T_x,T_y,T_z) and
per-thread tiles (Tile_x,Tile_y,Tile_z,Tile_rz).

The CUDA validity constraint ("total threads per block <= 1024") becomes the
VMEM-residency constraint: all live blocks, double-buffered, must fit in
VMEM.  `Template.validate` enforces it; the searches only ever propose valid
configurations (§2.3 Step1 "any randomly generated configuration will be
verified first").
"""

from __future__ import annotations

import dataclasses
import itertools
import json
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import hw


@dataclasses.dataclass(frozen=True)
class OpDesc:
    """Hardware-relevant description of one operator instance.

    kind: 'matmul' | 'conv2d' | 'attention' | 'attention_decode'
    dims: kind-specific dims dict (see the templates below)
    dtype: compute dtype
    """

    kind: str
    dims: Tuple[Tuple[str, int], ...]
    dtype: str = "bfloat16"
    activation: Optional[str] = None
    label: str = ""

    @staticmethod
    def make(kind: str, dims: Dict[str, int], dtype: str = "bfloat16",
             activation: Optional[str] = None, label: str = "") -> "OpDesc":
        return OpDesc(kind, tuple(sorted(dims.items())), dtype, activation, label)

    @property
    def d(self) -> Dict[str, int]:
        return dict(self.dims)

    def signature(self) -> str:
        return json.dumps(
            [self.kind, list(self.dims), self.dtype, self.activation], sort_keys=True
        )

    @staticmethod
    def matmul(m: int, n: int, k: int, dtype="bfloat16", activation=None, label="") -> "OpDesc":
        return OpDesc.make("matmul", {"m": m, "n": n, "k": k}, dtype, activation, label)

    @staticmethod
    def conv2d(n, h, w, cin, cout, kh, kw, stride=1, padding="SAME",
               dtype="bfloat16", activation=None, label="") -> "OpDesc":
        pad = 1 if padding == "SAME" else 0
        oh = h // stride if pad else (h - kh) // stride + 1
        ow = w // stride if pad else (w - kw) // stride + 1
        return OpDesc.make(
            "conv2d",
            {"n": n, "h": h, "w": w, "cin": cin, "cout": cout, "kh": kh,
             "kw": kw, "stride": stride, "pad": pad, "oh": oh, "ow": ow},
            dtype, activation, label)

    @staticmethod
    def attention(b, q, kv, heads, head_dim, dtype="bfloat16", label="") -> "OpDesc":
        return OpDesc.make(
            "attention", {"b": b, "q": q, "kv": kv, "h": heads, "d": head_dim},
            dtype, None, label)

    def gemm_view(self) -> Tuple[int, int, int]:
        """(M, N, K) of the underlying GEMM (implicit GEMM for conv)."""
        d = self.d
        if self.kind == "matmul":
            return d["m"], d["n"], d["k"]
        if self.kind == "conv2d":
            return d["n"] * d["oh"] * d["ow"], d["cout"], d["kh"] * d["kw"] * d["cin"]
        if self.kind == "attention":
            # dominant GEMM: (b*h) batched q x kv
            return d["b"] * d["h"] * d["q"], d["kv"], d["d"]
        raise ValueError(self.kind)

    def flops(self) -> float:
        d = self.d
        if self.kind == "attention":
            return 4.0 * d["b"] * d["h"] * d["q"] * d["kv"] * d["d"]
        m, n, k = self.gemm_view()
        return 2.0 * m * n * k

    def io_bytes(self) -> int:
        """Minimum HBM traffic: read inputs once + write output once."""
        d = self.d
        item = np.dtype(self.dtype).itemsize
        if self.kind == "matmul":
            return item * (d["m"] * d["k"] + d["k"] * d["n"] + d["m"] * d["n"])
        if self.kind == "conv2d":
            return item * (
                d["n"] * d["h"] * d["w"] * d["cin"]
                + d["kh"] * d["kw"] * d["cin"] * d["cout"]
                + d["n"] * d["oh"] * d["ow"] * d["cout"]
            )
        if self.kind == "attention":
            return item * (
                3 * d["b"] * d["q"] * d["h"] * d["d"]  # q + out (+v-ish)
                + 2 * d["b"] * d["kv"] * d["h"] * d["d"]
            )
        raise ValueError(self.kind)

    def arithmetic_intensity(self) -> float:
        return self.flops() / max(1, self.io_bytes())


Config = Dict[str, Any]


class Template:
    """Base schedule template: a named, finite tunable-parameter space."""

    name: str = "base"
    kinds: Tuple[str, ...] = ()

    def space(self, op: OpDesc) -> Dict[str, List[Any]]:
        raise NotImplementedError

    def validate(self, op: OpDesc, cfg: Config, chip: hw.Chip = hw.TPU_V5E) -> bool:
        raise NotImplementedError

    # ---- encoding helpers shared by GA / RL / random searches ----------
    def axes(self, op: OpDesc) -> List[Tuple[str, List[Any]]]:
        return sorted(self.space(op).items())

    def encode(self, op: OpDesc, cfg: Config) -> List[int]:
        return [choices.index(cfg[k]) for k, choices in self.axes(op)]

    def decode(self, op: OpDesc, vec: Sequence[int]) -> Config:
        return {k: choices[v % len(choices)] for (k, choices), v in zip(self.axes(op), vec)}

    def random_config(self, op: OpDesc, rng: np.random.Generator,
                      chip: hw.Chip = hw.TPU_V5E, max_tries: int = 200) -> Config:
        axes = self.axes(op)
        for _ in range(max_tries):
            cfg = {k: choices[rng.integers(len(choices))] for k, choices in axes}
            if self.validate(op, cfg, chip):
                return cfg
        # Fall back to the smallest (always-valid) config.
        cfg = {k: choices[0] for k, choices in axes}
        assert self.validate(op, cfg, chip), "template has no valid config"
        return cfg

    def enumerate_configs(self, op: OpDesc, chip: hw.Chip = hw.TPU_V5E):
        axes = self.axes(op)
        names = [k for k, _ in axes]
        for combo in itertools.product(*[c for _, c in axes]):
            cfg = dict(zip(names, combo))
            if self.validate(op, cfg, chip):
                yield cfg

    def space_size(self, op: OpDesc) -> int:
        return int(np.prod([len(c) for _, c in self.axes(op)]))


def _vmem_matmul_bytes(bm: int, bn: int, bk: int, dtype) -> int:
    item = np.dtype(dtype).itemsize
    # A-block + B-block double-buffered, f32 accumulator single-buffered.
    return 2 * (bm * bk + bk * bn) * item + bm * bn * 4


class MatmulTemplate(Template):
    """Tiled MXU matmul: grid (M/bm, N/bn, K/bk), f32 VMEM accumulator.

    Tunables:
      bm, bn, bk     block sizes (MXU-aligned choices only)
      order          'mn' or 'nm' grid-major order (affects reuse direction)
      k_unroll       inner-K unroll factor hint
    """

    name = "pallas_matmul"
    kinds = ("matmul",)

    BM = [8, 16, 32, 64, 128, 256, 512, 1024]
    BN = [128, 256, 512, 1024]
    BK = [128, 256, 512, 1024, 2048]

    def space(self, op: OpDesc) -> Dict[str, List[Any]]:
        m, n, k = op.gemm_view()
        return {
            "bm": [b for b in self.BM if b <= max(8, 2 * m)],
            "bn": [b for b in self.BN if b <= max(128, 2 * n)],
            "bk": [b for b in self.BK if b <= max(128, 2 * k)],
            "order": ["mn", "nm"],
            "k_unroll": [1, 2, 4],
        }

    def validate(self, op: OpDesc, cfg: Config, chip: hw.Chip = hw.TPU_V5E) -> bool:
        sub = chip.sublane(op.dtype)
        if cfg["bm"] % sub and cfg["bm"] > sub:
            return False  # large unaligned bm wastes sublanes; tiny m pads
        if cfg["bn"] % chip.lane or cfg["bk"] % chip.lane:
            return False
        need = _vmem_matmul_bytes(cfg["bm"], cfg["bn"], cfg["bk"], op.dtype)
        return need <= 0.9 * chip.vmem_bytes


class Conv2dTemplate(MatmulTemplate):
    """Convolution as implicit GEMM (in-kernel im2col), the TPU-native
    rethink of the paper's direct-CUDA conv template: M = N*OH*OW,
    K = KH*KW*CIN, N = COUT.  Extra tunable `row_block` controls how many
    output rows share one halo load."""

    name = "pallas_conv2d"
    kinds = ("conv2d",)

    def space(self, op: OpDesc) -> Dict[str, List[Any]]:
        s = super().space(op)
        s["row_block"] = [1, 2, 4, 8]
        return s

    def validate(self, op: OpDesc, cfg: Config, chip: hw.Chip = hw.TPU_V5E) -> bool:
        if not super().validate(op, cfg, chip):
            return False
        d = op.d
        # halo rows must fit alongside the GEMM blocks
        item = np.dtype(op.dtype).itemsize
        halo = (cfg["row_block"] * d["stride"] + d["kh"]) * d["w"] * d["cin"] * item
        return halo + _vmem_matmul_bytes(cfg["bm"], cfg["bn"], cfg["bk"], op.dtype) \
            <= 0.9 * chip.vmem_bytes


class AttentionTemplate(Template):
    """Flash-attention schedule: online-softmax over KV blocks.

    Tunables: block_q, block_kv sizes; whether the (b,h) grid axis is
    'arbitrary' (parallel) or the kv axis is innermost.  Serve-graph
    `prefill_chunk` ops (the segment-packed chunk lane of the unified
    serving step) additionally race `max_segments` — the packing width of
    the segmented kernel's block_q x max-segments grid, which the
    scheduler consumes as its per-step packing cap
    (`PlanRouter.chunk_segments`).
    """

    name = "pallas_attention"
    kinds = ("attention",)

    BQ = [128, 256, 512, 1024]
    BKV = [128, 256, 512, 1024, 2048]
    MAX_SEGMENTS = [1, 2, 4, 8]

    def space(self, op: OpDesc) -> Dict[str, List[Any]]:
        d = op.d
        s = {
            "block_q": [b for b in self.BQ if b <= max(128, d["q"])],
            "block_kv": [b for b in self.BKV if b <= max(128, d["kv"])],
        }
        if op.label.startswith("prefill_chunk"):
            # packing can't exceed one request per query row
            s["max_segments"] = [m for m in self.MAX_SEGMENTS
                                 if m <= max(1, d["q"])]
        return s

    def validate(self, op: OpDesc, cfg: Config, chip: hw.Chip = hw.TPU_V5E) -> bool:
        d = op.d
        item = np.dtype(op.dtype).itemsize
        hd = max(d["d"], chip.lane)
        need = (
            2 * cfg["block_q"] * hd * item          # q block (double buffered)
            + 4 * cfg["block_kv"] * hd * item       # k + v blocks
            + cfg["block_q"] * cfg["block_kv"] * 4  # logits f32
            + cfg["block_q"] * hd * 4               # o accumulator f32
            + 2 * cfg["block_q"] * 4 * chip.lane    # m/l running stats
        )
        return need <= 0.9 * chip.vmem_bytes


TEMPLATES: Dict[str, Template] = {
    t.name: t for t in (MatmulTemplate(), Conv2dTemplate(), AttentionTemplate())
}


def templates_for(op: OpDesc) -> List[Template]:
    return [t for t in TEMPLATES.values() if op.kind in t.kinds]
