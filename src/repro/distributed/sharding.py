"""Logical-axis sharding rules (DP / TP / EP / SP / pod).

Model code never names mesh axes.  It names *logical* axes ("batch",
"heads", "ffn", "experts", "vocab", "seq", "embed", ...), and a
`ShardingRules` table maps logical axes to physical mesh axes.  The same
model runs on a laptop (rules=None → every constraint is a no-op), a single
16×16 pod, or the 2×16×16 multi-pod mesh — only the rules change.

Physical axes:
  pod    pod-level data parallelism (gradients cross the DCN)
  data   in-pod data parallelism / ZeRO-1 shard axis / sequence parallelism
  model  tensor parallelism (heads, ffn, vocab) and expert parallelism

The rules are deliberately centralised: the §Perf hillclimb iterates by
editing *this table* (or passing an override), re-lowering, and re-reading
the roofline — the sharding scheme is a first-class tunable of the system,
in the same spirit as the paper's per-operator schedule search.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Logical-axis -> physical mesh axis (or tuple of axes, or None)."""

    rules: Tuple[Tuple[str, object], ...] = (
        ("batch", ("pod", "data")),   # DP over pod+data
        ("seq", None),                # sequence replicated by default
        ("seq_sharded", "data"),      # SP: long-context activations
        ("embed", None),              # residual stream replicated
        ("heads", "model"),           # TP over attention heads
        ("kv_heads", "model"),
        ("ffn", "model"),             # TP over FFN hidden
        ("experts", "model"),         # EP
        ("vocab", "model"),           # TP over vocab
        ("ssm_heads", "model"),       # TP over mamba heads
        ("conv_dim", "model"),
        ("layers", None),
        ("expert_cap", None),
        ("expert_ffn", None),         # TP inside experts when EP indivisible
        ("embed_vec", None),          # lm_head d_model dim (fallback TP
                                      # target when vocab is indivisible)
        ("embed_tbl", None),          # embed-table d_model dim: NEVER model-
                                      # sharded (SPMD gather on a dim-1-
                                      # sharded table fails the partitioner)
        ("moe_tokens", None),         # MoE (B, S*k, d) combine/dispatch token
                                      # dim; -> 'model' turns the EP combine
                                      # all-reduce into all-to-all resharding
        ("kv_seq", None),             # KV-cache sequence dim (SP on long ctx)
        ("ssm_state", None),          # mamba state dim (sharded on long ctx)
        ("zero", "data"),             # ZeRO-1 optimizer-state shard axis
    )

    def lookup(self, name: Optional[str]):
        if name is None:
            return None
        for k, v in self.rules:
            if k == name:
                return v
        raise KeyError(f"unknown logical axis {name!r}")

    def spec(self, logical: Sequence[Optional[str]]) -> P:
        return P(*[self.lookup(a) for a in logical])

    def replace(self, **kw) -> "ShardingRules":
        table = dict(self.rules)
        table.update(kw)
        return ShardingRules(tuple(table.items()))


DEFAULT_RULES = ShardingRules()


def prune_for_mesh(rules: ShardingRules, mesh: Mesh) -> ShardingRules:
    """Drop physical axes the mesh doesn't have (e.g. 'pod' on one pod)."""
    present = set(mesh.shape.keys())

    def prune(v):
        if v is None:
            return None
        if isinstance(v, str):
            return v if v in present else None
        kept = tuple(a for a in v if a in present)
        return kept if len(kept) > 1 else (kept[0] if kept else None)

    return ShardingRules(tuple((k, prune(v)) for k, v in rules.rules))

_tls = threading.local()


def activation_rules(rules: Optional[ShardingRules]):
    """Context manager installing the rules `constrain` uses inside jit."""
    class _Ctx:
        def __enter__(self):
            self.prev = getattr(_tls, "rules", None)
            _tls.rules = rules
            return rules

        def __exit__(self, *exc):
            _tls.rules = self.prev

    return _Ctx()


def constrain(x, logical: Sequence[Optional[str]]):
    """with_sharding_constraint against the active rules (no-op outside)."""
    rules = getattr(_tls, "rules", None)
    if rules is None:
        return x
    spec = rules.spec(list(logical) + [None] * (x.ndim - len(logical)))
    return jax.lax.with_sharding_constraint(x, spec)


def logical_to_spec(rules: ShardingRules, logical: Sequence[Optional[str]]) -> P:
    return rules.spec(logical)


# ---------------------------------------------------------------------------
# Parameter sharding: every model publishes a pytree of logical-axis tuples
# matching its params pytree ("param_logical_axes").  These helpers turn it
# into NamedShardings for pjit in_shardings / checkpoint resharding.
# ---------------------------------------------------------------------------

def params_shardings(mesh: Mesh, rules: ShardingRules, logical_tree):
    def to_sharding(logical):
        return NamedSharding(mesh, rules.spec(logical))

    return jax.tree.map(
        to_sharding, logical_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x),
    )


def input_shardings(mesh: Mesh, rules: ShardingRules, logical_tree):
    return params_shardings(mesh, rules, logical_tree)
