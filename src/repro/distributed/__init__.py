from repro.distributed.sharding import (
    ShardingRules,
    DEFAULT_RULES,
    constrain,
    activation_rules,
    logical_to_spec,
    params_shardings,
    input_shardings,
    prune_for_mesh,
)

__all__ = [
    "ShardingRules",
    "DEFAULT_RULES",
    "constrain",
    "activation_rules",
    "logical_to_spec",
    "params_shardings",
    "input_shardings",
    "prune_for_mesh",
]
