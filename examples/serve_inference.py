"""End-to-end serving driver (the paper is an inference system).

Boots a small qwen3-family LM, briefly trains it on the synthetic pipeline
so decode produces the learnable next-token structure, then serves a queue
of requests through the continuous-batching runtime: WPK inference plan ->
plan-aware router -> slot scheduler -> paged KV-cache -> ONE jitted
unified step (token-budget chunked-prefill lane + the decode batch) that
requests join and leave in flight.

Run:  PYTHONPATH=src python examples/serve_inference.py [--requests 12]
"""

import argparse
import time

# must run before anything imports jax: --devices N asks the CPU backend
# for N virtual host devices, and the backend latches XLA_FLAGS at the
# first jax import (see repro.platform)
from repro import platform

platform.configure_from_argv()

import jax
import numpy as np

from repro.configs import get_config
from repro.core.search.tuner import Tuner
from repro.data import DataConfig, SyntheticLMData
from repro.distributed.sharding import DEFAULT_RULES
from repro.launch.mesh import single_device_mesh, tp_mesh
from repro.launch.steps import TrainConfig, jit_train_step
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init
from repro.serve import (
    ContinuousEngine,
    PlanRouter,
    RuntimeConfig,
    build_serve_plan,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--train-steps", type=int, default=30)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--no-plan", action="store_true",
                    help="skip WPK plan tuning (pure XLA dispatch)")
    ap.add_argument("--devices", type=int, default=None,
                    help="virtual host devices (applied by repro.platform "
                         "before the jax import above)")
    ap.add_argument("--tp", type=int, default=1,
                    help="model-parallel mesh width for SERVING (<= "
                         "--devices); token streams are byte-identical "
                         "across widths")
    args = ap.parse_args()

    cfg = get_config("qwen3-1.7b").reduced(n_layers=2, d_model=128, d_ff=256,
                                           vocab=211)
    model = build_model(cfg)
    # warm-up training stays single-device; serving gets its own
    # (1, tp) mesh — the engine's serve_rules guard every indivisible
    # axis, and the token streams are byte-identical across widths
    mesh = single_device_mesh()
    serve_mesh = tp_mesh(args.tp) if args.tp > 1 else mesh
    data = SyntheticLMData(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8))

    with mesh:
        params = model.init(jax.random.PRNGKey(0))
        opt = adamw_init(params)
        batch0 = data.batch(0)
        specs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch0.items()}
        step = jit_train_step(
            model, mesh, DEFAULT_RULES,
            TrainConfig(opt=AdamWConfig(lr=3e-3, warmup_steps=5,
                                        total_steps=args.train_steps)), specs)
        for i in range(args.train_steps):
            b = {k: jax.numpy.asarray(v) for k, v in data.batch(i).items()}
            params, opt, m = step(params, opt, b)
        print(f"warm-up train: final loss {float(m['loss']):.3f} "
              f"({args.train_steps} steps)")

    rcfg = RuntimeConfig(max_slots=4, block_size=16, max_blocks_per_seq=4,
                         max_new_tokens=args.new_tokens)
    router = PlanRouter(None)
    if not args.no_plan:
        t0 = time.perf_counter()
        plan = build_serve_plan(
            cfg, prefill_len=32, slots=rcfg.max_slots, max_seq=rcfg.max_seq,
            chunk_tokens=rcfg.chunk_width,
            tuner=Tuner(methods=("random",), random_budget=16),
            model_parallel=args.tp)
        router = PlanRouter(plan)
        print(f"serve plan tuned in {time.perf_counter() - t0:.1f}s: "
              f"{router.describe()}")

    engine = ContinuousEngine(model, params, serve_mesh, DEFAULT_RULES, rcfg,
                              router=router)
    if args.tp > 1:
        print(f"serving mesh {engine.mesh_tag} | decode layouts "
              f"{router.layout_table('decode')}")
    rng = np.random.default_rng(0)
    correct = 0
    prompts = {}
    for _ in range(args.requests):
        start = int(rng.integers(0, cfg.vocab))
        prompt = (start + 17 * np.arange(16)) % cfg.vocab  # pipeline's rule
        rid = engine.submit(prompt)
        prompts[rid] = prompt

    t0 = time.perf_counter()
    done = engine.run()
    wall = time.perf_counter() - t0

    for req in done:
        prompt = prompts[req.rid]
        want = (prompt[-1] + 17 * (1 + np.arange(args.new_tokens))) % cfg.vocab
        correct += int(np.array_equal(req.output, want))
    s = engine.metrics.summary()
    print(f"served {len(done)} requests in {wall:.2f}s | "
          f"{s['tokens_per_s']:,.0f} tok/s | "
          f"latency p50 {s['latency_p50_s']:.2f}s p95 {s['latency_p95_s']:.2f}s | "
          f"ttft p50 {s['ttft_p50_s']:.2f}s | "
          f"slot occ {s['slot_occupancy_mean']:.0%} | "
          f"cache occ mean {s['cache_occupancy_mean']:.0%} "
          f"max {s['cache_occupancy_max']:.0%}")
    print(f"{correct}/{len(done)} requests continued the learned sequence exactly")


if __name__ == "__main__":
    main()
