"""End-to-end serving driver (the paper is an inference system).

Boots a small qwen3-family LM, briefly trains it on the synthetic pipeline
so decode produces the learnable next-token structure, then serves a queue
of batched requests through the prefill/decode engine — the same
`prefill_step`/`serve_step` programs the 512-chip dry-run compile-validates.

Run:  PYTHONPATH=src python examples/serve_inference.py [--requests 12]
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.data import DataConfig, SyntheticLMData
from repro.distributed.sharding import DEFAULT_RULES
from repro.launch.mesh import single_device_mesh
from repro.launch.steps import TrainConfig, jit_train_step
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init
from repro.serve import ServeConfig, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--train-steps", type=int, default=30)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config("qwen3-1.7b").reduced(n_layers=2, d_model=128, d_ff=256,
                                           vocab=211)
    model = build_model(cfg)
    mesh = single_device_mesh()
    data = SyntheticLMData(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8))

    with mesh:
        params = model.init(jax.random.PRNGKey(0))
        opt = adamw_init(params)
        batch0 = data.batch(0)
        specs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch0.items()}
        step = jit_train_step(
            model, mesh, DEFAULT_RULES,
            TrainConfig(opt=AdamWConfig(lr=3e-3, warmup_steps=5,
                                        total_steps=args.train_steps)), specs)
        for i in range(args.train_steps):
            b = {k: jax.numpy.asarray(v) for k, v in data.batch(i).items()}
            params, opt, m = step(params, opt, b)
        print(f"warm-up train: final loss {float(m['loss']):.3f} "
              f"({args.train_steps} steps)")

    engine = ServeEngine(model, params, mesh, DEFAULT_RULES,
                         ServeConfig(batch_size=4, max_seq=64,
                                     max_new_tokens=args.new_tokens))
    rng = np.random.default_rng(0)
    correct = 0
    prompts = []
    for _ in range(args.requests):
        start = int(rng.integers(0, cfg.vocab))
        prompt = (start + 17 * np.arange(16)) % cfg.vocab  # pipeline's rule
        prompts.append(prompt)
        engine.submit(prompt)

    t0 = time.perf_counter()
    done = engine.run()
    wall = time.perf_counter() - t0

    for req, prompt in zip(done, prompts):
        want = (prompt[-1] + 17 * (1 + np.arange(args.new_tokens))) % cfg.vocab
        correct += int(np.array_equal(req.output, want))
    print(f"served {len(done)} requests in {wall:.2f}s | "
          f"decode throughput {engine.throughput():,.0f} tok/s | "
          f"prefill {engine.stats['prefill_s']:.2f}s "
          f"decode {engine.stats['decode_s']:.2f}s")
    print(f"{correct}/{len(done)} requests continued the learned sequence exactly")


if __name__ == "__main__":
    main()
