"""Training driver with the full fault-tolerance loop.

Trains a small-LM config (scaled-down qwen3 family, ~10M params by default)
for a few hundred steps on the deterministic synthetic pipeline, with
periodic atomic checkpoints.  Re-running the same command resumes from the
latest checkpoint automatically; touch `<ckpt_dir>/PREEMPT` while it runs to
watch the preemption path save-and-exit.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--d-model 256]
"""

import argparse

from repro.configs import get_config
from repro.data import DataConfig, SyntheticLMData
from repro.distributed.sharding import DEFAULT_RULES
from repro.launch.mesh import single_device_mesh
from repro.launch.steps import TrainConfig
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = get_config("qwen3-1.7b").reduced(
        n_layers=args.layers, d_model=args.d_model, d_ff=4 * args.d_model,
        n_heads=4, n_kv_heads=2, head_dim=args.d_model // 4, vocab=4096)
    model = build_model(cfg)
    n_params = cfg.param_count()
    print(f"model ~{n_params / 1e6:.1f}M params "
          f"({cfg.n_layers}L d={cfg.d_model} ff={cfg.d_ff} vocab={cfg.vocab})")

    data = SyntheticLMData(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                      global_batch=args.batch))
    trainer = Trainer(
        model, single_device_mesh(), DEFAULT_RULES, data,
        TrainerConfig(
            steps=args.steps, ckpt_every=25, ckpt_dir=args.ckpt_dir,
            log_every=10,
            train=TrainConfig(
                microbatches=2,
                opt=AdamWConfig(lr=1e-3, warmup_steps=20,
                                total_steps=args.steps))))

    start, state = trainer.restore_or_init()
    if start:
        print(f"resuming from checkpoint at step {start}")
    step, state, info = trainer.run(start_step=start, state=state)
    print(f"finished at step {step}; preempted={info['preempted']}; "
          f"stragglers at {info['stragglers']}")


if __name__ == "__main__":
    main()
