"""WPK quickstart: the paper's Figure-1a pipeline on a small conv net.

    graph import -> graph optimization (§2.1) -> automated search (§2.3)
    -> system-level backend selection (§2.5) -> runtime engine

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import (
    Engine,
    Graph,
    Tuner,
    default_registry,
    optimize_graph,
    select,
)


def build_graph() -> Graph:
    rng = np.random.default_rng(0)
    g = Graph("quickstart")
    x = g.add_input("x", (4, 3, 32, 32))
    w1 = g.add_constant("w1", rng.standard_normal((16, 3, 3, 3)).astype(np.float32) * 0.2)
    c1 = g.add_node("conv2d", [x, w1], (4, 16, 32, 32), {"stride": 1, "padding": "SAME"})
    sc = g.add_constant("sc", (rng.random(16) + 0.5).astype(np.float32))
    sh = g.add_constant("sh", rng.standard_normal(16).astype(np.float32) * 0.1)
    b1 = g.add_node("batch_norm", [c1, sc, sh], (4, 16, 32, 32))
    r1 = g.add_node("relu", [b1], (4, 16, 32, 32))
    d1 = g.add_node("dropout", [r1], (4, 16, 32, 32))   # removed at inference
    w2 = g.add_constant("w2", rng.standard_normal((32, 16, 3, 3)).astype(np.float32) * 0.2)
    c2 = g.add_node("conv2d", [d1, w2], (4, 32, 16, 16), {"stride": 2, "padding": "SAME"})
    g2 = g.add_node("gelu", [c2], (4, 32, 16, 16))
    gp = g.add_node("global_avg_pool", [g2], (4, 32))
    wf = g.add_constant("wf", rng.standard_normal((32, 10)).astype(np.float32) * 0.3)
    out = g.add_node("matmul", [gp, wf], (4, 10))
    g.set_outputs([out])
    return g


def main() -> None:
    g = build_graph()
    print(f"imported   : {g}")

    gopt = optimize_graph(g)                       # §2.1
    print(f"optimized  : {gopt}")

    tuner = Tuner(methods=("genetic",))            # §2.3 (add 'rl' for §2.4)
    plan = select(gopt, tuner=tuner)               # §2.2 + §2.5
    print(f"plan       : {plan.backend_histogram()}, "
          f"modeled {plan.total_modeled_time_s() * 1e6:.1f} us/batch on TPU v5e")
    for name, choice in plan.choices.items():
        print(f"  {name:24s} -> {choice.backend:16s} "
              f"{choice.modeled_time_s * 1e6:7.2f} us  "
              f"(candidates: {({k: round(v * 1e6, 2) for k, v in choice.candidates.items()})})")

    engine = Engine(gopt, plan, default_registry(interpret=True))
    x = jnp.asarray(np.random.default_rng(1).standard_normal((4, 3, 32, 32)).astype(np.float32))
    err = engine.verify_against_reference(x)
    print(f"engine     : optimized plan == reference graph (max err {err:.2e})")


if __name__ == "__main__":
    main()
