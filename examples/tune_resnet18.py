"""Paper reproduction driver: tune ResNet-18's convolutions (§3.1-§3.3).

Runs genetic search (and optionally RL-search, §2.4) on every deduplicated
convolution group of ResNet-18 and prints the Figure-2b-style speedup table
vs the vendor (XLA) backend, plus the Figure-3b search-time column and the
§3.3 cache-reuse demonstration.

Run:  PYTHONPATH=src python examples/tune_resnet18.py [--rl]
"""

import argparse
import time

import numpy as np

from repro.core import SearchCache, SearchTask, TEMPLATES, Tuner, rl_search, xla_time
from repro.models.resnet import conv_groups


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rl", action="store_true", help="also run RL-search (§2.4, slower)")
    args = ap.parse_args()

    cache = SearchCache()
    tuner = Tuner(methods=("genetic",), cache=cache)
    print(f"{'conv':8s} {'shape':>24s} {'vendor us':>10s} {'wpk us':>8s} "
          f"{'speedup':>8s} {'search s':>9s}")
    speedups = []
    for name, op in conv_groups(batch=1, image=224):
        t0 = time.perf_counter()
        res = tuner.tune(op)
        dt = time.perf_counter() - t0
        if args.rl:
            rl = rl_search(SearchTask(op, TEMPLATES["pallas_conv2d"], seed=0),
                           episodes=3, steps_per_episode=16)
            if rl.runtime_s < res.runtime_s:
                res = rl
        t_vendor = xla_time(op)
        sp = t_vendor / res.runtime_s
        speedups.append(sp)
        d = op.d
        shape = f"{d['h']}x{d['w']}x{d['cin']}->{d['cout']} k{d['kh']} s{d['stride']}"
        print(f"{name:8s} {shape:>24s} {t_vendor * 1e6:10.2f} "
              f"{res.runtime_s * 1e6:8.2f} {sp:8.2f} {dt:9.2f}")

    print(f"\nmean speedup {np.mean(speedups):.2f}x  max {np.max(speedups):.2f}x "
          f"(paper: 2.54x mean, 5.40x max over cuDNN)")

    # §3.3: the cache makes a second model from the same backbone ~free
    t0 = time.perf_counter()
    for _, op in conv_groups(batch=1, image=224):
        tuner.tune(op)
    print(f"warm-cache re-tune of the whole backbone: "
          f"{(time.perf_counter() - t0) * 1e3:.1f} ms ({cache.hits} hits)")


if __name__ == "__main__":
    main()
